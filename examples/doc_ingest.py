"""Batch document ingest on the declarative API: parse -> digest -> index.

    PYTHONPATH=src python examples/doc_ingest.py

The digest stage fans out over 72 chunks; LLM decode streams the weights
once per step regardless of batch size (the batch roofline, DESIGN.md §7),
so below the compute knee batching is nearly free and constraint choice
mostly moves the parse/digest *tiers* (pypdf vs OCR, 7B vs 104B) while the
scheduler co-schedules chunks aggressively under every objective.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import MAX_QUALITY, MIN_COST, MIN_LATENCY, Murakkab
from repro.configs.workflow_docingest import make_docingest_job

if __name__ == "__main__":
    for tag, c in [("MIN_COST", MIN_COST), ("MIN_LATENCY", MIN_LATENCY),
                   ("MAX_QUALITY", MAX_QUALITY)]:
        system = Murakkab.paper_cluster()
        result = make_docingest_job(c).execute(system)
        print(f"\n== {tag} ==")
        for tid, cfg in result.plan.configs.items():
            node = result.dag.nodes[tid]
            print(f"  {node.agent:<10s} items={node.work_items:<3d} -> "
                  f"{cfg.impl:<26s} {cfg.pool:<4s} "
                  f"x{cfg.n_devices * cfg.n_instances:<3d} "
                  f"batch={cfg.batch}")
        print(f"  makespan={result.makespan_s:.1f}s "
              f"energy={result.energy_wh:.1f}Wh cost=${result.usd:.4f} "
              f"quality={result.quality:.3f}")
        print(result.trace_str())
