"""Fault-tolerance demo: injected node failures, straggler mitigation and
elastic remesh planning — the machinery a 1000-node deployment leans on.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpointing.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.models.model_zoo import build_model
from repro.runtime import train as train_rt
from repro.runtime.fault_tolerance import (RestartPolicy, StragglerMonitor,
                                           plan_remesh, run_with_restarts)

CKPT = "/tmp/repro_ft_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    opts = train_rt.TrainOptions(remat_policy=None)
    state = train_rt.init_train_state(model, jax.random.PRNGKey(0), opts)
    step = jax.jit(train_rt.build_train_step(model, opts))
    data = DataIterator(DataConfig(cfg.vocab_size, 32, 4), model_cfg=cfg)
    ckpt = CheckpointManager(CKPT, keep=2, async_save=False)

    # inject two failures (a preemption at step 7 and a crash at step 13)
    injected = {7, 13}

    def fail_hook(s):
        if s in injected:
            injected.discard(s)
            raise RuntimeError(f"injected node failure at step {s}")

    state, hist, failures = run_with_restarts(
        num_steps=20, state=state, data_iter=data, step_fn=step,
        ckpt_manager=ckpt, save_every=5,
        policy=RestartPolicy(max_failures=5), fail_hook=fail_hook, log=print)
    print(f"\nsurvived {failures} injected failures; "
          f"completed {int(state['step'])} steps; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # straggler mitigation policy
    mon = StragglerMonitor(threshold=1.5)
    for i in range(10):
        for w in ("w0", "w1", "w2", "w3"):
            mon.record(w, 1.0 if w != "w3" else 2.5)   # w3 lags
    print(f"stragglers flagged: {mon.stragglers()} "
          f"-> action: {mon.action('w3')}")

    # elastic remesh: lose 64 of 512 devices
    plan = plan_remesh((2, 16, 16), ("pod", "data", "model"),
                       devices_available=448)
    print(f"remesh after losing 64/512 devices: {plan.old_shape} -> "
          f"{plan.new_shape} (uses {plan.devices_used}; resharded axes: "
          f"{plan.resharded_axes}; per-device batch x{plan.batch_scale:.2f})")


if __name__ == "__main__":
    main()
