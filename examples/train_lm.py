"""Training example: train a small LM end-to-end with the full substrate
(data pipeline -> AdamW -> checkpoint/restart -> straggler monitor),
then resume from the checkpoint to prove bitwise-deterministic restart.

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    ckpt = f"/tmp/repro_example_ckpt_{args.arch}"
    shutil.rmtree(ckpt, ignore_errors=True)

    # phase 1: half the run
    half = args.steps // 2
    r1 = train_main(["--arch", args.arch, "--steps", str(half),
                     "--batch", str(args.batch), "--seq", str(args.seq),
                     "--lr", "1e-3", "--ckpt-dir", ckpt,
                     "--save-every", "10"])
    # phase 2: resume to the full step count (auto-restores the checkpoint)
    r2 = train_main(["--arch", args.arch, "--steps", str(args.steps),
                     "--batch", str(args.batch), "--seq", str(args.seq),
                     "--lr", "1e-3", "--ckpt-dir", ckpt,
                     "--save-every", "10"])
    assert r2["loss_last"] < r1["loss_first"], "loss must decrease end-to-end"
    print(f"\nOK: loss {r1['loss_first']:.3f} -> {r2['loss_last']:.3f} "
          f"across a checkpoint/resume boundary")


if __name__ == "__main__":
    main()
