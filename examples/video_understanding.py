"""The paper's full evaluation workflow (Fig. 3), baseline vs Murakkab.

    PYTHONPATH=src python examples/video_understanding.py          # simulate
    PYTHONPATH=src python examples/video_understanding.py --real   # real JAX

``--real`` executes every agent as an actual JAX program on this machine
(reduced model configs) and verifies the paper's claim that baseline and
Murakkab produce identical outputs — the configurations differ only in
*where/how* agents run, never in *what* they compute.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import MIN_COST, Murakkab
from repro.core.executor import Media, RealExecutor
from repro.configs.workflow_video import (PAPER_VIDEOS,
                                          make_baseline_workflow,
                                          make_declarative_job)


def simulate():
    base_sys = Murakkab.paper_cluster()
    base = make_baseline_workflow().execute(base_sys, inputs=PAPER_VIDEOS)
    print("== BASELINE (paper Listing 1: pinned, sequential) ==")
    print(base.trace_str())

    mur_sys = Murakkab.paper_cluster()
    mur_sys.prewarm("nvlm-72b", "gpu", 8)
    mur_sys.prewarm("nvlm-embed", "gpu", 2)
    mur_sys.prewarm("whisper-large", "gpu", 1)
    res = make_declarative_job(MIN_COST).execute(mur_sys)
    print("\n== MURAKKAB (MIN_COST) ==")
    print(res.trace_str())
    print(f"\nspeedup {base.makespan_s / res.makespan_s:.2f}x (paper ~3.4x); "
          f"energy efficiency {base.energy_wh / res.energy_wh:.2f}x "
          f"(paper ~4.5x)")


def real():
    media = [Media.synthesize(v.name, v.scenes, v.frames_per_scene, seed=i)
             for i, v in enumerate(PAPER_VIDEOS)]

    # Murakkab plan
    sys_m = Murakkab.paper_cluster()
    dag_m, plan_m = sys_m.plan(make_declarative_job(MIN_COST))
    out_m = RealExecutor(sys_m.library).run(dag_m, plan_m, media)

    # baseline plan (pinned)
    sys_b = Murakkab.paper_cluster()
    dag_b, plan_b = sys_b.lower_imperative(make_baseline_workflow(),
                                           PAPER_VIDEOS)
    out_b = RealExecutor(sys_b.library).run(dag_b, plan_b, media)

    print("== real execution (reduced models, CPU) ==")
    for tid, o in out_m.items():
        if tid != "_timings":
            print(f"  {tid:<22s} -> {np.asarray(o).shape}")
    summ_m = np.asarray([v for k, v in out_m.items() if "summar" in k][0])
    summ_b = np.asarray([v for k, v in out_b.items() if "summar" in k][0])
    same = np.array_equal(summ_m, summ_b)
    print(f"\nbaseline and Murakkab summaries identical: {same} "
          f"(paper: 'execution output and accuracy are the same')")
    assert same
    print("timings:", {k: f"{v:.2f}s" for k, v in out_m["_timings"].items()})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true")
    args = ap.parse_args()
    (real if args.real else simulate)()
