"""Quickstart: the paper's Listing 2 in five lines.

    PYTHONPATH=src python examples/quickstart.py

The constraint below uses the composable DSL (core/constraints.py):
``Deadline(s=60)`` ahead of ``MinCost()`` means "meet a 60-second
end-to-end deadline; among configurations that do, spend the least".
The seed enum (``constraints=MIN_COST``) still works everywhere.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Deadline, Job, Lexicographic, MinCost, Murakkab, \
    VideoInput

# Define the job in natural language (paper Listing 2)
desc = "List objects shown/mentioned in the videos"
# Optional: specify sub-tasks in the job
t1 = "Extract frames from each video"
t2 = "Run speech-to-text on all scenes"
t3 = "Detect objects in the frames"
# Inputs
videos = [VideoInput("cats.mov", scenes=4), VideoInput("formula_1.mov", scenes=4)]

# Execute: meet a 60 s deadline, then minimize spend
system = Murakkab.paper_cluster()
result = Job(description=desc, inputs=videos, tasks=[t1, t2, t3],
             constraints=Lexicographic(Deadline(s=60), MinCost())).execute(system)

print("== task DAG ==")
for row in result.dag.to_json():
    print(f"  {row['id']:<22s} deps={row['deps']}")
print("\n== generated toolcalls (paper §3.2) ==")
for tid, call in result.toolcalls.items():
    print(f"  {tid:<22s} {call}")
print("\n== chosen configuration per task ==")
for tid, cfg in result.plan.configs.items():
    print(f"  {tid:<22s} {cfg.impl:<16s} {cfg.pool:<4s} "
          f"x{cfg.n_devices * cfg.n_instances:<3d} batch={cfg.batch}")
print("\n== execution ==")
print(result.trace_str())
assert result.makespan_s <= 60.0, "deadline missed"
