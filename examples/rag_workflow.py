"""Agentic RAG on the declarative API: retrieval routing by constraint.

    PYTHONPATH=src python examples/rag_workflow.py

The same four-stage workflow (retrieve -> rerank -> synthesize -> index)
executes three ways without changing its definition: MIN_COST routes
retrieval to lexical BM25 on CPU cores, MAX_QUALITY pays for hybrid
retrieval and an LLM reranker, and a Deadline(30s)+MinEnergy ordering
finds the lowest-energy plan that meets the SLO.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (Deadline, Lexicographic, MAX_QUALITY, MIN_COST,
                        MinEnergy, Murakkab)
from repro.configs.workflow_rag import make_rag_job


def run(tag, constraints):
    system = Murakkab.paper_cluster()
    result = make_rag_job(constraints).execute(system)
    print(f"\n== {tag} ==")
    for tid, cfg in result.plan.configs.items():
        agent = result.dag.nodes[tid].agent
        print(f"  {agent:<12s} -> {cfg.impl:<22s} {cfg.pool:<4s} "
              f"x{cfg.n_devices * cfg.n_instances:<3d} batch={cfg.batch}")
    print(f"  makespan={result.makespan_s:.1f}s "
          f"energy={result.energy_wh:.1f}Wh cost=${result.usd:.4f} "
          f"quality={result.quality:.3f}")
    return result


if __name__ == "__main__":
    cheap = run("MIN_COST (keyword route)", MIN_COST)
    best = run("MAX_QUALITY (hybrid route)", MAX_QUALITY)
    slo = run("Deadline(30s) then MinEnergy",
              Lexicographic(Deadline(s=30.0), MinEnergy()))
    print(f"\nrouting lever: quality {cheap.quality:.3f} -> "
          f"{best.quality:.3f} for {best.usd / max(cheap.usd, 1e-9):.1f}x "
          f"the cost; SLO plan meets {slo.makespan_s:.1f}s <= 30s-ish "
          f"while spending {slo.energy_wh:.1f}Wh")
