"""End-to-end driver (the paper's kind = serving): batched request serving
of a small zoo model, scheduled by Murakkab on the TPU-cluster model.

1. Murakkab receives a stream of QA jobs (declarative),
2. plans them onto the shared TPU cluster model (warm instances multiplex),
3. and serves the actual generations with a real JAX model on this machine.

    PYTHONPATH=src python examples/serve_workflow.py --requests 12
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Job, MIN_LATENCY, Murakkab
from repro.configs.registry import get_config
from repro.models.model_zoo import build_model
from repro.runtime.serve import ServeOptions, ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    # --- 1) Murakkab schedules the request stream on the cluster model ------
    system = Murakkab.tpu_cluster(v5e=64, v5p=0, v4_harvest=0, host_cores=64)
    jobs = {
        f"req{i}": (Job(description=f"Answer the user question #{i} over "
                        "the indexed summaries",
                        tasks=(f"Answer question {i} from retrieved context",),
                        constraints=MIN_LATENCY, quality_floor=0.8), i * 0.5)
        for i in range(args.requests)}
    report = system.execute_many(jobs)
    warm = sum(1 for e in report.trace if e.note == "warm")
    print(f"[murakkab] {args.requests} QA jobs: makespan "
          f"{report.makespan_s:.1f}s, energy {report.energy_wh:.2f}Wh, "
          f"warm-instance hits {warm}/{len(report.trace)}")

    # --- 2) real batched serving of the generations --------------------------
    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sess = ServeSession(model, params, opts=ServeOptions())
    rng = np.random.default_rng(0)
    t0 = time.time()
    outs = []
    for i in range(0, args.requests, args.batch):
        n = min(args.batch, args.requests - i)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (n, 24),
                                           dtype=np.int32))
        outs.append(sess.generate(prompts, max_new_tokens=args.max_new))
    jax.block_until_ready(outs[-1])
    dt = time.time() - t0
    total = args.requests * args.max_new
    print(f"[serve] {args.arch} (reduced): {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s) across "
          f"{(args.requests + args.batch - 1) // args.batch} batches")
    print("sample generation:", np.asarray(outs[0][0]))


if __name__ == "__main__":
    main()
