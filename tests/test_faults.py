"""Fault injection + failure-aware recovery (DESIGN.md §10).

Covers the PR's acceptance surface:

1. **Inertness** — ``faults=None`` and a zero-probability profile are
   byte-identical to each other on both dispatch paths (trace, energy,
   per-class metrics): the subsystem provably does not perturb fault-free
   runs.
2. **Replay determinism** (hypothesis) — the same ``FaultProfile`` seed
   reproduces the identical run: trace, energy and every fault counter.
3. **Accounting safety** (hypothesis) — crash-then-resume never drives a
   pool's busy/served/energy ledgers negative, and the cluster passes a
   full ``audit()`` after every fault run (unconditionally, not just
   under ``__debug__``).
4. **Recovery semantics** — dead-lettering terminates a saturated run,
   retries resume chunkable tasks from their checkpoint, hedges fire on
   stragglers and first-wins, crashes repair back to nominal capacity.
"""
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.configs.workflow_video import make_declarative_job
from repro.core import MIN_LATENCY, Murakkab
from repro.core.arrivals import PoissonArrivals, default_mix
from repro.core.faults import (DEFAULT_MAX_ATTEMPTS, FaultProfile,
                               RetryPolicy)


def _system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128)


def _open_loop(faults, horizon=600.0, seed=4, **kw):
    system = _system()
    rep = system.open_loop(
        PoissonArrivals(rate_per_s=0.25, mix=default_mix(), seed=seed),
        horizon_s=horizon, warmup_s=60.0, faults=faults, **kw)
    return system, rep


def _closed_loop(faults, n=6, policy="strict-priority"):
    system = _system()
    jobs = {f"j{i}": (make_declarative_job(MIN_LATENCY), i * 30.0)
            for i in range(n)}
    rep = system.execute_many(jobs, policy=policy, faults=faults)
    return system, rep


def _key(rep):
    return (rep.trace, rep.energy_wh, rep.usd, rep.wasted_dev_s,
            rep.faults_injected, rep.instance_crashes, rep.task_faults,
            rep.fault_retries, rep.hedges_launched, rep.hedges_won,
            rep.dead_letters, rep.degrade_replans)


FP = FaultProfile(seed=7,
                  instance_mtbf_s={"v5e": 700.0, "v4_harvest": 500.0},
                  repair_s=120.0, task_fail_p=0.05, straggler_p=0.05)


# -- profile validation -------------------------------------------------------

def test_profile_validates():
    with pytest.raises(ValueError, match="MTBF"):
        FaultProfile(instance_mtbf_s={"v5e": 0.0})
    with pytest.raises(ValueError, match="repair_s"):
        FaultProfile(instance_mtbf_s={"v5e": 100.0}, repair_s=0.0)
    with pytest.raises(ValueError, match="task_fail_p"):
        FaultProfile(task_fail_p=1.5)
    with pytest.raises(ValueError, match="straggler_mult"):
        FaultProfile(straggler_p=0.5, straggler_mult=1.0)
    with pytest.raises(ValueError, match="unknown pool"):
        _open_loop(FaultProfile(instance_mtbf_s={"nope": 100.0}))


def test_retry_policy_backoff():
    rp = RetryPolicy()
    assert rp.attempts_for("priority") == DEFAULT_MAX_ATTEMPTS["priority"]
    assert rp.attempts_for("unknown-class") == rp.default_attempts
    # centre of the jitter band: pure exponential, capped
    assert rp.backoff_s(1, 0.5) == pytest.approx(rp.backoff_base_s)
    assert rp.backoff_s(2, 0.5) == pytest.approx(
        rp.backoff_base_s * rp.backoff_mult)
    assert rp.backoff_s(50, 0.5) == pytest.approx(rp.backoff_cap_s)
    # jitter spans +/- jitter_frac
    assert rp.backoff_s(1, 1.0) == pytest.approx(
        rp.backoff_base_s * (1 + rp.jitter_frac))
    assert rp.backoff_s(1, 0.0) == pytest.approx(
        rp.backoff_base_s * (1 - rp.jitter_frac))


# -- 1. inertness: fault-free runs are untouched ------------------------------

@pytest.mark.parametrize("fast", [True, False])
def test_zero_probability_profile_is_byte_identical(fast):
    """A profile that can never fire must not perturb the run at all —
    and ``faults=None`` must equal it (same heap, same float-op order)."""
    _, base = _open_loop(None, fast_dispatch=fast)
    _, zero = _open_loop(FaultProfile(seed=1), fast_dispatch=fast)
    assert base.trace == zero.trace
    assert base.energy_wh == zero.energy_wh
    assert base.usd == zero.usd
    assert base.per_class == zero.per_class
    assert zero.faults_injected == 0 and zero.hedges_launched == 0
    assert zero.dead_letters == 0


def test_closed_loop_zero_probability_identical():
    _, base = _closed_loop(None)
    _, zero = _closed_loop(FaultProfile(seed=1))
    assert base.trace == zero.trace
    assert base.energy_wh == zero.energy_wh


# -- 2./3. hypothesis: replay determinism + accounting safety ----------------

@given(st.integers(0, 10 ** 6))
@settings(max_examples=8, deadline=None)
def test_fault_replay_is_deterministic(seed):
    """Same seed, same profile => byte-identical replay (trace, ledgers,
    every counter), plus ledger non-negativity and a clean audit."""
    fp = FaultProfile(seed=seed,
                      instance_mtbf_s={"v5e": 600.0, "v4_harvest": 400.0},
                      repair_s=90.0, task_fail_p=0.08, straggler_p=0.08)
    sys_a, a = _open_loop(fp, horizon=300.0)
    sys_b, b = _open_loop(fp, horizon=300.0)
    assert _key(a) == _key(b)
    assert a.per_class == b.per_class
    # crash-then-resume never drives the ledgers negative
    assert a.energy_wh >= 0.0 and a.active_wh >= -1e-9 and a.usd >= 0.0
    for pool, busy in a.pool_busy_device_s.items():
        assert busy >= -1e-6, (pool, busy)
    assert a.wasted_dev_s >= 0.0
    # satellite #2: audit unconditionally in tests (run() already audits
    # under __debug__; this keeps the invariant under python -O too)
    sys_a.cluster.audit()
    sys_b.cluster.audit()


@given(st.integers(0, 10 ** 6))
@settings(max_examples=4, deadline=None)
def test_closed_loop_fault_run_is_safe(seed):
    fp = FaultProfile(seed=seed, instance_mtbf_s={"v5e": 300.0},
                      repair_s=60.0, task_fail_p=0.1, straggler_p=0.1)
    system, rep = _closed_loop(fp)
    system.cluster.audit()
    assert rep.energy_wh >= 0.0
    for pool, busy in rep.pool_busy_device_s.items():
        assert busy >= -1e-6, (pool, busy)
    # every workflow either completed or was dead-lettered
    done = sum(1 for v in rep.per_workflow.values() if v["finish"] > 0.0)
    assert done + rep.dead_letters >= len(rep.per_workflow) - \
        rep.dead_letters or done <= len(rep.per_workflow)


# -- 4. recovery semantics ----------------------------------------------------

def test_dead_letter_saturation_terminates():
    """task_fail_p=1.0: every attempt fails, every workflow exhausts its
    budget and dead-letters; the run still terminates (crash/retry chains
    stop once nothing is incomplete)."""
    system, rep = _closed_loop(FaultProfile(seed=3, task_fail_p=1.0))
    assert rep.dead_letters == 6
    assert rep.fault_retries > 0          # it did try before giving up
    system.cluster.audit()


def test_dead_letters_count_against_slo_attainment():
    fp = FaultProfile(seed=3, task_fail_p=1.0)
    _, rep = _open_loop(fp, horizon=300.0)
    assert rep.dead_letters > 0
    assert rep.completed == 0
    for row in rep.per_class.values():
        assert row["dead"] > 0
        assert row["slo_attainment"] == 0.0


def test_transient_failures_retry_and_complete():
    fp = FaultProfile(seed=11, task_fail_p=0.15)
    _, rep = _closed_loop(fp)
    assert rep.task_faults > 0
    assert rep.fault_retries > 0
    # trace records the failed attempts distinctly
    notes = {e.note for e in rep.trace}
    assert "failed" in notes


def test_retry_resumes_chunkable_from_checkpoint():
    """With resume on, a failed chunkable task keeps its completed items
    (resumed_items > 0 and a later attempt carries a "resume" note)."""
    fp = FaultProfile(seed=5, task_fail_p=0.25)
    _, rep = _closed_loop(fp)
    assert rep.resumed_items > 0
    assert any(e.note.startswith("resume") for e in rep.trace)


def test_hedge_launches_and_first_wins():
    """Every task straggles (4x): hedges launch at the threshold and most
    beat their primaries; the loser is traced as hedge_lost/beat."""
    fp = FaultProfile(seed=2, straggler_p=1.0)
    _, rep = _closed_loop(fp)
    assert rep.hedges_launched > 0
    assert rep.hedges_won > 0
    notes = {e.note for e in rep.trace}
    assert notes & {"hedge_lost", "hedge_beat_primary"}
    assert any("slow" in e.note for e in rep.trace)


def test_hedge_disabled_launches_none():
    fp = FaultProfile(seed=2, straggler_p=1.0, hedge=False)
    _, rep = _closed_loop(fp)
    assert rep.faults_injected > 0        # stragglers still injected
    assert rep.hedges_launched == 0 and rep.hedges_won == 0


def test_hedging_beats_no_hedging_on_makespan():
    """At 100% straggler rate, first-wins hedging onto spare capacity
    should strictly shorten the run vs letting stragglers drag."""
    slow = FaultProfile(seed=2, straggler_p=1.0, hedge=False)
    hedged = FaultProfile(seed=2, straggler_p=1.0)
    _, a = _closed_loop(slow)
    _, b = _closed_loop(hedged)
    assert b.hedges_won > 0
    assert b.makespan_s < a.makespan_s


def test_crashes_repair_back_to_nominal():
    fp = FaultProfile(seed=9, instance_mtbf_s={"v5e": 120.0},
                      repair_s=30.0)
    system, rep = _closed_loop(fp)
    assert rep.instance_crashes > 0
    assert any(e.note == "crashed" for e in rep.trace) or \
        rep.task_faults == 0    # crashes may only have hit idle shells
    # every crash's repair restores the pool to its nominal size
    assert system.cluster.pools["v5e"].capacity == 64
    system.cluster.audit()


def test_open_loop_full_fault_mix():
    """All fault classes at once on the serving path: the run drains,
    counters are populated, and per-class metrics stay well-formed."""
    _, rep = _open_loop(FP)
    assert rep.faults_injected > 0
    assert rep.completed + rep.dead_letters == rep.arrivals
    for row in rep.per_class.values():
        if row["slo_attainment"] is not None:
            assert 0.0 <= row["slo_attainment"] <= 1.0
        assert row["dead"] >= 0


def test_open_loop_reference_dispatch_fault_run():
    """The full-rescan reference path also runs faults to completion and
    is itself deterministic (fast-vs-ref equality is only guaranteed
    fault-free: hedge/crash placement depends on live availability)."""
    _, a = _open_loop(FP, horizon=300.0, fast_dispatch=False)
    _, b = _open_loop(FP, horizon=300.0, fast_dispatch=False)
    assert _key(a) == _key(b)
    assert a.faults_injected > 0
    assert a.completed + a.dead_letters == a.arrivals
