"""DAG IR: validation, topological order, critical path (+ hypothesis)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dag import DAG, TaskNode


def _node(i, deps=(), agent="summarize"):
    return TaskNode(id=f"t{i}", description=f"task {i}", agent=agent,
                    deps=tuple(deps))


def test_duplicate_id_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        DAG([_node(0), _node(0)])


def test_unknown_dep_rejected():
    with pytest.raises(ValueError, match="unknown"):
        DAG([_node(0, deps=("t9",))])


def test_cycle_rejected():
    a = TaskNode(id="a", description="", agent="x", deps=("b",))
    b = TaskNode(id="b", description="", agent="x", deps=("a",))
    with pytest.raises(ValueError, match="cycle"):
        DAG([a, b])


def test_topo_and_structure():
    d = DAG([_node(0), _node(1, ["t0"]), _node(2, ["t0"]),
             _node(3, ["t1", "t2"])])
    order = d.topo_order
    assert order.index("t0") < order.index("t1") < order.index("t3")
    assert d.roots() == ["t0"]
    assert d.leaves() == ["t3"]
    assert d.successors("t0") == ["t1", "t2"]
    assert d.levels() == [["t0"], ["t1", "t2"], ["t3"]]


def test_critical_path():
    d = DAG([_node(0), _node(1, ["t0"]), _node(2, ["t0"]),
             _node(3, ["t1", "t2"])])
    dur = {"t0": 1.0, "t1": 5.0, "t2": 2.0, "t3": 1.0}
    total, path = d.critical_path(dur)
    assert total == 7.0
    assert path == ("t0", "t1", "t3")


@st.composite
def random_dag_edges(draw):
    n = draw(st.integers(2, 12))
    edges = []
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()):
                edges.append((i, j))
    return n, edges


@given(random_dag_edges())
@settings(max_examples=50, deadline=None)
def test_topo_order_property(ne):
    """Every forward-edge layered graph is a valid DAG; topo respects deps."""
    n, edges = ne
    deps = {j: [f"t{i}" for i, jj in edges if jj == j] for j in range(n)}
    d = DAG([_node(i, deps.get(i, [])) for i in range(n)])
    pos = {t: k for k, t in enumerate(d.topo_order)}
    assert len(pos) == n
    for i, j in edges:
        assert pos[f"t{i}"] < pos[f"t{j}"]


@given(random_dag_edges(), st.lists(st.floats(0.1, 100), min_size=12,
                                    max_size=12))
@settings(max_examples=50, deadline=None)
def test_critical_path_bounds_property(ne, durs):
    """cp <= sum(durations) and cp >= max(single duration)."""
    n, edges = ne
    deps = {j: [f"t{i}" for i, jj in edges if jj == j] for j in range(n)}
    d = DAG([_node(i, deps.get(i, [])) for i in range(n)])
    dur = {f"t{i}": durs[i] for i in range(n)}
    cp, path = d.critical_path(dur)
    assert cp <= sum(dur[f"t{i}"] for i in range(n)) + 1e-9
    assert cp >= max(dur[f"t{i}"] for i in range(n)) - 1e-9
    # path is a real dependency chain
    for a, b in zip(path, path[1:]):
        assert a in d.nodes[b].deps
