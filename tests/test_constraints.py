"""Constraint DSL: normalization, lexicographic bands, deadlines, budgets,
weighted blends — and their end-to-end effect on scheduling."""
import pytest

from repro.core import (Budget, ConstraintSpec, Deadline, Job, Lexicographic,
                        MAX_QUALITY, MIN_COST, MIN_ENERGY, MIN_LATENCY,
                        MaxQuality, MinCost, MinEnergy, MinLatency, Murakkab,
                        TaskConfig, Weighted, as_spec)
from repro.core.dag import TaskNode


def _cfg(usd=1.0, j=1.0, lat=1.0, q=0.9):
    return TaskConfig(impl="x", pool="p", n_devices=1, est_usd=usd,
                      est_energy_j=j, est_latency_s=lat, quality=q)


def _node(agent="summarize", items=8):
    return TaskNode(id="t", description="", agent=agent, work_items=items,
                    chunkable=True, tokens_in=900, tokens_out=120)


# -- normalization -----------------------------------------------------------


def test_as_spec_accepts_all_forms():
    for form in (MIN_COST, (MIN_COST,), [MIN_COST, MIN_ENERGY], MinCost(),
                 (MinCost(), MIN_LATENCY), Lexicographic(MIN_COST),
                 ConstraintSpec((MinCost(),))):
        spec = as_spec(form)
        assert isinstance(spec, ConstraintSpec)
        assert isinstance(spec.objectives[0], MinCost)
    assert isinstance(as_spec(MAX_QUALITY).objectives[0], MaxQuality)
    with pytest.raises(TypeError):
        as_spec("cheapest please")
    with pytest.raises(ValueError):
        as_spec(())


def test_objective_values():
    c = _cfg(usd=2.0, j=30.0, lat=5.0, q=0.8)
    assert MinCost().value(c) == 2.0
    assert MinEnergy().value(c) == 30.0
    assert MinLatency().value(c) == 5.0
    assert MaxQuality().value(c) == -0.8


def test_lexicographic_bands_break_near_ties():
    """Same 5% log-band on the primary counts as a tie; secondary decides."""
    spec = as_spec((MIN_LATENCY, MIN_COST))
    near = _cfg(lat=1.02, usd=0.1)       # same band, 10x cheaper
    fast = _cfg(lat=1.00, usd=1.0)
    assert spec.key(near) < spec.key(fast)
    far = _cfg(lat=2.0, usd=0.001)       # 2x slower: latency dominates
    assert spec.key(fast) < spec.key(far)


def test_deadline_semantics():
    d = Deadline(s=10.0)
    assert d.value(_cfg(lat=8.0)) == 0.0          # met -> no pressure
    assert d.value(_cfg(lat=14.0)) == pytest.approx(4.0)
    assert d.per_task(4) == Deadline(s=2.5)
    # among deadline-met configs the secondary objective decides
    spec = Lexicographic(Deadline(s=10.0), MinEnergy())
    cheap = _cfg(lat=9.9, j=1.0)
    fast = _cfg(lat=1.0, j=50.0)
    assert spec.key(cheap) < spec.key(fast)


def test_budget_semantics():
    b = Budget(usd=1.0, wh=1.0)
    assert b.value(_cfg(usd=0.5, j=1000.0)) == 0.0
    assert b.value(_cfg(usd=2.0, j=1000.0)) == pytest.approx(1.0)
    assert b.value(_cfg(usd=0.0, j=7200.0)) == pytest.approx(1.0)
    half = b.per_task(2)
    assert half.usd == 0.5 and half.wh == 0.5
    assert Budget(usd=1.0).per_task(4).wh is None


def test_weighted_blend():
    w = Weighted.of(cost=1.0, energy=0.5)
    assert w.value(_cfg(usd=2.0, j=4.0)) == pytest.approx(4.0)
    assert Weighted.of(latency=1.0).value(_cfg(lat=7.0)) == 7.0
    # per_task propagates into nested workflow-level terms
    nested = Weighted(((Deadline(s=8.0), 1.0),)).per_task(4)
    assert nested.terms[0][0] == Deadline(s=2.0)


def test_deadline_feasible_beats_small_overrun():
    """Regression: a sub-unit overrun must not band below feasibility."""
    spec = Lexicographic(Deadline(s=60.0), MinEnergy())
    feasible = _cfg(lat=59.0, j=100.0)
    overrun = _cfg(lat=60.9, j=1.0)
    assert spec.key(feasible) < spec.key(overrun)
    # same for budget caps: within budget beats slightly-over
    bspec = Lexicographic(Budget(usd=1.0), MinLatency())
    within = _cfg(usd=0.99, lat=100.0)
    over = _cfg(usd=1.5, lat=1.0)
    assert bspec.key(within) < bspec.key(over)


def test_quality_primary_ordering_respects_quality():
    """Regression: MaxQuality values are negative; banding must not collapse
    them all into one band and hand the decision to the secondary."""
    spec = as_spec((MAX_QUALITY, MIN_COST))
    good = _cfg(q=0.99, usd=1.0)
    cheap = _cfg(q=0.80, usd=0.5)
    assert spec.key(good) < spec.key(cheap)


def test_degenerate_deadline_budget_rejected():
    with pytest.raises(ValueError, match="positive target"):
        Deadline(s=0)
    with pytest.raises(ValueError, match="positive target"):
        Deadline(s=-5)
    with pytest.raises(ValueError, match="positive usd cap"):
        Budget(usd=0.0)
    with pytest.raises(ValueError, match="at least one"):
        Budget()


def test_constraint_order_round_trips_enum_members():
    """Seed compat: atomic objectives come back as enum members so identity
    and membership checks written against the seed API keep working."""
    job = Job(description="x", constraints=(MIN_LATENCY, MIN_COST))
    assert job.constraint_order == (MIN_LATENCY, MIN_COST)
    assert job.constraint_order[0] is MIN_LATENCY
    assert MIN_COST in job.constraint_order
    # composite DSL terms pass through untouched
    job2 = Job(description="x", constraints=(Deadline(s=5.0), MIN_COST))
    assert job2.constraint_order == (Deadline(s=5.0), MIN_COST)


def test_seeks_quality():
    assert as_spec(MAX_QUALITY).seeks_quality
    assert as_spec((MAX_QUALITY, MIN_COST)).seeks_quality
    assert not as_spec(MIN_COST).seeks_quality
    assert not as_spec((MIN_COST, MAX_QUALITY)).seeks_quality


# -- end-to-end scheduling effects -------------------------------------------


@pytest.fixture()
def system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=16, host_cores=128)


def test_deadline_then_energy_plan(system):
    """Tight deadline forces a faster (more energetic) config than pure
    MIN_ENERGY; loose deadline collapses to the MIN_ENERGY choice."""
    node = _node()
    loose = system.scheduler.plan_task(
        node, Lexicographic(Deadline(s=1e6), MinEnergy()), 0.85)
    pure = system.scheduler.plan_task(node, (MIN_ENERGY,), 0.85)
    assert loose.est_energy_j <= pure.est_energy_j * 1.001
    tight_s = pure.est_latency_s * 0.5
    tight = system.scheduler.plan_task(
        node, Lexicographic(Deadline(s=tight_s), MinEnergy()), 0.85)
    assert tight.est_latency_s <= pure.est_latency_s + 1e-9


def test_budget_caps_spend(system):
    """A budget below the MIN_LATENCY plan's cost trades latency for spend;
    a generous budget collapses to the MIN_LATENCY choice."""
    node = _node()
    fast = system.scheduler.plan_task(node, (MIN_LATENCY,), 0.85)
    capped = system.scheduler.plan_task(
        node, Lexicographic(Budget(usd=fast.est_usd * 0.5), MinLatency()),
        0.85)
    assert capped.est_usd < fast.est_usd
    assert capped.est_latency_s >= fast.est_latency_s - 1e-9
    loose = system.scheduler.plan_task(
        node, Lexicographic(Budget(usd=fast.est_usd * 100), MinLatency()),
        0.85)
    assert loose.est_latency_s <= fast.est_latency_s * 1.001


def test_weighted_matches_primary_at_extreme(system):
    """An all-cost weighted blend picks the same config as MIN_COST."""
    node = _node()
    a = system.scheduler.plan_task(node, (MIN_COST,), 0.85)
    b = system.scheduler.plan_task(node, Weighted.of(cost=1.0), 0.85)
    assert b.est_usd <= a.est_usd * 1.001


def test_job_accepts_dsl_end_to_end(system):
    from repro.core import VideoInput
    job = Job(description="Describe the videos",
              inputs=(VideoInput("v.mov"),),
              constraints=Lexicographic(Deadline(s=3600.0), MinCost()),
              quality_floor=0.0)
    result = job.execute(system)
    assert result.makespan_s > 0 and result.energy_wh > 0


def test_plan_divides_workflow_deadline_across_tasks(system):
    from repro.configs.workflow_video import make_declarative_job
    job = make_declarative_job(Lexicographic(Deadline(s=40.0), MinCost()))
    dag = system.lower(job)
    # per_task sees len(dag)=5 -> 8s per task; verify via spec arithmetic
    spec = job.constraint_spec.per_task(len(dag))
    assert spec.objectives[0] == Deadline(s=8.0)
