"""Open-loop arrival processes (core/arrivals.py, DESIGN.md §8).

Property tests (deterministic hypothesis fallback via _hypothesis_compat):
seeded streams replay identically, Poisson empirical rates land near the
configured rate, MMPP alternates burst/idle regimes, and JSONL traces
round-trip exactly.
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.arrivals import (DEFAULT_TENANT_SHARES, ArrivalEvent,
                                 MMPPArrivals, PoissonArrivals,
                                 ServingPreset, TraceArrivals, default_mix,
                                 register_preset)

MIX = {"video": 0.25, "rag": 0.5, "docingest": 0.25}


def _take(process, n):
    out = []
    for e in process.events():
        out.append(e)
        if len(out) >= n:
            break
    return out


# -- seeded determinism ------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31),
       st.floats(min_value=0.05, max_value=50.0))
def test_poisson_streams_replay_identically(seed, rate):
    p = PoissonArrivals(rate_per_s=rate, mix=MIX, seed=seed)
    assert _take(p, 200) == _take(p, 200)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_mmpp_streams_replay_identically(seed):
    p = MMPPArrivals(rate_on=10.0, rate_off=0.5, mean_on_s=20.0,
                     mean_off_s=60.0, mix=MIX, seed=seed)
    assert _take(p, 200) == _take(p, 200)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_different_seeds_differ(seed):
    a = PoissonArrivals(rate_per_s=1.0, mix=MIX, seed=seed)
    b = PoissonArrivals(rate_per_s=1.0, mix=MIX, seed=seed + 1)
    assert _take(a, 50) != _take(b, 50)


def test_events_time_ordered_and_mix_respected():
    p = PoissonArrivals(rate_per_s=2.0, mix=MIX, seed=7)
    evs = _take(p, 500)
    assert all(a.t <= b.t for a, b in zip(evs, evs[1:]))
    assert {e.scenario for e in evs} == set(MIX)
    assert {e.tenant for e in evs} <= set(DEFAULT_TENANT_SHARES)


# -- rate calibration --------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.floats(min_value=0.2, max_value=20.0),
       st.integers(min_value=0, max_value=1000))
def test_poisson_empirical_rate_matches(rate, seed):
    """n arrivals over [0, t_n] estimate the configured rate; for n=2000
    the relative error of a Poisson-process MLE is ~1/sqrt(n) ≈ 2.2%, so
    a 15% band is ~6 sigma — deterministic seeds keep this stable."""
    n = 2000
    evs = _take(PoissonArrivals(rate_per_s=rate, mix=MIX, seed=seed), n)
    empirical = n / evs[-1].t
    assert empirical == pytest.approx(rate, rel=0.15)


def test_mmpp_long_run_rate_matches_mean_rate():
    p = MMPPArrivals(rate_on=8.0, rate_off=0.5, mean_on_s=30.0,
                     mean_off_s=90.0, mix=MIX, seed=3)
    n = 4000
    evs = _take(p, n)
    assert n / evs[-1].t == pytest.approx(p.mean_rate(), rel=0.2)
    assert p.mean_rate() == pytest.approx(
        (8.0 * 30.0 + 0.5 * 90.0) / 120.0)


def test_mmpp_alternates_burst_and_idle():
    """With rate_off=0 every arrival happens in the on-state, so gaps
    cluster: most are short (within a burst) and some span the whole
    off-dwell — the signature a constant-rate Poisson stream lacks."""
    p = MMPPArrivals(rate_on=10.0, rate_off=0.0, mean_on_s=10.0,
                     mean_off_s=100.0, mix=MIX, seed=11)
    evs = _take(p, 1500)
    gaps = [b.t - a.t for a, b in zip(evs, evs[1:])]
    long_gaps = [g for g in gaps if g > 20.0]    # off-dwell crossings
    short_gaps = [g for g in gaps if g < 1.0]    # in-burst arrivals
    assert long_gaps, "stream never left the burst state"
    assert len(short_gaps) > len(gaps) * 0.8
    # squared coefficient of variation >> 1 marks burstiness (Poisson: 1)
    mean = sum(gaps) / len(gaps)
    var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
    assert var / mean**2 > 2.0


# -- trace replay / JSONL ----------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31),
       st.integers(min_value=1, max_value=200))
def test_jsonl_round_trip_exact(seed, n):
    src = PoissonArrivals(rate_per_s=1.0, mix=MIX, seed=seed)
    trace = TraceArrivals(_take(src, n))
    back = TraceArrivals.from_jsonl(trace.to_jsonl())
    assert list(back.events()) == list(trace.events())
    assert len(back) == n


def test_record_materializes_up_to_horizon():
    src = PoissonArrivals(rate_per_s=2.0, mix=MIX, seed=5)
    trace = TraceArrivals.record(src, horizon_s=50.0)
    assert all(e.t <= 50.0 for e in trace.events())
    # same horizon, same seed -> identical materialization
    again = TraceArrivals.record(
        PoissonArrivals(rate_per_s=2.0, mix=MIX, seed=5), horizon_s=50.0)
    assert list(again.events()) == list(trace.events())


def test_trace_rejects_disorder_and_unknown_tenant():
    with pytest.raises(ValueError, match="time-ordered"):
        TraceArrivals([ArrivalEvent(2.0, "rag"), ArrivalEvent(1.0, "rag")])
    with pytest.raises(ValueError, match="tenant"):
        TraceArrivals([ArrivalEvent(1.0, "rag", tenant="vip")])


# -- validation & presets ----------------------------------------------------

def test_constructor_validation():
    with pytest.raises(ValueError):
        PoissonArrivals(rate_per_s=0.0, mix=MIX)
    with pytest.raises(ValueError):
        PoissonArrivals(rate_per_s=1.0, mix={})
    with pytest.raises(ValueError):
        PoissonArrivals(rate_per_s=1.0, mix={"rag": 0.0})
    with pytest.raises(ValueError, match="tenant"):
        PoissonArrivals(rate_per_s=1.0, mix=MIX,
                        tenant_shares={"platinum": 1.0})
    with pytest.raises(ValueError):
        MMPPArrivals(rate_on=0.0, rate_off=0.1, mean_on_s=1, mean_off_s=1,
                     mix=MIX)
    with pytest.raises(ValueError):
        MMPPArrivals(rate_on=1.0, rate_off=-0.1, mean_on_s=1, mean_off_s=1,
                     mix=MIX)


def test_serving_presets_register_via_configs():
    import repro.configs.workflow_docingest  # noqa: F401
    import repro.configs.workflow_rag  # noqa: F401
    import repro.configs.workflow_video  # noqa: F401
    mix = default_mix()
    assert {"video", "rag", "docingest"} <= set(mix)
    assert all(w > 0 for w in mix.values())


def test_preset_slo_scales_per_class():
    preset = ServingPreset(scenario="x", make_job=lambda: None,
                           base_slo_s=100.0)
    assert preset.slo_for("priority") == pytest.approx(50.0)
    assert preset.slo_for("standard") == pytest.approx(100.0)
    assert preset.slo_for("harvest") == pytest.approx(400.0)
    best_effort = ServingPreset(scenario="y", make_job=lambda: None)
    assert best_effort.slo_for("priority") is None


def test_register_preset_replaces():
    p1 = ServingPreset(scenario="tmp_scenario", make_job=lambda: None,
                       weight=1.0)
    p2 = ServingPreset(scenario="tmp_scenario", make_job=lambda: None,
                       weight=2.0)
    try:
        register_preset(p1)
        register_preset(p2)
        assert default_mix()["tmp_scenario"] == 2.0
    finally:
        from repro.core.arrivals import SERVING_PRESETS
        SERVING_PRESETS.pop("tmp_scenario", None)
