import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

import _hypothesis_compat

_hypothesis_compat.install()     # no-op when the real package is installed

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
