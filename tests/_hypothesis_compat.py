"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The property tests (cluster allocation safety, simulator additivity) declare
strategies via ``hypothesis.given``. The real package is a test dependency
(see pyproject.toml), but this repo must also run in hermetic containers
where installing it isn't possible. ``install()`` registers a minimal
stand-in module that replays each property over a fixed-seed random sample
plus the strategy bounds — deterministic, no shrinking, same test code.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)     # always-tried edge examples

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5, boundary=(False, True))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def composite(fn):
    """``@st.composite``: fn(draw, ...) -> value becomes a strategy factory."""
    def factory(*args, **kw):
        return _Strategy(
            lambda r: fn(lambda s: s.example(r), *args, **kw))
    return factory


def tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda r: tuple(s.example(r) for s in strats))


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) \
        -> _Strategy:
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elem.example(r) for _ in range(n)]
    return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        def wrapper(*args, **kw):
            # @settings may sit above or below @given; check both targets
            n = getattr(wrapper, "_compat_max_examples",
                        getattr(fn, "_compat_max_examples",
                                _DEFAULT_EXAMPLES))
            rnd = random.Random(0)
            cases = []
            if len(strats) == 1 and strats[0].boundary:
                cases += [(b,) for b in strats[0].boundary]
            cases += [tuple(s.example(rnd) for s in strats)
                      for _ in range(n)]
            for case in cases[:n]:        # honor max_examples
                fn(*args, *case, **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def install():
    """Register the stand-in as ``hypothesis`` if the real one is absent."""
    try:
        import hypothesis                              # noqa: F401
        return
    except ModuleNotFoundError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.booleans = booleans
    strategies.floats = floats
    strategies.tuples = tuples
    strategies.lists = lists
    strategies.composite = composite
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
