"""Per-kernel validation: shape/dtype sweeps, Pallas interpret mode vs the
pure-jnp oracle in ``kernels/ref.py`` (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import gmm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _qkv(key, B, Sq, Sk, H, KVH, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, H, D), dtype)
    k = jax.random.normal(k2, (B, Sk, KVH, D), dtype)
    v = jax.random.normal(k3, (B, Sk, KVH, D), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Sk,H,KVH,D", [
        (1, 128, 128, 4, 4, 64),     # MHA
        (2, 128, 128, 4, 2, 64),     # GQA 2:1
        (1, 256, 256, 8, 1, 32),     # MQA
        (1, 100, 100, 4, 2, 64),     # ragged (padding path)
        (1, 64, 192, 2, 2, 128),     # cross lengths
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_naive(self, B, Sq, Sk, H, KVH, D, dtype):
        q, k, v = _qkv(jax.random.PRNGKey(0), B, Sq, Sk, H, KVH, D, dtype)
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                     block_q=64, block_k=64)
        want = ref.mha_naive(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL[dtype], rtol=TOL[dtype])

    @pytest.mark.parametrize("window", [0, 32])
    @pytest.mark.parametrize("softcap", [0.0, 20.0])
    def test_window_softcap(self, window, softcap):
        q, k, v = _qkv(jax.random.PRNGKey(1), 1, 128, 128, 4, 2, 64,
                       jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     softcap=softcap, interpret=True,
                                     block_q=64, block_k=64)
        want = ref.mha_naive(q, k, v, causal=True, window=window,
                             logit_softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_kv_valid_mask(self):
        """Decode-style: only the first kv_valid cache entries count."""
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 1, 256, 4, 2, 64,
                       jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, q_offset=99,
                                     kv_valid=100, interpret=True)
        want = ref.mha_naive(q[:, :1], k[:, :100], v[:, :100], causal=True,
                             q_offset=99)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_chunked_ref_equals_naive(self):
        """The CPU execution path (mha_chunked) is the oracle's twin."""
        q, k, v = _qkv(jax.random.PRNGKey(3), 2, 96, 96, 4, 2, 32,
                       jnp.float32)
        got = ref.mha_chunked(q, k, v, causal=True, block_k=32)
        want = ref.mha_naive(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


class TestSSDScan:
    @pytest.mark.parametrize("B,L,nh,P,N,G,chunk", [
        (1, 64, 2, 16, 16, 1, 16),
        (2, 128, 4, 32, 16, 2, 32),
        (1, 96, 2, 16, 32, 1, 32),     # L not multiple of chunk handled above
    ])
    def test_vs_ref(self, B, L, nh, P, N, G, chunk):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, L, nh, P)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
        a_log = jnp.ones((nh,)) * 0.5
        b = jax.random.normal(ks[2], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        d_skip = jax.random.normal(ks[4], (nh,))
        y_p, st_p = ssd_scan_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk,
                                    interpret=True)
        y_r, st_r = ref.ssd_chunked(x, dt, a_log, b, c, d_skip,
                                    chunk_size=chunk)
        np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(st_p), np.asarray(st_r),
                                   atol=1e-4, rtol=1e-4)

    def test_decode_step_matches_scan(self):
        """Stepwise recurrent decode == chunked scan on the same sequence."""
        B, L, nh, P, N, G = 1, 32, 2, 16, 16, 1
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, L, nh, P)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
        a_log = jnp.ones((nh,)) * 0.5
        b = jax.random.normal(ks[2], (B, L, G, N)) * 0.3
        c = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
        d_skip = jax.random.normal(ks[4], (nh,))
        y_scan, st_scan = ref.ssd_chunked(x, dt, a_log, b, c, d_skip,
                                          chunk_size=16)
        state = jnp.zeros((B, nh, P, N))
        ys = []
        for t in range(L):
            y_t, state = ref.ssd_decode_step(
                state, x[:, t], dt[:, t], a_log, b[:, t], c[:, t], d_skip)
            ys.append(y_t)
        y_step = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(st_scan),
                                   atol=1e-4, rtol=1e-4)


class TestGMM:
    @pytest.mark.parametrize("E,C,d,f", [
        (2, 16, 32, 64), (8, 64, 128, 64), (4, 8, 256, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_naive(self, E, C, d, f, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (E, C, d), dtype)
        w = jax.random.normal(k2, (E, d, f), dtype)
        got = gmm_pallas(x, w, interpret=True)
        want = ref.gmm_naive(x, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=TOL[dtype] * d ** 0.5,
                                   rtol=TOL[dtype])


class TestOpsDispatch:
    def test_decode_attention_matches_flash(self):
        """The GEMV decode path == flash over the valid prefix."""
        B, Sk, H, KVH, D = 2, 64, 4, 2, 32
        q, k, v = _qkv(jax.random.PRNGKey(5), B, 1, Sk, H, KVH, D,
                       jnp.float32)
        idx = 40
        got = ops.decode_attention(q, k, v, q_offset=idx, kv_len=idx + 1)
        want = ref.mha_naive(q, k[:, :idx + 1], v[:, :idx + 1], causal=True,
                             q_offset=idx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
