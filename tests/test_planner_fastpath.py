"""Planner fast path (DESIGN.md §7): dominated-config pruning never moves
the chosen plan, the estimate memo is transparent, the admission plan cache
reuses plans only for identical (workflow, constraints, cluster-state)
triples, and the pinned-count device filter respects max_devices."""
import pytest

from repro.core import (MAX_QUALITY, MIN_COST, MIN_ENERGY, MIN_LATENCY,
                        Murakkab)
from repro.core.dag import TaskNode
from repro.configs.workflow_docingest import make_docingest_job
from repro.configs.workflow_rag import make_rag_job
from repro.configs.workflow_video import make_declarative_job

ALL_JOBS = (make_declarative_job, make_rag_job, make_docingest_job)


def _system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=16,
                                host_cores=128)


@pytest.mark.parametrize("constraint",
                         [MIN_COST, MIN_ENERGY, MIN_LATENCY, MAX_QUALITY])
def test_pruning_never_changes_the_plan(constraint):
    """Sound pruning: identical configs with strictly fewer estimate()
    evaluations, across every scenario and objective."""
    for make_job in ALL_JOBS:
        job = make_job(constraint)
        ref, fast = _system(), _system()
        ref.scheduler.prune = False
        _, p_ref = ref.plan(job)
        _, p_fast = fast.plan(job)
        assert p_ref.configs == p_fast.configs, make_job.__name__
        assert fast.scheduler.evals < ref.scheduler.evals
        assert fast.scheduler.pruned > 0


def test_estimate_cache_transparent_and_counted():
    system = _system()
    job = make_rag_job(MIN_LATENCY)
    _, p1 = system.plan(job)
    assert system.profiles.cache_info()["misses"] > 0
    hits_before = system.profiles.cache_info()["hits"]
    _, p2 = system.plan(job)
    assert p1.configs == p2.configs
    assert system.profiles.cache_info()["hits"] > hits_before
    # disabling the cache still yields the same plan
    system.profiles.cache_reset(enabled=False)
    _, p3 = system.plan(job)
    assert p3.configs == p1.configs
    assert system.profiles.cache_info()["hits"] == 0


def test_pin_invalidates_estimate_cache():
    system = _system()
    impl = system.library.impls["gemma2-9b"]
    work = impl.work_fn(900, 120)
    from repro.core import CATALOG
    from repro.core.profiles import CostQuery
    q = CostQuery(impl=impl, spec=CATALOG["tpu-v5e"], n_devices=1, work=work)
    before = system.profiles.step_latency(q)
    system.profiles.pin("gemma2-9b", "tpu-v5e", 1, before * 10)
    assert system.profiles.step_latency(q) == \
        pytest.approx(before * 10)


def test_plan_cache_hits_on_identical_admission():
    """Same DAG shape + constraints + pristine cluster => cached plan, as
    a private copy the simulator may mutate."""
    system = _system()
    job = make_docingest_job(MIN_COST)
    dag = system.lower(job)
    p1 = system.plan_admitted(dag, job)
    assert (system.plan_cache_hits, system.plan_cache_misses) == (0, 1)
    p2 = system.plan_admitted(dag, job)
    assert (system.plan_cache_hits, system.plan_cache_misses) == (1, 1)
    assert p2.configs == p1.configs
    assert p2 is not p1 and p2.configs is not p1.configs


def test_plan_cache_misses_on_changed_key():
    system = _system()
    job = make_docingest_job(MIN_COST)
    dag = system.lower(job)
    system.plan_admitted(dag, job)
    # different constraints -> miss
    system.plan_admitted(dag, make_docingest_job(MIN_LATENCY))
    assert system.plan_cache_misses == 2
    # changed cluster state (devices held) -> miss
    system.cluster.alloc("v5e", 8, t=0.0)
    system.plan_admitted(dag, job)
    assert system.plan_cache_misses == 3


def test_execute_many_reuses_plans_for_simultaneous_tenants():
    """Identical tenants admitted at the same instant see the same cluster
    digest (same-time events drain before dispatch), so every tenant after
    the first reuses the cached plan instead of re-searching."""
    system = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                  host_cores=32)
    report = system.execute_many({
        f"t{i}": (make_docingest_job(MIN_LATENCY), 0.0) for i in range(4)
    })
    assert len(report.per_workflow) == 4
    assert system.plan_cache_misses == 1
    assert system.plan_cache_hits == 3
    assert all(v["finish"] > 0 for v in report.per_workflow.values())


def test_pin_invalidates_plan_cache():
    """Calibration after planning must not resurrect a stale cached plan:
    pin() bumps ProfileStore.version, which is part of the plan-cache key."""
    system = _system()
    job = make_docingest_job(MIN_COST)
    dag = system.lower(job)
    p1 = system.plan_admitted(dag, job)
    digest = next(tid for tid in dag.topo_order
                  if dag.nodes[tid].agent == "digest")
    # make the previously-chosen digest config measurably terrible
    cfg = p1.configs[digest]
    device = system.cluster.pools[cfg.pool].device
    system.profiles.pin(cfg.impl, device, cfg.n_devices, 500.0)
    p2 = system.plan_admitted(dag, job)
    assert system.plan_cache_hits == 0      # key changed: no stale hit
    assert p2.configs != p1.configs


def test_dag_signature_identity():
    system = _system()
    job = make_rag_job()
    d1, d2 = system.lower(job), system.lower(job)
    assert d1.signature() == d2.signature()
    other = system.lower(make_docingest_job())
    assert other.signature() != d1.signature()


def test_cluster_digest_tracks_planner_visible_state():
    system = _system()
    d0 = system.cluster.digest()
    lease = system.cluster.alloc("v5e", 4, t=0.0)
    assert system.cluster.digest() != d0
    system.cluster.release(lease, t=1.0)
    assert system.cluster.digest() == d0


def _recomputed_digest(cluster):
    """Force the uncached path: drop the memo, recompute, restore."""
    memo = cluster._digest
    cluster._digest = None
    fresh = cluster.digest()
    cluster._digest = memo
    return fresh


def test_digest_cache_byte_identical_to_recompute():
    """Satellite: the dirty-flag memo must equal a from-scratch recompute
    after every mutation class that can touch planner-visible state —
    alloc, release, instance add/evict, capacity resize, preemption."""
    from repro.core.cluster import Instance
    system = _system()
    cluster = system.cluster
    assert cluster.digest() == _recomputed_digest(cluster)

    lease = cluster.alloc("v5e", 4, t=0.0)
    assert cluster.digest() == _recomputed_digest(cluster)

    inst = Instance("gemma2-9b", "v5e", 4, lease=lease)
    cluster.add_instance(inst)
    assert cluster.digest() == _recomputed_digest(cluster)

    cluster.set_capacity("v4_harvest", 8, t=1.0)
    assert cluster.digest() == _recomputed_digest(cluster)

    h = cluster.alloc("v4_harvest", 4, t=2.0, harvest=True)
    assert cluster.digest() == _recomputed_digest(cluster)
    assert cluster.preempt_harvest("v4_harvest", 4, t=3.0)
    assert h.id not in cluster._leases
    assert cluster.digest() == _recomputed_digest(cluster)

    cluster.evict_instance(inst, t=4.0)     # also releases the lease
    assert cluster.digest() == _recomputed_digest(cluster)


def test_digest_cached_object_reused_between_reads():
    """No mutation between two reads ⟹ the same memoized tuple comes back
    (identity, not just equality — the cache actually short-circuits)."""
    system = _system()
    cluster = system.cluster
    cluster.alloc("v5e", 2, t=0.0)
    d1 = cluster.digest()
    d2 = cluster.digest()
    assert d1 is d2


def test_pinned_counts_respect_max_devices():
    """Satellite fix: a calibration point above impl.max_devices must not
    become selectable — the filter caps at hi = min(max_devices, cap)."""
    system = Murakkab.paper_cluster()
    # whisper-large caps at 64 CPU cores; pin an (absurdly fast) 128-core
    # row — the old `lo <= n <= cap` filter would have selected it.
    system.profiles.pin("whisper-large", "epyc-7v12-core", 128, 0.001)
    node = TaskNode(id="t", description="", agent="speech_to_text",
                    work_items=8, chunkable=True)
    cfg = system.scheduler.plan_task(node, (MIN_COST,),
                                     {"speech_to_text": 0.97})
    max_cpu = system.library.impls["whisper-large"].max_devices["cpu"]
    assert cfg.n_devices <= max_cpu
