"""Model-level correctness: KV-cache decode == full forward, RoPE/norm
properties, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import moe as moe_mod
from repro.models.common import apply_rope, rms_norm, softcap
from repro.models.model_zoo import build_model
from repro.runtime import serve as serve_rt

# bf16 params + bf16 P in the decode GEMV (§Perf A1: avoids the hoisted
# fp32 full-cache copy). Max observed logit delta ~0.04 on ~10-magnitude
# logits; greedy argmax is unaffected (asserted in serve smoke tests).
DECODE_TOL = 6e-2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_equals_forward(arch):
    """Prefill(S-1) + decode(1) logits == full forward at the last position.

    This is the KV-cache/SSM-state correctness proof per architecture."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 17
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    extras = model.extra_inputs(B, S - 1)
    logits_full, _, _ = model.apply(
        params, {"tokens": toks, **model.extra_inputs(B, S)}, mode="train")

    enc_len = model.enc_len_for(S - 1)
    cache = model.init_cache(B, S + 2, enc_len=enc_len)
    prefill = serve_rt.build_prefill_step(model, serve_rt.ServeOptions())
    _, cache = prefill(params, {"tokens": toks[:, :S - 1], **extras}, cache)
    decode = serve_rt.build_decode_step(model, serve_rt.ServeOptions())
    _, last, _ = decode(params, cache, toks[:, S - 1:S],
                        jnp.asarray(S - 1, jnp.int32))
    if cfg.family == "encdec":
        # decode sees the encoder KV of the S-1 prefill; compare against a
        # full forward with the same encoder inputs
        logits_full, _, _ = model.apply(
            params, {"tokens": toks, **model.extra_inputs(B, S - 1)},
            mode="train")
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, -1]),
                               atol=DECODE_TOL, rtol=DECODE_TOL)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on i - j (orthogonal rotation)."""
    D = 64
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]))
        kj = apply_rope(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(0, 0) - float(jnp.sum(q * k))) < 1e-4


def test_rope_partial_rotation():
    """stablelm-style rope_pct rotates only a prefix of head_dim."""
    D = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, D))
    y = apply_rope(x, jnp.arange(4)[None], rope_pct=0.25)
    rot = int(D * 0.25)
    np.testing.assert_array_equal(np.asarray(y[..., rot:]),
                                  np.asarray(x[..., rot:]))
    assert not np.allclose(np.asarray(y[..., 1, :, :rot]),
                           np.asarray(x[..., 1, :, :rot]))


def test_rms_norm_scale_invariance():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32))
    y1 = rms_norm(x, jnp.ones(32))
    y2 = rms_norm(x * 100.0, jnp.ones(32))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    # unit RMS out
    rms = jnp.sqrt(jnp.mean(jnp.square(y1), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


@given(st.floats(1.0, 100.0), st.floats(-1e4, 1e4))
@settings(max_examples=50, deadline=None)
def test_softcap_bounds(cap, v):
    out = float(softcap(jnp.asarray(v), cap))
    assert abs(out) <= cap * 1.0001
    if abs(v) < cap / 10:           # ~identity in the linear region
        assert abs(out - v) < abs(v) * 0.05 + 1e-6


class TestMoE:
    def _setup(self, T=64):
        cfg = get_config("deepseek-moe-16b", reduced=True)
        key = jax.random.PRNGKey(0)
        from repro.models.moe import moe_specs
        from repro.models.common import init_params
        p = init_params(moe_specs(cfg), key, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                              jnp.float32)
        return cfg, p, x

    def test_router_topk_weights_normalized(self):
        cfg, p, x = self._setup()
        idx, w, aux = moe_mod._route(x, p["router"], cfg)
        assert idx.shape == (64, cfg.moe.top_k)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-3)
        assert float(aux) > 0

    def test_dispatch_preserves_tokens(self):
        """Sort-based dispatch: every kept assignment lands in exactly one
        slot, dropped slots point at the padding token."""
        cfg, p, x = self._setup()
        T = x.shape[0]
        E, k = cfg.moe.num_experts, cfg.moe.top_k
        C = moe_mod._capacity(T, cfg)
        idx, w, _ = moe_mod._route(x, p["router"], cfg)
        gather_idx, inv = moe_mod._dispatch_indices(idx, E, C)
        assert gather_idx.shape == (E, C)
        assert bool(jnp.all((gather_idx >= 0) & (gather_idx <= T)))
        # every token index in a slot belongs to a real routed assignment
        routed = set()
        idx_np = np.asarray(idx)
        for t in range(T):
            for e in idx_np[t]:
                routed.add((int(e), t))
        for e in range(E):
            for c in range(C):
                tok = int(np.asarray(gather_idx)[e, c])
                if tok < T:
                    assert (e, tok) in routed

    def test_local_moe_finite_and_shaped(self):
        cfg, p, x = self._setup()
        out, aux = moe_mod._moe_local(x, p, cfg)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_high_capacity_matches_dense_compute(self):
        """With capacity >> needed, MoE == explicit per-token expert sum."""
        cfg, p, x = self._setup(T=16)
        cfg = cfg.replace(moe=cfg.moe.__class__(
            **{**cfg.moe.__dict__, "capacity_factor": 64.0}))
        out, _ = moe_mod._moe_local(x, p, cfg)
        idx, w, _ = moe_mod._route(x, p["router"], cfg)
        act = jax.nn.silu
        want = jnp.zeros_like(x)
        for t in range(16):
            acc = jnp.zeros((cfg.d_model,), jnp.float32)
            for j in range(cfg.moe.top_k):
                e = int(idx[t, j])
                h = act(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
                acc += float(w[t, j]) * (h @ p["w_down"][e])
            want = want.at[t].set(acc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=5e-3, rtol=5e-3)
