"""Scheduler: greedy hierarchical search vs brute force, constraint
semantics, lever behavior."""

import pytest

from repro.core import (MAX_QUALITY, MIN_COST, MIN_ENERGY, MIN_LATENCY,
                        Murakkab)
from repro.core.dag import TaskNode
from repro.core.scheduler import _pow2_range
from repro.configs.workflow_video import make_declarative_job


def _node(agent="summarize", items=8, tin=900, tout=120):
    return TaskNode(id="t", description="", agent=agent, work_items=items,
                    chunkable=True, tokens_in=tin, tokens_out=tout)


@pytest.fixture()
def system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=16, host_cores=128)


def _brute_force(system, node, order, floor):
    """Enumerate the full lever cross-product, return the best config."""
    sch = system.scheduler
    best = None
    for impl in system.library.impls_for(node.agent):
        if impl.quality < floor:
            continue
        for pool_name, pool in system.cluster.pools.items():
            kind = pool.spec.kind
            if kind not in impl.hw_kinds:
                continue
            lo = impl.min_devices.get(kind, 1)
            hi = min(impl.max_devices.get(kind, pool.capacity), pool.capacity)
            if lo > hi:
                continue
            for n in _pow2_range(lo, hi):
                for ni in _pow2_range(1, node.work_items):
                    if n * ni > pool.capacity:
                        continue
                    for b in _pow2_range(1, impl.max_batch):
                        cfg = sch.estimate(node, impl, pool_name, n, ni, b)
                        if best is None or sch._key(cfg, order) < \
                                sch._key(best, order):
                            best = cfg
    return best


@pytest.mark.parametrize("constraint", [MIN_COST, MIN_ENERGY, MIN_LATENCY])
def test_greedy_close_to_bruteforce(system, constraint):
    """Greedy result within 25% of the exhaustive optimum on the primary
    objective (it's a heuristic — the paper prunes, we quantify the gap)."""
    node = _node()
    order = (constraint,)
    greedy = system.scheduler.plan_task(node, order, quality_floor=0.85)
    brute = _brute_force(system, node, order, 0.85)
    obj = system.scheduler._objective
    g, b = obj(greedy, constraint), obj(brute, constraint)
    assert g <= b * 1.25 + 1e-9, (g, b)


def test_quality_floor_honored(system):
    node = _node()
    plan = system.scheduler.plan_task(node, (MIN_COST,), quality_floor=0.95)
    assert system.library.impls[plan.impl].quality >= 0.95
    plan2 = system.scheduler.plan_task(node, (MIN_COST,), quality_floor=0.0)
    assert plan2.est_usd <= plan.est_usd + 1e-12   # relaxing floor can't cost


def test_max_quality_uses_paths_on_harvest(system):
    node = _node(items=1)
    cfg = system.scheduler.plan_task(node, (MAX_QUALITY,), quality_floor=0.0)
    best_q = max(i.quality for i in system.library.impls_for("summarize"))
    assert cfg.quality >= best_q          # paths can only raise quality


def test_min_latency_fans_out(system):
    node = _node(items=16)
    lat_c = system.scheduler.plan_task(node, (MIN_LATENCY,), 0.85)
    one = system.scheduler.estimate(
        node, system.library.impls[lat_c.impl], lat_c.pool, lat_c.n_devices)
    assert lat_c.est_latency_s <= one.est_latency_s
    assert lat_c.n_instances > 1 or lat_c.batch > 1


def test_constraint_priority_ordering(system):
    """(MIN_LATENCY, MIN_COST) breaks latency near-ties by cost."""
    node = _node()
    primary = system.scheduler.plan_task(node, (MIN_LATENCY,), 0.85)
    chained = system.scheduler.plan_task(node, (MIN_LATENCY, MIN_COST), 0.85)
    # chained may give up <=5% latency for cheaper $
    assert chained.est_latency_s <= primary.est_latency_s * 1.06
    assert chained.est_usd <= primary.est_usd * 1.001


def test_cpu_batch_is_ignored(system):
    node = _node(agent="speech_to_text", tin=0, tout=0)
    impl = system.library.impls["whisper-large"]
    cfg = system.scheduler.estimate(node, impl, "cpu", 64, batch=4)
    assert cfg.batch == 1


def test_pinned_counts_restrict_menu():
    system = Murakkab.paper_cluster()     # pins whisper cpu@64, gpu@1
    node = _node(agent="speech_to_text", tin=0, tout=0)
    cfg = system.scheduler.plan_task(node, (MIN_COST,),
                                     {"speech_to_text": 0.97})
    assert (cfg.pool, cfg.n_devices) in {("cpu", 64), ("gpu", 1)}


def test_estimate_scaling_sanity(system):
    """More devices: latency non-increasing; energy/cost non-decreasing-ish."""
    node = _node(items=1)
    impl = system.library.impls["deepseek-7b"]
    prev = None
    for n in (1, 2, 4, 8, 16):
        cfg = system.scheduler.estimate(node, impl, "v5e", n)
        if prev is not None:
            assert cfg.est_latency_s <= prev.est_latency_s * 1.001
        prev = cfg


def test_search_space_vs_visited(system):
    job = make_declarative_job()
    dag = system.lower(job)
    full = sum(system.scheduler.search_space_size(dag.nodes[t]) for t in dag)
    system.scheduler.evals = 0
    system.scheduler.plan(dag, (MIN_COST,), 0.85)
    assert system.scheduler.evals * 10 < full     # >=10x pruning
