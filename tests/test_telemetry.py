"""Telemetry store + offline evaluator (core/telemetry, DESIGN.md §11).

The load-bearing properties: the log round-trips exactly through JSONL,
the evaluator's weight update is a pure function of the log (same log ->
same weights, always), and the simulator's records price each task exactly
as the energy ledger charged it.
"""
import pytest

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.configs.workflow_rag import ROUTED_QUERIES, make_rag_job
from repro.core import (Murakkab, OfflineEvaluator, Router, TaskRecord,
                        TelemetryStore, featurize)
from repro.core.dag import TaskNode


def _rec(impl: str, text: str, quality: float, usd: float,
         interface: str = "retrieve", energy: float = 0.0,
         t: float = 1.0) -> TaskRecord:
    return TaskRecord(t=t, workflow="w", task="t", interface=interface,
                      impl=impl, pool="p", features=featurize(text),
                      latency_s=0.5, energy_j=energy, usd=usd,
                      quality=quality)


LOOKUP = "10-K 2024 item 1A filing"
SEMANTIC = "how does management describe margin pressure over time"


# -- record + store basics ----------------------------------------------------

def test_jsonl_round_trip_exact():
    store = TelemetryStore()
    store.log(_rec("a", LOOKUP, 0.9, 0.01))
    store.log(_rec("b", SEMANTIC, 0.7, 0.02, interface="synthesize",
                   energy=3.5))
    text = store.to_jsonl()
    back = TelemetryStore.from_jsonl(text)
    assert back.records == store.records
    assert back.to_jsonl() == text          # idempotent
    assert TelemetryStore().to_jsonl() == ""


def test_observe_grades_with_quality_model():
    node = TaskNode(id="t0", description="", agent="retrieve",
                    args={"query": LOOKUP})
    plain = TelemetryStore()
    rec = plain.observe(t=1.0, workflow="w", task="t0", node=node,
                        interface="retrieve", impl="bm25", pool="cpu",
                        latency_s=0.5, energy_j=0.0, usd=0.001,
                        declared_quality=0.82)
    assert rec.quality == 0.82              # defaults to declared

    graded = TelemetryStore(quality_model=lambda f, impl, q: 0.5)
    rec2 = graded.observe(t=1.0, workflow="w", task="t0", node=node,
                          interface="retrieve", impl="bm25", pool="cpu",
                          latency_s=0.5, energy_j=0.0, usd=0.001,
                          declared_quality=0.82)
    assert rec2.quality == 0.5
    # both saw the same features the router would
    assert rec2.features == rec.features == featurize(LOOKUP)


def test_attainment_and_mean_quality():
    store = TelemetryStore()
    assert store.attainment("retrieve", 0.85) == 1.0     # no evidence
    store.log(_rec("a", LOOKUP, 0.9, 0.01))
    store.log(_rec("a", SEMANTIC, 0.7, 0.01))
    store.log(_rec("b", SEMANTIC, 0.95, 0.02))
    assert store.attainment("retrieve", 0.85) == pytest.approx(2 / 3)
    assert store.by_interface("retrieve") == store.records
    mq = store.mean_quality()
    assert mq["a"] == pytest.approx(0.8)
    assert mq["b"] == pytest.approx(0.95)
    # min_count refuses single-sample calibration
    assert "b" not in store.mean_quality(min_count=2)


# -- evaluator purity ---------------------------------------------------------

def test_rewards_pure_function_of_log():
    store = TelemetryStore()
    for q, usd in ((0.9, 0.01), (0.85, 0.012), (0.7, 0.002)):
        store.log(_rec("cheap", SEMANTIC, q - 0.1, usd / 2))
        store.log(_rec("good", SEMANTIC, q, usd))
    ev = OfflineEvaluator(quality_target=0.85, cost_weight=0.1,
                          cost_key="usd")
    w1 = ev.rewards(store)
    w2 = ev.rewards(store)
    w3 = ev.rewards(TelemetryStore.from_jsonl(store.to_jsonl()))
    assert w1 == w2 == w3
    # replaying the same log through update yields identical routers
    r = Router(interfaces=("retrieve",), epsilon=0.0, seed=7)
    assert ev.update(r, store).weights == ev.update(r, store).weights
    # ...and never mutates the input router (frozen weights)
    with pytest.raises(TypeError):
        r.weights[("retrieve", "x")] = {}


def _two_phase_drift_log() -> TelemetryStore:
    """Phase 1 (t <= 100): ``drift-arm`` is excellent, 18 records deep.
    Phase 2 (t ~ 1000): it regressed hard; ``steady-arm`` never moved."""
    store = TelemetryStore()
    for i in range(18):
        store.log(_rec("drift-arm", LOOKUP, 0.95, 0.01, t=5.0 * i))
    for i in range(2):
        store.log(_rec("drift-arm", LOOKUP, 0.40, 0.01, t=1000.0 + 10 * i))
    for t in (10.0, 60.0, 1000.0, 1010.0):
        store.log(_rec("steady-arm", LOOKUP, 0.80, 0.01, t=t))
    return store


def test_half_life_decay_tracks_drift():
    """The two-phase drift property: a lifetime mean is dominated by the
    stale majority and keeps preferring the regressed arm; a half-life
    evaluator forgets phase 1 and flips to the arm that still works."""
    store = _two_phase_drift_log()
    bucket = ("retrieve", featurize(LOOKUP).bucket())
    lifetime = OfflineEvaluator(cost_weight=0.0).rewards(store)[bucket]
    decayed = OfflineEvaluator(cost_weight=0.0,
                               half_life_s=100.0).rewards(store)[bucket]
    assert lifetime["drift-arm"] > lifetime["steady-arm"]    # the bug
    assert decayed["drift-arm"] < decayed["steady-arm"]      # the fix
    # phase 2 is what the decayed estimate converges toward
    assert decayed["drift-arm"] == pytest.approx(0.40 / 0.85, abs=0.05)


def test_window_drops_stale_records_outright():
    """The hard-cutoff variant: only phase 2 survives a 50 s window."""
    store = _two_phase_drift_log()
    bucket = ("retrieve", featurize(LOOKUP).bucket())
    windowed = OfflineEvaluator(cost_weight=0.0,
                                window_s=50.0).rewards(store)[bucket]
    assert windowed["drift-arm"] == pytest.approx(0.40 / 0.85)
    assert windowed["steady-arm"] == pytest.approx(0.80 / 0.85)


def test_decay_off_reproduces_the_lifetime_mean_exactly():
    """Defaults (no half-life, no window) are the legacy aggregation,
    bitwise: unit age-weights multiply through as exact identities."""
    store = _two_phase_drift_log()
    bucket = ("retrieve", featurize(LOOKUP).bucket())
    got = OfflineEvaluator(cost_weight=0.0).rewards(store)[bucket]
    drift = [min(r.quality / 0.85, 1.0) for r in store.records
             if r.impl == "drift-arm"]
    assert got["drift-arm"] == sum(drift) / len(drift)
    with pytest.raises(ValueError, match="half_life_s"):
        OfflineEvaluator(half_life_s=0.0)
    with pytest.raises(ValueError, match="window_s"):
        OfflineEvaluator(window_s=-1.0)


def test_two_arm_convergence_smoke():
    """Synthetic two-arm workload: the cheap arm attains the target on
    lookup-shaped queries only; one update routes each bucket right."""
    store = TelemetryStore()
    for i in range(6):
        store.log(_rec("cheap-arm", LOOKUP, 0.93, 0.001))
        store.log(_rec("good-arm", LOOKUP, 0.92, 0.010))
        store.log(_rec("cheap-arm", SEMANTIC, 0.65, 0.001))
        store.log(_rec("good-arm", SEMANTIC, 0.92, 0.010))
    ev = OfflineEvaluator(quality_target=0.85, cost_weight=0.05,
                          cost_key="usd")
    trained = ev.update(Router(interfaces=("retrieve",), epsilon=0.0,
                               seed=0), store)
    arms = ["cheap-arm", "good-arm"]

    def node(text):
        return TaskNode(id="t0", description="", agent="retrieve",
                        args={"query": text})

    assert trained.route(node(LOOKUP), arms) == "cheap-arm"
    assert trained.route(node(SEMANTIC), arms) == "good-arm"
    assert trained.version == 1
    assert trained.weight_churn(Router(interfaces=("retrieve",))) > 0


def test_calibrate_profiles_pins_measured_quality():
    system = Murakkab.tpu_cluster()
    store = TelemetryStore()
    for _ in range(3):
        store.log(_rec("gemma2-9b-synth", SEMANTIC, 0.93, 0.01,
                       interface="synthesize"))
    store.log(_rec("deepseek-7b-synth", SEMANTIC, 0.80, 0.01,
                   interface="synthesize"))    # below min_count: no pin
    v0 = system.profiles.version
    pins = OfflineEvaluator().calibrate_profiles(store, system.profiles,
                                                 min_count=3)
    assert pins == {"gemma2-9b-synth": pytest.approx(0.93)}
    assert system.profiles.quality("gemma2-9b-synth") == pytest.approx(0.93)
    assert system.profiles.quality("deepseek-7b-synth") == \
        system.library.impls["deepseek-7b-synth"].quality
    assert system.profiles.version > v0     # plan caches invalidate


# -- simulator logging --------------------------------------------------------

def test_simulator_records_match_trace_and_ledger():
    tele = TelemetryStore()
    system = Murakkab.paper_cluster(telemetry=tele)
    res = system.execute(make_rag_job())
    assert len(tele.records) == len(res.sim.trace)
    by_task = {r.task: r for r in tele.records}
    for entry in res.sim.trace:
        rec = by_task[entry.task]
        assert (rec.impl, rec.pool) == (entry.impl, entry.pool)
        assert rec.latency_s == pytest.approx(entry.end - entry.start)
    # records price exactly what the ledger charged (clean run: no refunds)
    total_j = sum(r.energy_j for r in tele.records)
    assert total_j / 3600.0 == pytest.approx(res.sim.active_wh, rel=1e-9)
    # with no quality model every record attains its planned quality
    for rec in tele.records:
        assert rec.quality == res.plan[rec.task].quality
        assert rec.routed is False


def test_telemetry_store_never_influences_the_run():
    stock = Murakkab.paper_cluster().execute(make_rag_job())
    logged = Murakkab.paper_cluster(
        telemetry=TelemetryStore()).execute(make_rag_job())
    assert logged.sim.trace == stock.sim.trace
    assert logged.energy_wh == stock.energy_wh
    assert logged.usd == stock.usd
    assert logged.plan.configs == stock.plan.configs


def test_routed_flag_stamped_per_interface():
    tele = TelemetryStore()
    system = Murakkab.paper_cluster(
        router=Router(interfaces=("retrieve",), epsilon=1.0, seed=5),
        telemetry=tele)
    system.execute(make_rag_job(queries=ROUTED_QUERIES[:1]))
    flags = {r.interface: r.routed for r in tele.records}
    assert flags["retrieve"] is True
    assert all(v is False for k, v in flags.items() if k != "retrieve")
