"""Fault tolerance: restart driver, stragglers, elastic remesh
(+ hypothesis on remesh-plan validity)."""
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpointing.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.models.model_zoo import build_model
from repro.runtime import train as train_rt
from repro.runtime.fault_tolerance import (RestartPolicy, StragglerMonitor,
                                           plan_remesh, run_with_restarts)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-7b", reduced=True)
    model = build_model(cfg)
    opts = train_rt.TrainOptions(remat_policy=None, warmup_steps=1,
                                 total_steps=30)
    step = jax.jit(train_rt.build_train_step(model, opts))
    return cfg, model, opts, step


def test_restart_replays_to_identical_state(setup, tmp_path):
    """A failure-riddled run ends bit-identical to a clean run (determinism
    of the data pipeline + checkpoint restore)."""
    cfg, model, opts, step = setup

    def run(inject):
        mgr = CheckpointManager(str(tmp_path / f"ck{inject}"),
                                async_save=False)
        state = train_rt.init_train_state(model, jax.random.PRNGKey(0), opts)
        data = DataIterator(DataConfig(cfg.vocab_size, 16, 4), model_cfg=cfg)
        injected = {6, 11} if inject else set()

        def hook(s):
            if s in injected:
                injected.discard(s)
                raise RuntimeError("boom")

        state, hist, fails = run_with_restarts(
            num_steps=15, state=state, data_iter=data, step_fn=step,
            ckpt_manager=mgr, save_every=5,
            policy=RestartPolicy(max_failures=4), fail_hook=hook)
        return state, fails

    clean, f0 = run(False)
    faulty, f1 = run(True)
    assert f0 == 0 and f1 == 2
    import numpy as np
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_gives_up_after_policy(setup, tmp_path):
    cfg, model, opts, step = setup
    mgr = CheckpointManager(str(tmp_path / "give_up"), async_save=False)
    state = train_rt.init_train_state(model, jax.random.PRNGKey(0), opts)
    data = DataIterator(DataConfig(cfg.vocab_size, 16, 4), model_cfg=cfg)

    def always_fail(s):
        if s >= 3:
            raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="persistent"):
        run_with_restarts(num_steps=10, state=state, data_iter=data,
                          step_fn=step, ckpt_manager=mgr, save_every=2,
                          policy=RestartPolicy(max_failures=2),
                          fail_hook=always_fail)


class TestStragglers:
    def test_flags_slow_worker(self):
        mon = StragglerMonitor(threshold=1.5)
        for _ in range(10):
            for w, d in [("a", 1.0), ("b", 1.1), ("c", 0.9), ("d", 3.0)]:
                mon.record(w, d)
        assert mon.stragglers() == ["d"]
        assert mon.action("d") == "exclude"

    def test_no_flag_on_uniform(self):
        mon = StragglerMonitor()
        for _ in range(5):
            for w in "abcd":
                mon.record(w, 1.0)
        assert mon.stragglers() == []

    def test_single_worker_never_flagged(self):
        mon = StragglerMonitor()
        mon.record("solo", 99.0)
        assert mon.stragglers() == []

    def test_solo_worker_action_is_redispatch(self):
        """With no peers there is no baseline to be slow against: action()
        must not compare the worker to a zero median and exclude it."""
        mon = StragglerMonitor()
        for _ in range(10):
            mon.record("solo", 99.0)
        assert mon.action("solo") == "redispatch"

    def test_action_uses_peer_median_not_own(self):
        """The straggler's own durations must not drag the baseline up."""
        mon = StragglerMonitor(threshold=1.5, window=4)
        for _ in range(4):
            for w, d in [("a", 1.0), ("b", 1.0), ("slow", 10.0)]:
                mon.record(w, d)
        assert mon.action("slow") == "exclude"
        assert mon.action("a") == "redispatch"


def test_restart_policy_default_is_per_call():
    """``policy`` defaults to None (fresh RestartPolicy per call), not a
    shared mutable default instance."""
    import inspect
    sig = inspect.signature(run_with_restarts)
    assert sig.parameters["policy"].default is None


class TestRemesh:
    def test_prefers_shrinking_data_axes(self):
        plan = plan_remesh((2, 16, 16), ("pod", "data", "model"), 256)
        assert plan.new_shape == (1, 16, 16)
        assert plan.resharded_axes == ("pod",)
        assert plan.batch_scale == 2.0

    def test_halves_model_only_when_forced(self):
        plan = plan_remesh((1, 2, 16), ("pod", "data", "model"), 16)
        assert plan.devices_used == 16
        # either (1,1,16) keeping model, or fallback; model kept if possible
        assert plan.new_shape[2] == 16

    @given(st.integers(1, 512))
    @settings(max_examples=80, deadline=None)
    def test_plan_validity_property(self, avail):
        plan = plan_remesh((2, 16, 16), ("pod", "data", "model"), avail)
        used = 1
        for s in plan.new_shape:
            used *= s
        assert used == plan.devices_used <= max(avail, 1)
        assert all(s >= 1 for s in plan.new_shape)
        assert plan.devices_lost == 512 - avail
        # batch scale keeps global batch constant
        assert abs(plan.batch_scale * plan.devices_used - 512) < 1e-6
