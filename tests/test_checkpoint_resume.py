"""Work-item checkpoint/resume of preempted harvest tasks (DESIGN.md §6.4).

Covers the schedule inversion (``ProfileStore.completed_items``), the
step-granular energy/$ refund, estimate/actual parity for resumed
residuals, the resume-vs-restart win, the eviction bookkeeping of dropped
warm shells, and hypothesis properties over random preemption times
(ledger never negative, exact total charge, resume never slower).
"""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CATALOG, MIN_LATENCY, Murakkab, Submission
from repro.core.dag import DAG, TaskNode
from repro.core.profiles import CostQuery
from repro.core.scheduler import ExecutionPlan
from repro.core.simulator import Simulator

V5E = CATALOG["tpu-v5e"]


def _summarize_node(tid="t", items=12, chunkable=True):
    return TaskNode(id=tid, description="", agent="summarize",
                    work_items=items, chunkable=chunkable,
                    tokens_in=900, tokens_out=120)


def _summarize_dag(tid, items, chunkable=True):
    return DAG([_summarize_node(tid, items, chunkable)])


def _system(v5e=8, cores=16):
    return Murakkab.tpu_cluster(v5e=v5e, v5p=0, v4_harvest=0,
                                host_cores=cores)


def _pinned_plan(system, node, n_devices=4, batch=1):
    """A single-config plan (n_instances=1) so requeues reuse the exact
    configuration and the accounting properties are checkable in closed
    form."""
    impl = max(system.library.impls_for(node.agent), key=lambda i: i.quality)
    cfg = system.scheduler.estimate(node, impl, "v5e", n_devices,
                                    n_instances=1, batch=batch)
    return ExecutionPlan({node.id: cfg})


def _preempt_at(system, plan_h, dag_h, arrival_p, resume=True,
                items_p=4, plan_p=None):
    """Run a harvest task preempted by a priority arrival at ``arrival_p``."""
    dag_p = _summarize_dag("quick", items_p)
    if plan_p is not None:
        sub_p = Submission(dag_p, plan_p, arrival_p, tenant="priority")
    else:
        sub_p = Submission(dag_p, None, arrival_p, tenant="priority",
                           plan_fn=lambda: system.scheduler.plan(
                               dag_p, (MIN_LATENCY,), 0.8))
    sim = Simulator(system.cluster, system.library, system.profiles,
                    resume=resume)
    rep = sim.run({
        "h": Submission(dag_h, plan_h, 0.0, tenant="harvest"),
        "p": sub_p,
    }, policy="strict-priority")
    return rep


# -- schedule inversion -------------------------------------------------------


def test_completed_items_inverts_schedule():
    system = _system()
    impl = system.library.impls["nvlm-72b"]
    work = impl.work_fn(900, 120)
    def _ci(elapsed, items=10):
        return system.profiles.completed_items(CostQuery(
            impl=impl, spec=V5E, n_devices=4, work=work, batch=4,
            items=items, elapsed_s=elapsed))
    step4 = system.profiles.step_latency(CostQuery(
        impl=impl, spec=V5E, n_devices=4, work=work, batch=4))
    # 10 items at batch 4: 2 full steps + a 2-item remainder step
    done, wall = _ci(0.0)
    assert (done, wall) == (0, 0.0)
    done, wall = _ci(0.5 * step4)
    assert (done, wall) == (0, 0.0)      # in-flight step is discarded
    done, wall = _ci(1.5 * step4)
    assert done == 4 and wall == pytest.approx(step4)
    # landing exactly on a boundary credits the step that just finished
    done, wall = _ci(2.0 * step4)
    assert done == 8 and wall == pytest.approx(2 * step4)
    # the remainder step only completes at the schedule's very end
    rem = system.profiles.step_latency(CostQuery(
        impl=impl, spec=V5E, n_devices=4, work=work, batch=2))
    done, _ = _ci(2 * step4 + 0.9 * rem)
    assert done == 8
    done, wall = _ci(2 * step4 + rem)
    assert done == 10 and wall == pytest.approx(2 * step4 + rem)


def test_completed_items_caps_at_full_steps():
    """Elapsed beyond the schedule never over-credits items."""
    system = _system()
    impl = system.library.impls["nvlm-72b"]
    work = impl.work_fn(900, 120)
    done, wall = system.profiles.completed_items(CostQuery(
        impl=impl, spec=V5E, n_devices=4, work=work, batch=4, items=8,
        elapsed_s=1e9))
    sched = system.profiles.schedule_latency(CostQuery(
        impl=impl, spec=V5E, n_devices=4, work=work, batch=4, items=8))
    assert done == 8 and wall == pytest.approx(sched)


# -- estimate/actual parity for residuals ------------------------------------


def test_residual_estimate_matches_simulator_duration():
    """Scheduler.estimate(items_done=d) and Simulator._duration price the
    residual through the same schedule_latency call — parity by
    construction, including the warm (no-load) case."""
    system = _system()
    node = _summarize_node(items=11)
    impl = max(system.library.impls_for("summarize"),
               key=lambda i: i.quality)
    sim = Simulator(system.cluster, system.library, system.profiles)
    for d in (0, 1, 4, 7, 10):
        est = system.scheduler.estimate(node, impl, "v5e", 4, batch=4,
                                        warm=True, items_done=d)
        dur, compute, _ = sim._duration(node, est, n_inst=1,
                                        new_instances=0, items_done=d)
        assert dur == pytest.approx(est.est_latency_s)
        assert compute == pytest.approx(system.profiles.schedule_latency(
            CostQuery(impl=impl, spec=V5E, n_devices=4,
                      work=impl.work_fn(900, 120), batch=4, items=11 - d)))


# -- end-to-end resume --------------------------------------------------------


def test_resume_executes_residual_only():
    system = _system()
    dag_h = _summarize_dag("long", 400)
    plan_h = system.scheduler.plan(dag_h, (MIN_LATENCY,), 0.8)
    rep = _preempt_at(system, plan_h, dag_h, arrival_p=10.0)
    assert rep.preemptions >= 1
    assert rep.resumed_items > 0
    notes = {e.note.split("+")[0] for e in rep.trace}
    assert "resume" in notes and "requeue" not in notes
    assert rep.per_workflow["h"]["finish"] > 0
    assert rep.wasted_dev_s >= 0.0
    system.cluster.audit()


def test_nonchunkable_task_restarts_from_scratch():
    """Non-chunkable victims keep the legacy restart path: no checkpoint,
    note stays a requeue, wasted covers all executed compute."""
    system = _system()
    dag_h = _summarize_dag("long", 400, chunkable=False)
    plan_h = system.scheduler.plan(dag_h, (MIN_LATENCY,), 0.8)
    rep = _preempt_at(system, plan_h, dag_h, arrival_p=10.0)
    assert rep.preemptions >= 1
    assert rep.resumed_items == 0
    notes = {e.note.split("+")[0] for e in rep.trace}
    assert "requeue" in notes and "resume" not in notes
    assert rep.wasted_dev_s > 0.0


def test_requeue_note_composes_cold_start():
    """A requeued task that pays a fresh weights load reports both facts
    ("resume+cold"/"requeue+cold"), not just the requeue."""
    system = _system(v5e=8)
    dag_h = _summarize_dag("long", 400)
    plan_h = system.scheduler.plan(dag_h, (MIN_LATENCY,), 0.8)
    # priority job large enough that the victim's warm instance is evicted
    # while it waits, forcing a cold restart of the resumed attempt
    rep = _preempt_at(system, plan_h, dag_h, arrival_p=10.0, items_p=64)
    restarts = [e.note for e in rep.trace
                if e.note.split("+")[0] in ("resume", "requeue")]
    assert restarts
    assert all("+" in n for n in restarts), restarts
    assert any(n.endswith("+cold") or n.endswith("+warm")
               for n in restarts)


def test_resume_beats_restart_wasted_and_span():
    """The headline claim: checkpoint/resume strictly reduces wasted
    device-seconds and never lengthens the victim's span."""
    def run(resume):
        system = _system()
        dag_h = _summarize_dag("long", 400)
        plan_h = system.scheduler.plan(dag_h, (MIN_LATENCY,), 0.8)
        return _preempt_at(system, plan_h, dag_h, 10.0, resume=resume)

    with_resume, restart = run(True), run(False)
    assert with_resume.preemptions == restart.preemptions >= 1
    assert with_resume.wasted_dev_s < restart.wasted_dev_s
    assert with_resume.workflow_span("h") <= restart.workflow_span("h") + 1e-9
    # the priority tenant is untouched by the victim's resume path
    assert with_resume.workflow_span("p") == \
        pytest.approx(restart.workflow_span("p"))


def test_dropped_warm_shell_keeps_cluster_consistent():
    """Preempting the lease under an *idle* warm instance routes through
    evict_instance: no dangling shell, usage matches live leases."""
    system = _system()
    dag_h = _summarize_dag("long", 8)       # short: finishes, stays warm
    plan_h = system.scheduler.plan(dag_h, (MIN_LATENCY,), 0.8)
    dag_p = _summarize_dag("quick", 64)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({
        "h": Submission(dag_h, plan_h, 0.0, tenant="harvest"),
        "p": Submission(dag_p, None, 30.0, tenant="priority",
                        plan_fn=lambda: system.scheduler.plan(
                            dag_p, (MIN_LATENCY,), 0.8)),
    }, policy="strict-priority")
    assert rep.per_workflow["p"]["finish"] > 0
    system.cluster.audit()
    # no instance survived on a released lease
    for inst in system.cluster.instances:
        assert inst.lease is None or \
            system.cluster.lease_active(inst.lease)


# -- hypothesis: random preemption times --------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 30.0), st.integers(2, 64), st.booleans())
def test_preemption_accounting_properties(arrival, batch, resume):
    """Over random preemption times: (1) refunds never drive pool busy
    device-seconds negative; (2) a resumed run charges exactly
    schedule_latency(total items) worth of compute across attempts;
    (3) resume is never slower than restart."""
    system = _system()
    node = _summarize_node("long", items=200)
    node_p = _summarize_node("quick", items=4)
    plan_h = _pinned_plan(system, node, n_devices=4, batch=batch)
    plan_p = _pinned_plan(system, node_p, n_devices=4, batch=1)
    dag_h = DAG([node])
    rep = _preempt_at(system, plan_h, dag_h, arrival_p=arrival,
                      resume=resume, plan_p=plan_p)
    assert all(v >= -1e-9 for v in rep.pool_busy_device_s.values()), \
        rep.pool_busy_device_s
    assert rep.wasted_dev_s >= -1e-9
    assert math.isclose(rep.energy_wh, rep.active_wh + rep.idle_wh,
                        rel_tol=1e-9)
    system.cluster.audit()
    if resume:
        # exact charge: with both configs pinned (n_instances=1, fixed
        # count/batch), the pool's total busy device-seconds equal one
        # clean run of each task's full schedule — the preempted victim's
        # kept steps + residual re-charge sum to exactly
        # schedule_latency(total items), never more, never less
        impl = system.library.impls[plan_h[node.id].impl]
        work = impl.work_fn(node.tokens_in, node.tokens_out)
        expected_h = system.profiles.schedule_latency(CostQuery(
            impl=impl, spec=V5E, n_devices=4, work=work, batch=batch,
            items=node.work_items)) * 4
        impl_p = system.library.impls[plan_p[node_p.id].impl]
        work_p = impl_p.work_fn(node_p.tokens_in, node_p.tokens_out)
        expected_p = system.profiles.schedule_latency(CostQuery(
            impl=impl_p, spec=V5E, n_devices=4, work=work_p, batch=1,
            items=node_p.work_items)) * 4
        v5e_busy = rep.pool_busy_device_s.get("v5e", 0.0)
        assert math.isclose(v5e_busy, expected_h + expected_p,
                            rel_tol=1e-9, abs_tol=1e-9), \
            (v5e_busy, expected_h, expected_p, rep.preemptions)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.5, 30.0), st.integers(2, 64))
def test_resume_never_slower_than_restart(arrival, batch):
    """Same preemption point, same config: the resumed victim finishes no
    later than the restarted one."""
    spans = {}
    for resume in (True, False):
        system = _system()
        node = _summarize_node("long", items=200)
        plan_h = _pinned_plan(system, node, n_devices=4, batch=batch)
        rep = _preempt_at(system, plan_h, DAG([node]), arrival_p=arrival,
                          resume=resume)
        spans[resume] = (rep.workflow_span("h"), rep.preemptions,
                         rep.wasted_dev_s)
    assert spans[True][1] == spans[False][1]      # same preemption count
    assert spans[True][0] <= spans[False][0] + 1e-9
    if spans[True][1]:
        assert spans[True][2] <= spans[False][2] + 1e-9
