"""Typed dataflow specs: artifact registry, input units, cardinality/token
models, scenario matching, and DAG wiring purely from interface types."""
import pytest

from repro.core import (ARTIFACTS, SCENARIOS, AgentInterface, CardinalityModel,
                        DocumentInput, InputSet, Job, Murakkab, QueryInput,
                        RulePlanner, TokenModel, VideoInput, input_units)
from repro.core.agents import AgentLibrary, default_library


def test_inputs_satisfy_protocol_and_units_merge():
    vids = (VideoInput("a.mov", scenes=4, frames_per_scene=10),
            VideoInput("b.mov", scenes=4, frames_per_scene=10))
    assert all(isinstance(v, InputSet) for v in vids)
    assert input_units(vids) == {"videos": 2, "scenes": 8, "frames": 80}

    docs = (DocumentInput("x.pdf", pages=12, chunks_per_page=3),)
    assert input_units(docs) == {"documents": 1, "pages": 12, "chunks": 36}

    qs = (QueryInput("q1", candidates=20), QueryInput("q2", candidates=20))
    assert input_units(qs) == {"queries": 2, "passages": 40}

    # opaque payloads alongside typed inputs contribute nothing
    assert input_units((object(), VideoInput("a.mov", scenes=2)))["scenes"] \
        == 2


def test_cardinality_model_unit_fallback_chain():
    m = CardinalityModel(("scenes", "chunks", "queries"))
    assert m.items({"scenes": 8, "chunks": 99}) == 8     # first key wins
    assert m.items({"chunks": 72}) == 72
    assert m.items({"queries": 4}) == 4
    assert m.items({}) == 1                               # default
    assert CardinalityModel().items({"scenes": 8}) == 1   # unitless


def test_interface_declares_workload_models():
    lib = default_library()
    assert lib.interfaces["summarize"].cardinality.units == ("frames",)
    assert lib.interfaces["summarize"].tokens == TokenModel(900, 120)
    assert lib.interfaces["digest"].cardinality.units == ("chunks",)
    assert lib.interfaces["retrieve"].cardinality.units == ("queries",)


def test_unknown_artifact_type_rejected_at_registration():
    lib = AgentLibrary()
    with pytest.raises(KeyError, match="unknown artifact"):
        lib.register_interface(AgentInterface(
            "bad", "produces a typo'd artifact", schema={},
            keywords=("bad",), produces="framez"))
    with pytest.raises(KeyError, match="unknown artifact"):
        lib.register_interface(AgentInterface(
            "bad2", "consumes a typo'd artifact", schema={},
            keywords=("bad2",), produces="frames", consumes=("vydeo",)))
    # defining the artifact first makes registration legal
    ARTIFACTS.define("sidecar_meta", "test-only artifact")
    lib.register_interface(AgentInterface(
        "meta_extract", "produces the new artifact", schema={},
        keywords=("meta",), produces="sidecar_meta"))
    assert "meta_extract" in lib.interfaces


def test_scenario_matching_by_input_artifacts():
    assert SCENARIOS.match((VideoInput("v.mov"),)).name == \
        "video_understanding"
    assert SCENARIOS.match((QueryInput("q"),)).name == "agentic_rag"
    assert SCENARIOS.match((DocumentInput("d.pdf"),)).name == "doc_ingest"
    assert SCENARIOS.match((object(),)) is None
    assert {"video_understanding", "agentic_rag", "doc_ingest"} <= \
        set(SCENARIOS.names())


def test_dataflow_wiring_is_type_driven():
    """Edges come from produces/consumes artifact types, for every scenario."""
    lib = default_library()
    planner = RulePlanner(lib)

    rag = planner.lower(Job(description="answer the question",
                            inputs=(QueryInput("q", candidates=20),)))
    agents = {n.agent: n for n in rag.nodes.values()}
    assert [rag.nodes[t].agent for t in rag.topo_order] == \
        ["retrieve", "rerank", "synthesize", "embed"]
    assert {rag.nodes[d].agent for d in agents["rerank"].deps} == {"retrieve"}
    assert {rag.nodes[d].agent for d in agents["synthesize"].deps} == \
        {"rerank"}
    assert {rag.nodes[d].agent for d in agents["embed"].deps} == {"synthesize"}
    # cardinality: 1 query, 20 candidate passages
    assert agents["retrieve"].work_items == 1
    assert agents["rerank"].work_items == 20
    # token model flows from the interface
    assert agents["synthesize"].tokens_in == 1200

    ing = planner.lower(Job(description="ingest",
                            inputs=(DocumentInput("d.pdf", pages=10,
                                                  chunks_per_page=2),)))
    agents = {n.agent: n for n in ing.nodes.values()}
    assert [ing.nodes[t].agent for t in ing.topo_order] == \
        ["parse_doc", "digest", "embed"]
    assert agents["parse_doc"].work_items == 10       # pages
    assert agents["digest"].work_items == 20          # chunks
    assert agents["embed"].work_items == 20


def test_no_scenario_and_no_hints_raises():
    lib = default_library()
    with pytest.raises(ValueError, match="no registered scenario"):
        RulePlanner(lib).lower(Job(description="do something", inputs=()))


def test_typod_arg_builder_key_raises(monkeypatch):
    """A scenario arg_builder keyed by a misspelled interface is an error at
    decompose time, not silently-empty toolcall args."""
    import dataclasses

    from repro.core.spec import SCENARIOS
    from repro.configs.workflow_rag import RAG_SCENARIO
    bad = dataclasses.replace(
        RAG_SCENARIO, name="bad_rag",
        arg_builders={**RAG_SCENARIO.arg_builders,
                      "synthesise": lambda job: {}})
    monkeypatch.setitem(SCENARIOS._scenarios, "agentic_rag", bad)
    monkeypatch.delitem(SCENARIOS._scenarios, "bad_rag", raising=False)
    lib = default_library()
    with pytest.raises(ValueError, match="synthesise"):
        RulePlanner(lib).lower(Job(description="answer",
                                   inputs=(QueryInput("q"),)))


def test_unknown_component_alias_raises():
    system = Murakkab.paper_cluster()
    from repro.core import Tool, Workflow
    wf = Workflow(Tool(name="sprocketizer", resources={"CPUs": 1}))
    with pytest.raises(KeyError, match="unknown component 'sprocketizer'"):
        system.lower_imperative(wf, ())


def test_nonpositive_resources_rejected():
    system = Murakkab.paper_cluster()
    with pytest.raises(ValueError, match="non-positive device count"):
        system._resources_to_pool({"GPUs": 0})
    with pytest.raises(ValueError, match="non-positive device count"):
        system._resources_to_pool({"CPUs": -2})
    with pytest.raises(ValueError, match="unintelligible"):
        system._resources_to_pool({"FPGAs": 4})
