"""Per-(impl, pool) group-best level-3 expansion (DESIGN.md §11.4).

The ROADMAP carry-over: expanding *every* level-2 group best through the
level-3 parallelism levers widens the candidate set beyond the two-seed
expansion, and the fan-out-aware pruning bound must be plan-preserving —
pruned groups provably cannot win even after fan-out/paths, so plans with
pruning on equal the exhaustive (prune-off) expansion exactly.
"""
import pytest

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.configs.workflow_docingest import make_docingest_job
from repro.configs.workflow_rag import make_rag_job
from repro.configs.workflow_video import make_declarative_job
from repro.core import MAX_QUALITY, MIN_ENERGY, MIN_LATENCY, Murakkab
from repro.core.constraints import as_spec

JOBS = {
    "rag": make_rag_job,
    "docingest": make_docingest_job,
    "video": make_declarative_job,
}


def _plan(job, *, group_expand: bool, prune: bool = True):
    system = Murakkab.tpu_cluster()
    system.scheduler.group_expand = group_expand
    system.scheduler.prune = prune
    dag, plan = system.plan(job)
    return system, dag, plan


# -- plan equality: fan-out-aware pruning is plan-preserving ------------------

@pytest.mark.parametrize("scenario", sorted(JOBS))
def test_group_expand_prune_equals_exhaustive(scenario):
    """Pruned group expansion == exhaustive expansion of every group, on
    each scenario (the bound never skips a group that could have won)."""
    job = JOBS[scenario]()
    sys_p, dag_p, pruned = _plan(job, group_expand=True, prune=True)
    sys_x, dag_x, exhaustive = _plan(job, group_expand=True, prune=False)
    assert pruned.configs == exhaustive.configs
    # the bound actually fired: pruning skipped real candidate work
    assert sys_p.scheduler.pruned > 0
    assert sys_p.scheduler.evals < sys_x.scheduler.evals


@pytest.mark.parametrize("order", [MIN_ENERGY, MIN_LATENCY, MAX_QUALITY])
def test_group_expand_prune_equality_across_orders(order):
    """The same equality under latency-, energy- and quality-led orders
    (the quality-led path exercises the max-paths quality bound)."""
    job = make_rag_job(constraints=order)
    _, _, pruned = _plan(job, group_expand=True, prune=True)
    _, _, exhaustive = _plan(job, group_expand=True, prune=False)
    assert pruned.configs == exhaustive.configs


# -- never worse than the two-seed expansion ----------------------------------

@pytest.mark.parametrize("scenario", sorted(JOBS))
def test_group_expand_never_worse_than_two_seed(scenario):
    """Group expansion's candidate set is a superset of the two-seed
    search's: per task, the chosen config's constraint key is <= the
    default search's key."""
    job = JOBS[scenario]()
    spec = as_spec(job.constraint_spec)
    _, dag_d, default = _plan(job, group_expand=False)
    _, dag_g, grouped = _plan(job, group_expand=True)
    assert list(dag_d.topo_order) == list(dag_g.topo_order)
    for tid in dag_d.topo_order:
        assert spec.key(grouped[tid]) <= spec.key(default[tid])


# -- default-off inertness ----------------------------------------------------

def test_group_expand_off_by_default_and_plans_stable():
    """The flag defaults off, and flipping it on/off round-trips to the
    identical default plan (no hidden state leaks between searches)."""
    system = Murakkab.tpu_cluster()
    assert system.scheduler.group_expand is False
    job = make_rag_job()
    dag = system.lower(job)
    before = system.scheduler.plan(dag, job.constraint_spec,
                                   job.quality_floor)
    system.scheduler.group_expand = True
    system.scheduler.plan(dag, job.constraint_spec, job.quality_floor)
    system.scheduler.group_expand = False
    after = system.scheduler.plan(dag, job.constraint_spec,
                                  job.quality_floor)
    assert before.configs == after.configs
