"""The paper's evaluation endpoints (§4, Fig. 3, Table 2) as assertions."""
import pytest

from benchmarks.paper_eval import PAPER_TARGETS, run_all, prewarm
from repro.core import MIN_COST, Murakkab
from repro.configs.workflow_video import make_declarative_job


@pytest.fixture(scope="module")
def results():
    return run_all()


def test_baseline_matches_paper(results):
    mk, wh, _ = results["baseline"]
    assert abs(mk / PAPER_TARGETS["baseline"][0] - 1) < 0.10
    assert abs(wh / PAPER_TARGETS["baseline"][1] - 1) < 0.15


def test_murakkab_cpu_matches_paper(results):
    mk, wh, _ = results["cpu"]
    assert abs(mk / PAPER_TARGETS["cpu"][0] - 1) < 0.05
    assert abs(wh / PAPER_TARGETS["cpu"][1] - 1) < 0.05


def test_murakkab_gpu_rows_close(results):
    for row in ("gpu", "gpu+cpu"):
        mk, wh, _ = results[row]
        assert abs(mk / PAPER_TARGETS[row][0] - 1) < 0.10, row
        assert abs(wh / PAPER_TARGETS[row][1] - 1) < 0.20, row


def test_headline_speedup(results):
    speed = results["baseline"][0] / results["cpu"][0]
    assert 3.2 <= speed <= 3.9        # paper ~3.4x


def test_headline_energy_efficiency(results):
    eff = results["baseline"][1] / results["cpu"][1]
    assert 4.2 <= eff <= 5.3          # paper ~4.5x


def test_min_cost_selects_cpu_config():
    system = Murakkab.paper_cluster()
    prewarm(system)
    dag, plan = system.plan(make_declarative_job(MIN_COST))
    stt = next(c for t, c in plan.configs.items() if "speech" in t)
    assert stt.impl == "whisper-large" and stt.pool == "cpu"
    assert stt.n_devices == 64        # the profiled 64-core configuration


def test_murakkab_configs_all_beat_baseline(results):
    base_mk, base_wh, _ = results["baseline"]
    for row in ("cpu", "gpu", "gpu+cpu"):
        mk, wh, _ = results[row]
        assert mk < base_mk / 3.0     # >=3x faster
        assert wh < base_wh / 3.5     # >=3.5x less energy
