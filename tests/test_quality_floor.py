"""Quality-floor semantics + the ProfileStore quality column (§11).

ISSUE satellite: no selected config violates a satisfiable floor, the
cost-vs-floor frontier is monotone (raising a floor never lowers cost),
estimate/actual parity holds for quality-routed tasks, and measured
quality pins reshape level-1 gating (quality-aware model selection).
"""
import pytest

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.configs.workflow_rag import ROUTED_QUERIES, make_rag_job
from repro.core import MIN_COST, Murakkab, Router

SYNTH_LADDER = {"deepseek-7b-synth": 0.86, "gemma2-9b-synth": 0.90,
                "command-r-plus-104b-synth": 0.97}


def _plan(floor: dict, system=None):
    system = system or Murakkab.tpu_cluster()
    job = make_rag_job(quality_floor=floor)
    dag, plan = system.plan(job)
    synth = next(t for t in dag.topo_order if "synthesize" in t)
    return system, dag, plan, synth


# -- the floor is a hard gate -------------------------------------------------

@pytest.mark.parametrize("floor", [0.0, 0.8, 0.86, 0.90, 0.92, 0.97])
def test_satisfiable_floor_never_violated(floor):
    """Whenever >= 1 impl clears the floor, the chosen one does too."""
    system, dag, plan, synth = _plan({"synthesize": floor})
    assert system.profiles.quality(plan[synth].impl) >= floor
    # and the planned config's quality estimate clears it as well
    assert plan[synth].quality >= floor


def test_unsatisfiable_floor_falls_back_to_best_available():
    """A floor above the whole ladder degrades to max-quality, by design
    (the planner prefers a slightly-under answer over no answer)."""
    system, dag, plan, synth = _plan({"synthesize": 0.995})
    assert plan[synth].impl == "command-r-plus-104b-synth"   # ladder top


def test_cost_frontier_monotone_in_floor():
    """Raising a floor shrinks the admissible set: MIN_COST plan cost is
    non-decreasing along the floor grid, for $ and energy."""
    usd, energy = [], []
    for floor in (0.0, 0.86, 0.90, 0.92, 0.97):
        system, dag, plan, _ = _plan({"synthesize": floor})
        rep = plan.report(dag)
        usd.append(rep["est_usd"])
        energy.append(rep["est_energy_j"])
    for lo, hi in zip(usd, usd[1:]):
        assert hi >= lo - 1e-12
    for lo, hi in zip(energy, energy[1:]):
        assert hi >= lo - 1e-12
    assert usd[-1] > usd[0]    # the grid actually moves the choice


# -- estimate/actual parity for quality-routed tasks --------------------------

def test_estimate_actual_parity_under_routing():
    """A trained router narrowing the retrieve arm changes *which* config
    runs, not the estimate/actual contract: every trace interval equals
    its planned latency."""
    weights = {("retrieve", b): {"bm25-keyword": 1.0}
               for b in ("lookup:short", "semantic:short")}
    system = Murakkab.paper_cluster(
        router=Router(interfaces=("retrieve",), epsilon=0.0, seed=0,
                      weights=weights))
    res = system.execute(make_rag_job(queries=ROUTED_QUERIES[:1]))
    assert res.plan[
        next(iter(res.plan.configs))].impl    # plan resolved
    retrieve = [e for e in res.sim.trace if "retrieve" in e.task]
    assert retrieve and retrieve[0].impl == "bm25-keyword"
    for entry in res.sim.trace:
        cfg = res.plan[entry.task]
        assert entry.end - entry.start == pytest.approx(
            cfg.est_latency_s, rel=1e-9)


# -- the quality column (measured pins) ---------------------------------------

def test_pin_quality_validation():
    system = Murakkab.tpu_cluster()
    with pytest.raises(KeyError):
        system.profiles.pin_quality("no-such-impl", 0.9)
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError):
            system.profiles.pin_quality("gemma2-9b-synth", bad)


def test_pin_quality_overrides_declared_ladder():
    system = Murakkab.tpu_cluster()
    for name, declared in SYNTH_LADDER.items():
        assert system.profiles.quality(name) == declared
    system.profiles.pin_quality("gemma2-9b-synth", 0.93)
    assert system.profiles.quality("gemma2-9b-synth") == 0.93
    # estimates read the pinned column
    node = system.lower(make_rag_job()).nodes
    synth = next(n for n in node.values() if n.agent == "synthesize")
    impl = system.library.impls["gemma2-9b-synth"]
    cfg = system.scheduler.estimate(synth, impl, "v5e", 1)
    assert cfg.quality == pytest.approx(0.93)


def test_calibrated_pin_admits_cheaper_model_at_same_floor():
    """The ISSUE's model-selection criterion: at synthesize floor 0.92
    the declared ladder admits only the 104B model; pinning gemma2's
    measured 0.93 finds a strictly cheaper plan at the same floor."""
    _, dag_f, plan_f, synth = _plan({"synthesize": 0.92})
    assert plan_f[synth].impl == "command-r-plus-104b-synth"

    system = Murakkab.tpu_cluster()
    system.profiles.pin_quality("gemma2-9b-synth", 0.93)
    _, dag_c, plan_c, synth_c = _plan({"synthesize": 0.92}, system=system)
    assert plan_c[synth_c].impl == "gemma2-9b-synth"
    assert system.profiles.quality(plan_c[synth_c].impl) >= 0.92
    assert plan_c.report(dag_c)["est_usd"] < \
        plan_f.report(dag_f)["est_usd"]


def test_pin_quality_invalidates_plan_cache():
    system = Murakkab.tpu_cluster()
    job = make_rag_job(quality_floor={"synthesize": 0.92},
                       constraints=MIN_COST)
    dag = system.lower(job)
    system.plan_admitted(dag, job)
    system.plan_admitted(dag, job)
    assert system.plan_cache_hits == 1
    system.profiles.pin_quality("gemma2-9b-synth", 0.93)
    misses = system.plan_cache_misses
    plan = system.plan_admitted(dag, job)
    assert system.plan_cache_misses == misses + 1
    synth = next(t for t in dag.topo_order if "synthesize" in t)
    assert plan[synth].impl == "gemma2-9b-synth"
