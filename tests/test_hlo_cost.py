"""hlo_cost: the production-artifact cost model (§Roofline v2)."""
import textwrap

from repro.launch.hlo_cost import ScaledGraph, hlo_cost

_HLO = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %g = f32[8,128]{1,0} get-tuple-element(%p), index=1
      %ar = f32[8,128]{1,0} all-reduce(%g), replica_groups=[16,16]<=[256], to_apply=%add
      %d = f32[8,128]{1,0} add(%ar, %ar)
      ROOT %t = (s32[], f32[8,128]) tuple(%c, %d)
    }

    %cond.1 (p2: (s32[], f32[8,128])) -> pred[] {
      %p2 = (s32[], f32[8,128]) parameter(0)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,128]) -> f32[8,128] {
      %a = f32[8,128]{1,0} parameter(0)
      %big = f32[1024,1024]{1,0} dot(%a, %a)
      %w = (s32[], f32[8,128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"30"},"other":1}
      %ag = f32[64,128]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
      ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_trip_count_scaling():
    g = ScaledGraph.parse(_HLO)
    assert g.scale["__ENTRY__"] == 1.0
    assert g.scale["body.1"] == 30.0
    assert g.scale["cond.1"] == 31.0
    assert g.depth["body.1"] == 1


def test_collective_scaling():
    c = hlo_cost(_HLO)
    ar = c["coll"]["all-reduce"]
    assert ar["count"] == 30.0
    # 8*128*4 bytes * 2*(15/16) ring factor * 30 trips
    assert abs(ar["bytes"] - 8 * 128 * 4 * 2 * 15 / 16 * 30) < 1e-6
    ag = c["coll"]["all-gather"]
    assert ag["count"] == 1.0
    assert abs(ag["bytes"] - 64 * 128 * 4 * 3 / 4) < 1e-6


def test_memory_counts_materialized_only():
    g = ScaledGraph.parse(_HLO)
    m = g.memory_traffic()
    # body: (ar + add) x30; cond: compare x31; entry: dot + all-gather
    # (parameters/GTE/tuple/while free)
    expect = ((8 * 128 * 4 * 2) * 30 * 2      # ar + add
              + 1 * 31 * 2                     # pred compare
              + 1024 * 1024 * 4 * 2            # dot
              + 64 * 128 * 4 * 2)              # all-gather result
    assert abs(m - expect) < 1e-6


def test_variadic_collective_bytes():
    hlo = ("ENTRY %m (x: f32[4]) -> f32[4] {\n"
           "  %ar = (f32[256,128]{1,0}, f32[256,128]{1,0}) all-reduce("
           "%a, %b), replica_groups=[2,8]<=[16], to_apply=%add\n"
           "  ROOT %r = f32[4]{0} parameter(0)\n}\n")
    c = hlo_cost(hlo)
    assert c["coll"]["all-reduce"]["raw_bytes"] == 2 * 256 * 128 * 4


def test_kernel_boundary_excluded():
    hlo = ('ENTRY %m (x: f32[4]) -> f32[4] {\n'
           '  %k = f32[1024,1024]{1,0} dot(%x, %x), metadata={op_name='
           '"jit(f)/pk_flash_attention/dot_general"}\n'
           '  %d = f32[512,512]{1,0} dot(%x, %x), metadata={op_name='
           '"jit(f)/other/dot_general"}\n'
           '  ROOT %r = f32[4]{0} parameter(0)\n}\n')
    g = ScaledGraph.parse(hlo)
    assert g.memory_traffic() == 512 * 512 * 4 * 2


def test_serving_rules_replicate_weights():
    import types
    import numpy as np
    from repro.runtime import sharding as shd
    fake = types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((16, 16)))
    # dense weight: default FSDP on embed vs serving replication
    d = shd.spec_for_axes(("embed", "mlp"), (4096, 11008), fake)
    s = shd.spec_for_axes(("embed", "mlp"), (4096, 11008), fake,
                          rules=shd.SERVING_RULES)
    assert d[0] == "data" and s[0] is None
    # expert weight 2D: experts->model, f->data once embed is replicated
    e = shd.spec_for_axes(("experts", "embed", "expert_mlp"),
                          (384, 7168, 2048), fake, rules=shd.SERVING_RULES)
    assert e[0] == "model" and e[1] is None and e[2] == "data"
