"""Open-loop serving engine (``run_open_loop``, DESIGN.md §8).

The load-bearing property is dispatch equivalence: the indexed ready-set +
blocked-group memo + cached digest fast path must produce byte-identical
traces, energy, and steady-state metrics against the seed's full-rescan
reference (``fast_dispatch=False``) on every scenario mix.
"""
import dataclasses

import pytest

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.core import Murakkab
from repro.core.arrivals import (MMPPArrivals, PoissonArrivals,
                                 TraceArrivals, default_mix)
from repro.core.autoscale import Autoscaler, PoolPolicy


def _system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128)


def _poisson(rate=0.25, seed=4, mix=None):
    return PoissonArrivals(rate_per_s=rate, mix=mix or default_mix(),
                           seed=seed)


# -- fast-dispatch equivalence -----------------------------------------------
# The per-scenario byte-identity witness lives in test_engine_identity.py
# (one parametrized test, all four scenarios, both dispatch paths); this
# file keeps only the mixed-stream + autoscaler variant it can't cover.

def test_fast_dispatch_equivalent_mixed_with_autoscaler():
    """The full serving stack — mixed scenarios, all tenant classes, the
    harvest pool autoscaling to zero — still matches the reference path."""
    def run(fast):
        return _system().open_loop(
            _poisson(rate=0.3, seed=8), horizon_s=400.0, warmup_s=40.0,
            autoscaler=Autoscaler({"v4_harvest": PoolPolicy(
                0, 32, scale_up_lag_s=15.0, cooldown_s=60.0)},
                interval_s=15.0),
            fast_dispatch=fast)
    fast_rep, ref = run(True), run(False)
    assert fast_rep.trace == ref.trace
    assert fast_rep.energy_wh == ref.energy_wh
    assert fast_rep.scale_actions == ref.scale_actions
    assert fast_rep.per_class == ref.per_class
    # the whole point of the fast path: strictly fewer start attempts
    assert fast_rep.n_attempts < ref.n_attempts


def test_open_loop_deterministic_replay():
    a = _system().open_loop(_poisson(), horizon_s=300.0, warmup_s=30.0)
    b = _system().open_loop(_poisson(), horizon_s=300.0, warmup_s=30.0)
    assert a.trace == b.trace
    assert a.energy_wh == b.energy_wh
    assert a.per_class == b.per_class


# -- steady-state metrics ----------------------------------------------------

def test_warmup_trimming_and_slo_metrics():
    rep = _system().open_loop(_poisson(rate=0.3, seed=2),
                              horizon_s=400.0, warmup_s=100.0,
                              collect_trace=False)
    assert rep.arrivals == rep.completed        # under-loaded: all drain
    assert 0 < rep.measured < rep.arrivals      # warmup trimmed something
    assert rep.offered_rps == pytest.approx(rep.arrivals / 400.0)
    for cls, row in rep.per_class.items():
        assert row["n"] > 0
        assert 0.0 < row["p50_s"] <= row["p99_s"]
        assert row["slo_attainment"] is not None
        assert 0.0 <= row["slo_attainment"] <= 1.0
    assert rep.goodput_rps > 0
    assert rep.events_per_s > 0
    # priority SLOs are the tightest (0.5x) yet attainment shouldn't trail
    # harvest's (4x budget) by much on an under-loaded cluster; just
    # sanity-check the classes all appear
    assert set(rep.per_class) == {"priority", "standard", "harvest"}


def test_trace_replay_source_e2e():
    """A recorded JSONL trace replays to the identical serving report."""
    trace = TraceArrivals.record(_poisson(rate=0.25, seed=6),
                                 horizon_s=200.0)
    text = trace.to_jsonl()
    r1 = _system().open_loop(TraceArrivals.from_jsonl(text),
                             horizon_s=200.0, warmup_s=20.0)
    r2 = _system().open_loop(_poisson(rate=0.25, seed=6),
                             horizon_s=200.0, warmup_s=20.0)
    assert r1.trace == r2.trace
    assert r1.energy_wh == r2.energy_wh


def test_mmpp_burst_source_runs():
    rep = _system().open_loop(
        MMPPArrivals(rate_on=1.0, rate_off=0.02, mean_on_s=30.0,
                     mean_off_s=120.0, mix=default_mix(), seed=5),
        horizon_s=400.0, warmup_s=0.0, collect_trace=False)
    assert rep.completed == rep.arrivals > 0


def test_source_must_be_time_ordered():
    sys_ = _system()
    from repro.core.arrivals import SERVING_PRESETS
    from repro.core.simulator import Simulator, Submission
    sim = Simulator(sys_.cluster, sys_.library, sys_.profiles)
    job = SERVING_PRESETS["rag"].make_job()
    dag = sys_.lower(job)
    plan = sys_.plan_admitted(dag, job)

    def bad():
        yield "w0", Submission(dag=dag, plan=plan, arrival=5.0)
        yield "w1", Submission(dag=dag, plan=plan, arrival=1.0)

    with pytest.raises(ValueError, match="time-ordered"):
        sim.run_open_loop(bad(), horizon_s=10.0)


def test_plan_mode_validation_and_admission_mode():
    sys_ = _system()
    with pytest.raises(ValueError, match="plan_mode"):
        sys_.open_loop(_poisson(), horizon_s=50.0, plan_mode="lazy")
    rep = _system().open_loop(_poisson(rate=0.2, seed=1), horizon_s=120.0,
                              plan_mode="admission", collect_trace=False)
    assert rep.completed == rep.arrivals > 0


def test_report_is_a_sim_report_superset():
    """OpenLoopReport extends SimReport: closed-loop consumers (render
    helpers, regression gates) keep working on serving output."""
    from repro.core.simulator import SimReport
    rep = _system().open_loop(_poisson(rate=0.2, seed=3), horizon_s=120.0)
    assert isinstance(rep, SimReport)
    fields = {f.name for f in dataclasses.fields(rep)}
    assert {"energy_wh", "makespan_s", "per_class", "goodput_rps",
            "events_per_s", "scale_actions"} <= fields
