"""End-to-end behaviour of the full Murakkab system (paper Fig. 2):
declarative job in -> DAG -> adaptive schedule -> execution report out,
plus the orchestrator <-> cluster-manager interplay."""
import pytest

from repro.core import (Job, MAX_QUALITY, MIN_COST, MIN_ENERGY, MIN_LATENCY,
                        Murakkab, VideoInput)
from repro.configs.workflow_video import make_declarative_job


def test_declarative_job_end_to_end():
    system = Murakkab.paper_cluster()
    result = make_declarative_job().execute(system)
    assert result.makespan_s > 0
    assert result.energy_wh > 0
    assert len(result.dag) == 5
    assert set(result.toolcalls) == set(result.dag.nodes)
    assert 0 < result.quality <= 1
    # every task appears in the trace exactly once
    assert sorted(e.task for e in result.sim.trace) == \
        sorted(result.dag.nodes)


@pytest.mark.parametrize("c2", [MIN_COST, MIN_ENERGY])
def test_constraints_tradeoff(c2):
    """MIN_LATENCY is never slower than other single constraints."""
    r1 = make_declarative_job(MIN_LATENCY).execute(Murakkab.paper_cluster())
    r2 = make_declarative_job(c2).execute(Murakkab.paper_cluster())
    assert r1.makespan_s <= r2.makespan_s * 1.001


def test_max_quality_upgrades_impl():
    floor = {"speech_to_text": 0.0, "object_detect": 0.0, "summarize": 0.0,
             "frame_extract": 0.0, "embed": 0.0}
    cheap = Job(description="Describe the videos",
                inputs=(VideoInput("v.mov"),), constraints=MIN_COST,
                quality_floor=floor).execute(Murakkab.tpu_cluster())
    best = Job(description="Describe the videos",
               inputs=(VideoInput("v.mov"),), constraints=MAX_QUALITY,
               quality_floor=floor).execute(Murakkab.tpu_cluster())
    assert best.quality >= cheap.quality
    assert best.usd >= cheap.usd * 0.99


def test_orchestrator_sees_cluster_stats():
    """Resource-aware orchestration: a cluster without accelerators routes
    everything to CPU pools."""
    from repro.core.cluster import ClusterManager, Pool
    cpu_only = Murakkab(ClusterManager([Pool("cpu", "host-core",
                                             capacity=256)]))
    job = Job(description="Describe the videos",
              inputs=(VideoInput("v.mov"),), quality_floor=0.0)
    dag, plan = cpu_only.plan(job)
    assert all(c.pool == "cpu" for c in plan.configs.values())


def test_workflow_aware_rebalance_in_run():
    """During a run the cluster manager reclaims instances whose interface
    has no remaining demand (the Whisper->Llama example)."""
    system = Murakkab.paper_cluster()
    result = make_declarative_job().execute(system)
    assert any("reclaim" in line for line in result.log), result.log


def test_imperative_and_declarative_same_dag_semantics():
    from repro.configs.workflow_video import (PAPER_VIDEOS,
                                              make_baseline_workflow)
    system = Murakkab.paper_cluster()
    dag, plan = system.lower_imperative(make_baseline_workflow(),
                                        PAPER_VIDEOS)
    agents = [dag.nodes[t].agent for t in dag.topo_order]
    assert agents == ["frame_extract", "speech_to_text", "object_detect",
                      "summarize", "embed"]
    # chain: each node depends on the previous (the Listing-1 rigidity)
    order = dag.topo_order
    for a, b in zip(order, order[1:]):
        assert dag.nodes[b].deps == (a,)


def test_multitenant_isolation():
    """Two tenants' tasks never exceed pool capacity and both finish."""
    system = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0, host_cores=32)
    report = system.execute_many({
        "a": (make_declarative_job(MIN_LATENCY), 0.0),
        "b": (make_declarative_job(MIN_LATENCY), 1.0),
    })
    assert set(report.per_workflow) == {"a", "b"}
    assert all(v["finish"] > 0 for v in report.per_workflow.values())
