"""Golden test: the video scenario lowers to the exact DAG + plan the seed
produced before the scenario-agnostic API redesign (captured at PR 1).

If an intentional change to the lowering or scheduling semantics moves these
values, re-capture them with::

    PYTHONPATH=src python -c "
    from repro.core import Murakkab
    from repro.configs.workflow_video import make_declarative_job
    dag, plan = Murakkab.paper_cluster().plan(make_declarative_job())
    ..."
"""
import pytest

from repro.core import Murakkab
from repro.configs.workflow_video import (PAPER_VIDEOS,
                                          make_baseline_workflow,
                                          make_declarative_job)

# (id, deps, work_items, tokens_in, tokens_out) per node, topo order
GOLDEN_DAG = [
    ("t0_frame_extract", (), 8, 0, 0),
    ("t1_speech_to_text", (), 8, 0, 0),
    ("t2_object_detect", ("t0_frame_extract",), 8, 0, 0),
    ("t3_summarize", ("t0_frame_extract", "t2_object_detect",
                      "t1_speech_to_text"), 80, 900, 120),
    ("t4_embed", ("t3_summarize",), 8, 0, 0),
]

# (impl, pool, n_devices, n_instances, batch, paths) per task
GOLDEN_PLAN = {
    "t0_frame_extract": ("opencv", "cpu", 1, 8, 1, 1),
    "t1_speech_to_text": ("whisper-large", "cpu", 64, 2, 1, 1),
    "t2_object_detect": ("clip", "cpu", 2, 8, 1, 1),
    "t3_summarize": ("nvlm-72b", "gpu", 8, 1, 80, 1),
    "t4_embed": ("nvlm-embed", "gpu", 2, 1, 8, 1),
}

GOLDEN_TOOLCALL = ("FrameExtractor(end_time=240, file='cats.mov', "
                   "num_frames=10, start_time=0)")


def test_video_dag_matches_seed():
    dag, _ = Murakkab.paper_cluster().plan(make_declarative_job())
    got = [(n.id, n.deps, n.work_items, n.tokens_in, n.tokens_out)
           for n in (dag.nodes[t] for t in dag.topo_order)]
    assert got == GOLDEN_DAG


def test_video_plan_matches_seed():
    _, plan = Murakkab.paper_cluster().plan(make_declarative_job())
    got = {tid: (c.impl, c.pool, c.n_devices, c.n_instances, c.batch,
                 c.paths)
           for tid, c in plan.configs.items()}
    assert got == GOLDEN_PLAN


def test_video_execution_endpoints_match_seed():
    result = make_declarative_job().execute(Murakkab.paper_cluster())
    assert result.makespan_s == pytest.approx(143.05, abs=0.5)
    assert result.energy_wh == pytest.approx(57.47, abs=0.5)
    assert result.toolcalls["t0_frame_extract"] == GOLDEN_TOOLCALL

    base = make_baseline_workflow().execute(Murakkab.paper_cluster(),
                                            inputs=PAPER_VIDEOS)
    assert base.makespan_s == pytest.approx(295.2, abs=0.5)
    assert base.energy_wh == pytest.approx(168.26, abs=0.5)


def test_imperative_golden_dag():
    system = Murakkab.paper_cluster()
    dag, plan = system.lower_imperative(make_baseline_workflow(),
                                        PAPER_VIDEOS)
    items = {dag.nodes[t].agent: dag.nodes[t].work_items for t in dag}
    assert items == {"frame_extract": 8, "speech_to_text": 8,
                     "object_detect": 8, "summarize": 80, "embed": 8}
    summ = [n for n in dag.nodes.values() if n.agent == "summarize"][0]
    assert (summ.tokens_in, summ.tokens_out) == (900, 120)
    # Listing-1 pinning: the plan is warm and fixed
    assert all(c.warm for c in plan.configs.values())
