"""Vectorized pricing kernel (DESIGN.md §12): ``step_latency_batch`` /
``schedule_latency_batch`` must be *bitwise*-identical to mapping the
scalar path — the memo they feed is the same memo ``Simulator._duration``
and ``Scheduler.estimate`` read, so any ULP drift would fork estimates
from actuals. Every equality below is ``==``, never approx."""
import warnings

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.core.profiles as profiles_mod
from repro.core import CATALOG, Murakkab, Work
from repro.core.profiles import CostQuery

V5E = CATALOG["tpu-v5e"]
CPU = CATALOG["epyc-7v12-core"]     # link_bw == 0: the masked-lane regime


def _system():
    return Murakkab.tpu_cluster()


def _queries(impl, work, spec=V5E, *, counts=(1, 2, 4), batches=(1, 3, 8),
             items=17):
    return [CostQuery(impl=impl, spec=spec, n_devices=n, work=work,
                      batch=b, items=items)
            for n in counts for b in batches]


def _check_step_identity(prof, queries):
    """Batch result == scalar result, element by element, bit for bit."""
    got = prof.step_latency_batch(queries)
    prof.cache_reset()
    want = [prof.step_latency(q) for q in queries]
    assert got == want     # exact: same floats, not approx
    return got


# -- the four pricing regimes -------------------------------------------------


def test_analytic_phased_regime_bitwise_identical():
    """Prefill/decode-split works: the numpy roofline lanes match scalar."""
    sys_ = _system()
    impl = sys_.library.impls["gemma2-9b"]
    _check_step_identity(sys_.profiles, _queries(impl, impl.work_fn(700, 90)))


def test_analytic_alpha_regime_bitwise_identical():
    """Phase-less works: vectorized roofline base, scalar ``b ** alpha``."""
    sys_ = _system()
    impl = sys_.library.impls["dense-retrieval"]
    work = impl.work_fn(700, 90)
    assert not work.has_phases
    _check_step_identity(sys_.profiles, _queries(impl, work))


def test_pinned_curve_regime_bitwise_identical():
    """Measured curves stay on the scalar path (log-log interp is libm)."""
    sys_ = _system()
    impl = sys_.library.impls["gemma2-9b"]
    sys_.profiles.pin("gemma2-9b", V5E.name, 4,
                      {1: 0.9, 8: 0.2, 64: 0.12})
    _check_step_identity(sys_.profiles, _queries(impl, impl.work_fn(700, 90),
                                                 counts=(2, 4, 8)))


def test_pinned_single_point_regime_warns_and_matches():
    """Single-point pins: alpha fallback, one deprecation warning, equal."""
    sys_ = _system()
    impl = sys_.library.impls["gemma2-9b"]
    sys_.profiles.pin("gemma2-9b", V5E.name, 4, 0.75)
    with pytest.warns(DeprecationWarning, match="batch_alpha"):
        _check_step_identity(sys_.profiles,
                             _queries(impl, impl.work_fn(700, 90),
                                      counts=(4,), batches=(2, 8)))


def test_zero_link_bw_lanes_match_scalar_mask():
    """spec.link_bw == 0 zeroes the collective term, exactly like the
    scalar conditional — even with nonzero coll_bytes in the work."""
    sys_ = _system()
    impl = sys_.library.impls["dense-retrieval"]
    work = Work(flops=3e12, hbm_bytes=5e10, coll_bytes=7e9)
    phased = Work.two_phase(2e12, 9e12, 1e10, 4e10, 2e10, 90,
                            coll_bytes=7e9)
    qs = _queries(impl, work, spec=CPU) + _queries(impl, phased, spec=CPU) \
        + _queries(impl, phased, spec=V5E)
    _check_step_identity(sys_.profiles, qs)


# -- kernel mechanics ---------------------------------------------------------


def test_mixed_regimes_one_call_preserves_order():
    """One call spanning all regimes returns results in query order."""
    sys_ = _system()
    prof = sys_.profiles
    prof.pin("gemma2-9b", V5E.name, 2, {1: 0.9, 8: 0.2})
    gem = sys_.library.impls["gemma2-9b"]
    ret = sys_.library.impls["dense-retrieval"]
    qs = (_queries(gem, gem.work_fn(700, 90), counts=(1, 2))     # pin+phased
          + _queries(ret, ret.work_fn(700, 90))                  # alpha
          + _queries(ret, ret.work_fn(10, 5), spec=CPU))         # masked
    _check_step_identity(prof, qs)


def test_batch_call_feeds_the_shared_memo():
    """After one batch call, every scalar re-ask is a memo hit — and the
    cached value is the one the scalar path would have computed."""
    sys_ = _system()
    prof = sys_.profiles
    impl = sys_.library.impls["gemma2-9b"]
    qs = _queries(impl, impl.work_fn(700, 90))
    got = prof.step_latency_batch(qs)
    prof.cache_hits = prof.cache_misses = 0
    assert [prof.step_latency(q) for q in qs] == got
    assert prof.cache_hits == len(qs) and prof.cache_misses == 0


def test_schedule_batch_matches_scalar_schedule():
    """Full + remainder recomposition is the scalar float-op sequence."""
    sys_ = _system()
    prof = sys_.profiles
    impl = sys_.library.impls["gemma2-9b"]
    work = impl.work_fn(700, 90)
    qs = [CostQuery(impl=impl, spec=V5E, n_devices=n, work=work,
                    batch=b, items=i)
          for n in (1, 4) for b in (1, 3, 8) for i in (0, 1, 7, 24)]
    got = prof.schedule_latency_batch(qs)
    prof.cache_reset()
    assert got == [prof.schedule_latency(q) for q in qs]


def test_cache_hit_frac_discount_flows_through():
    """The prefill discount prices through effective_work, both paths."""
    sys_ = _system()
    prof = sys_.profiles
    impl = sys_.library.impls["gemma2-9b"]
    work = impl.work_fn(8000, 4)     # prompt-heavy: prefill dominates
    qs = [CostQuery(impl=impl, spec=V5E, n_devices=2, work=work,
                    batch=4, items=11, cache_hit_frac=f)
          for f in (0.0, 0.35, 0.9)]
    got = prof.schedule_latency_batch(qs)
    prof.cache_reset()
    assert got == [prof.schedule_latency(q) for q in qs]
    assert got[0] > got[1] > got[2]     # the discount actually discounts


def test_kernel_without_numpy_falls_back_to_scalar(monkeypatch):
    """``_np is None`` (numpy absent): identical answers, scalar route."""
    sys_ = _system()
    prof = sys_.profiles
    impl = sys_.library.impls["gemma2-9b"]
    qs = _queries(impl, impl.work_fn(700, 90))
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # fallback must stay silent
        monkeypatch.setattr(profiles_mod, "_np", None)
        got = prof.step_latency_batch(qs)
    prof.cache_reset()
    assert got == [prof.step_latency(q) for q in qs]


def test_empty_batch_is_a_no_op():
    prof = _system().profiles
    assert prof.step_latency_batch([]) == []
    assert prof.schedule_latency_batch([]) == []


def test_batch_rejects_positional_form():
    prof = _system().profiles
    with pytest.raises(TypeError, match="CostQuery"):
        prof.step_latency_batch([("gemma2-9b", V5E, 1)])


@settings(max_examples=40, deadline=None)
@given(st.floats(1e9, 1e15), st.floats(1e9, 1e15),
       st.floats(0.0, 1e12), st.floats(0.0, 1e12),
       st.floats(1e8, 2e11), st.integers(1, 512),
       st.integers(1, 16), st.integers(1, 64))
def test_property_phased_kernel_bitwise(pf, df, pb, db, wb, steps, n, b):
    """Random phased works: the numpy lane equals the scalar float."""
    sys_ = _system()
    impl = sys_.library.impls["gemma2-9b"]
    work = Work.two_phase(pf, df, pb, db, wb, steps)
    q = CostQuery(impl=impl, spec=V5E, n_devices=n, work=work, batch=b)
    got = sys_.profiles.step_latency_batch([q])[0]
    sys_.profiles.cache_reset()
    assert got == sys_.profiles.step_latency(q)
