"""Dry-run machinery: HLO collective parsing + a subprocess mini dry-run
(8 host devices) exercising lower+compile for dense/moe/ssm archs."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import parse_collectives

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestParseCollectives:
    def test_counts_and_bytes(self):
        hlo = textwrap.dedent("""\
            %ag = f32[4,256]{1,0} all-gather(f32[1,256] %x), replica_groups={{0,1,2,3}}, dimensions={0}
            %ar = bf16[1024]{0} all-reduce(bf16[1024] %y), replica_groups=[2,8]<=[16], to_apply=%add
            %d = f32[8]{0} add(f32[8] %a, f32[8] %b)
        """)
        out = parse_collectives(hlo)
        assert out["all-gather"]["count"] == 1
        assert out["all-gather"]["raw_bytes"] == 4 * 256 * 4
        assert out["all-reduce"]["count"] == 1
        assert out["all-reduce"]["raw_bytes"] == 1024 * 2
        assert out["reduce-scatter"]["count"] == 0
        assert out["total_bytes"] > 0

    def test_traffic_factors(self):
        from repro.launch.dryrun import _traffic_factor
        assert _traffic_factor("all-gather", 4) == pytest.approx(0.75)
        assert _traffic_factor("all-reduce", 4) == pytest.approx(1.5)
        assert _traffic_factor("collective-permute", 4) == 1.0
        assert _traffic_factor("all-reduce", 1) == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-moe-16b",
                                  "mamba2-370m"])
def test_mini_dryrun_subprocess(arch):
    """lower+compile a reduced config on an 8-device host mesh, both the
    train and decode step (the real dry-run entrypoints, small)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_config
        from repro.models.model_zoo import build_model
        from repro.launch.mesh import make_mesh
        from repro.runtime import train as train_rt, serve as serve_rt

        cfg = get_config({arch!r}, reduced=True)
        model = build_model(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        B, S = 8, 32
        opts = train_rt.TrainOptions(remat_policy=None)
        batch_abs = {{"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     **model.extra_inputs(B, S, abstract=True)}}
        # jax.set_mesh arrived in 0.6; older jax uses the Mesh as context
        mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
        with mesh_ctx:
            fn = train_rt.jit_train_step(model, opts, mesh, batch_abs)
            st_abs = train_rt.abstract_train_state(model, opts)
            lowered = fn.lower(st_abs, batch_abs)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            dfn, (p_abs, c_abs) = serve_rt.jit_decode_step(
                model, serve_rt.ServeOptions(), mesh, B, S,
                enc_len=S if cfg.family == "encdec" else 0)
            dfn.lower(p_abs, c_abs,
                      jax.ShapeDtypeStruct((B, 1), jnp.int32),
                      jax.ShapeDtypeStruct((), jnp.int32)).compile()
        print(json.dumps({{"flops": float(cost.get("flops", 0.0))}}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
