"""KV/prefix-cache residency + CostQuery API (DESIGN.md §9).

Four load-bearing properties:

1. **CostQuery surface** — the query object is the only ``ProfileStore``
   entry point; the PR 7 positional shims and ``latency`` are removed.
2. **Hit pricing** — warm prefill is never dearer than cold, cold pricing
   is *byte-identical* to the pre-cache model (``effective_work`` returns
   the same object at hit 0), and the discount is monotone in the hit
   fraction.
3. **Cache ledger** — residency never exceeds the HBM budget, eviction is
   LRU, the session index mirrors the per-instance entries (``audit``),
   and eviction/preemption drops a shell's entries with it.
4. **Serving economics** — the chat session stream: a turn's cached
   tokens are exactly the next turn's prefix, affinity placement beats
   cache-blind on p95 and energy, and cache-less streams stay
   byte-identical with the KV machinery on or off (reference and fast
   dispatch paths).
"""
import warnings

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.configs.workflow_chat as chat
import repro.configs.workflow_rag  # noqa: F401
from repro.core import CATALOG, Murakkab, Work
from repro.core.arrivals import (SERVING_PRESETS, PoissonArrivals,
                                 SessionArrivals)
from repro.core.cluster import (ClusterManager, Instance, Pool,
                                kv_cache_cap)
from repro.core.profiles import CostQuery

V5E = CATALOG["tpu-v5e"]


def _store():
    system = Murakkab.tpu_cluster()
    return system, system.profiles, system.library.impls["gemma2-9b-digest"]


def _chat_impl():
    system = Murakkab.tpu_cluster()
    return system, system.profiles, \
        system.library.impls["deepseek-7b-chat"]


def _query(impl, work, **kw):
    return CostQuery(impl=impl, spec=V5E, n_devices=1, work=work, **kw)


# -- 1. CostQuery is the only ProfileStore surface ---------------------------

def test_positional_forms_removed():
    """The PR 7 deprecation shims are gone: positional calls raise a
    TypeError that names the replacement, and ``latency`` no longer
    exists."""
    _, prof, impl = _store()
    work = impl.work_fn(700, 90)
    with pytest.raises(TypeError):
        prof.step_latency(impl, V5E, 1, work, 8)
    with pytest.raises(TypeError):
        prof.schedule_latency(impl, V5E, 1, work, 8, 50)
    with pytest.raises(TypeError):
        prof.completed_items(impl, V5E, 1, work, 8, 50, 1.0)
    # a non-query argument gets the explanatory error, not an AttributeError
    with pytest.raises(TypeError, match="CostQuery"):
        prof.step_latency(impl)
    with pytest.raises(TypeError, match="CostQuery"):
        prof.schedule_latency(impl)
    with pytest.raises(TypeError, match="CostQuery"):
        prof.completed_items(impl)
    assert not hasattr(prof, "latency")


def test_query_form_is_warning_free():
    _, prof, impl = _store()
    work = impl.work_fn(700, 90)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        prof.step_latency(_query(impl, work, batch=4))
        prof.schedule_latency(_query(impl, work, batch=4, items=9))
        prof.completed_items(_query(impl, work, batch=4, items=9,
                                    elapsed_s=1.0))


def test_cache_hit_frac_validated():
    _, _, impl = _store()
    work = impl.work_fn(700, 90)
    for bad in (-0.1, 1.0001, 7.0):
        with pytest.raises(ValueError, match="cache_hit_frac"):
            _query(impl, work, cache_hit_frac=bad)


# -- 2. hit-rate-dependent prefill pricing -----------------------------------

def test_effective_work_cold_path_is_same_object():
    """hit 0 returns the *identical* Work — cache-less pricing cannot
    drift from the pre-cache model by even a float rounding."""
    _, _, impl = _store()
    work = impl.work_fn(700, 90)
    assert _query(impl, work).effective_work() is work
    flat = Work(flops=1e12, hbm_bytes=1e9)      # no phase split
    assert _query(impl, flat, cache_hit_frac=0.9).effective_work() is flat


@settings(max_examples=40, deadline=None)
@given(st.integers(64, 20_000), st.integers(1, 256),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_warm_never_dearer_and_monotone(tin, tout, h1, h2):
    """Warm schedule latency <= cold, and non-increasing in hit frac."""
    _, prof, impl = _chat_impl()
    work = impl.work_fn(tin, tout)
    lo, hi = sorted((h1, h2))
    cold = prof.schedule_latency(_query(impl, work, batch=4, items=8))
    warm_lo = prof.schedule_latency(
        _query(impl, work, batch=4, items=8, cache_hit_frac=lo))
    warm_hi = prof.schedule_latency(
        _query(impl, work, batch=4, items=8, cache_hit_frac=hi))
    assert warm_hi <= warm_lo <= cold
    if lo == 0.0:
        assert warm_lo == cold


def test_chat_geometry_hit_discount_is_strict():
    """The chat interface is prefill-compute-bound by design — a warm
    prefix must make the step *strictly* cheaper there (a decode-bound
    geometry would hide the discount behind the weight-stream term)."""
    _, prof, impl = _chat_impl()
    work = impl.work_fn(chat.SYSTEM_TOKENS + chat.MESSAGE_TOKENS,
                        chat.REPLY_TOKENS)
    cold = prof.step_latency(_query(impl, work))
    warm = prof.step_latency(_query(impl, work, cache_hit_frac=0.9))
    assert warm < cold * 0.6


# -- 3. the cache ledger ------------------------------------------------------

def _cm_with_shell(cap_tokens=10, kv_per_tok=1.0):
    """A one-pool cluster holding one warm shell with a tiny KV budget."""
    cm = ClusterManager([Pool("tpu", "tpu-v5e", capacity=8)])
    lease = cm.alloc("tpu", 2, t=0.0)
    inst = Instance("m", "tpu", 2, lease=lease,
                    cache_cap_bytes=float(cap_tokens) * kv_per_tok)
    cm.add_instance(inst)
    return cm, inst


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(1, 6)),
                min_size=1, max_size=40))
def test_residency_never_exceeds_budget_and_audit_holds(inserts):
    """Any insert sequence: residency <= cap, index consistent (audit)."""
    cm, inst = _cm_with_shell(cap_tokens=10)
    t = 0.0
    for sid, tokens in inserts:
        t += 1.0
        cm.cache_insert(inst, f"s{sid}", tokens, float(tokens), t)
        assert cm.cache_residency(inst) <= inst.cache_cap_bytes
        cm.audit()
    for session, entry in inst.cache.items():
        assert inst in cm.cached_instances(session)
        assert cm.cache_tokens(inst, session) == entry.tokens


def test_lru_eviction_order_and_touch():
    cm, inst = _cm_with_shell(cap_tokens=10)
    cm.cache_insert(inst, "a", 4, 4.0, t=1.0)
    cm.cache_insert(inst, "b", 4, 4.0, t=2.0)
    cm.cache_touch(inst, "a", t=3.0)            # b is now the LRU entry
    assert cm.cache_insert(inst, "c", 4, 4.0, t=4.0)
    assert set(inst.cache) == {"a", "c"}        # b evicted, not a
    assert cm.cached_instances("b") == []
    cm.audit()


def test_oversized_and_budget_less_entries_rejected():
    cm, inst = _cm_with_shell(cap_tokens=10)
    assert not cm.cache_insert(inst, "big", 11, 11.0, t=1.0)
    assert inst.cache == {}
    inst.cache_cap_bytes = 0.0                  # tool-like impl: no KV
    assert not cm.cache_insert(inst, "s", 1, 1.0, t=2.0)
    assert not cm.cache_insert(inst, "", 1, 1.0, t=3.0)   # sessionless


def test_audit_catches_planted_cache_violations():
    cm, inst = _cm_with_shell(cap_tokens=10)
    cm.cache_insert(inst, "a", 4, 4.0, t=1.0)
    inst.cache["a"].bytes = 99.0                # blow the budget
    with pytest.raises(AssertionError):
        cm.audit()
    inst.cache["a"].bytes = 4.0
    cm._cache_index["ghost"] = [inst]           # index without an entry
    with pytest.raises(AssertionError):
        cm.audit()


def test_eviction_and_preemption_drop_resident_prefixes():
    """A shell's entries die with it — the preemption path's guarantee."""
    cm, inst = _cm_with_shell(cap_tokens=10)
    cm.cache_insert(inst, "a", 4, 4.0, t=1.0)
    cm.cache_insert(inst, "b", 4, 4.0, t=2.0)
    cm.evict_instance(inst, t=3.0)
    assert cm.cached_instances("a") == [] and cm.cached_instances("b") == []
    assert cm.free("tpu") == 8
    cm.audit()


def test_rebalance_keeps_cached_shells():
    """Zero pending demand reclaims idle shells — except those pinning
    session prefixes (think-time gaps hide returning demand)."""
    from repro.core.dag import DAG, TaskNode
    system = Murakkab.tpu_cluster()
    cm, lib = system.cluster, system.library
    lease = cm.alloc("v5e", 2, t=0.0)
    inst = Instance("deepseek-7b-chat", "v5e", 2, lease=lease,
                    cache_cap_bytes=1e9)
    cm.add_instance(inst)
    bare = Instance("deepseek-7b-chat", "v5e", 2,
                    lease=cm.alloc("v5e", 2, t=0.0))
    cm.add_instance(bare)
    cm.cache_insert(inst, "s0", 100, 1e6, t=0.0)
    # drive the demand ledger to zero for chat_respond: register one
    # turn's workflow and complete it (think-time gap: nothing pending)
    dag = DAG([TaskNode(id="r", description="", agent="chat_respond")])
    cm.register_workflow("wf", dag)
    cm.complete_task("wf", "r")
    actions = cm.rebalance(lib, t=10.0)
    assert any("deepseek-7b-chat" in a for a in actions)   # bare reclaimed
    assert inst in cm.instances and bare not in cm.instances


def test_kv_cache_cap_arithmetic():
    _, _, impl = _chat_impl()
    cap = kv_cache_cap(V5E, 2, impl.params_bytes, impl.kv_bytes_per_token)
    assert cap == pytest.approx(
        (V5E.hbm_bytes * 2 - impl.params_bytes) * 0.9)
    assert kv_cache_cap(V5E, 2, impl.params_bytes, 0.0) == 0.0
    assert kv_cache_cap(V5E, 1, V5E.hbm_bytes * 2, 1.0) == 0.0  # no room


# -- 4. serving economics on the chat stream ---------------------------------

def _system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128)


def _chat_stream(seed=7, rate=0.2):
    return SessionArrivals(rate, scenario="chat", mean_turns=6.0,
                           think_time_s=30.0, seed=seed)


def _chat_run(affinity=True, kv=True, fast=True, horizon=600.0):
    return _system().open_loop(
        _chat_stream(), horizon_s=horizon, warmup_s=60.0,
        presets={"chat": SERVING_PRESETS["chat"]}, fast_dispatch=fast,
        kv_cache=kv, cache_affinity=affinity)


def test_chat_prefix_is_exactly_the_prior_turns():
    """The config's token identity: turn k's full prompt+reply == turn
    k+1's history == the prefix a resident session cache can serve."""
    system = _system()
    for k in range(4):
        dag = system.lower(chat.make_chat_job(session="s", turn=k))
        node = next(n for n in dag.nodes.values()
                    if n.agent == "chat_respond")
        hist = chat.SYSTEM_TOKENS \
            + k * (chat.MESSAGE_TOKENS + chat.REPLY_TOKENS)
        assert node.prefix_tokens == hist
        assert node.tokens_in == hist + chat.MESSAGE_TOKENS
        # cached after this turn = tin + tout = next turn's prefix
        assert node.tokens_in + node.tokens_out == hist \
            + chat.MESSAGE_TOKENS + chat.REPLY_TOKENS


def test_prefix_tokens_in_node_signature():
    """Prefix changes re-key the node — plan caches cannot alias turns."""
    system = _system()
    d0 = system.lower(chat.make_chat_job(session="s", turn=0))
    d1 = system.lower(chat.make_chat_job(session="s", turn=1))
    n0 = next(n for n in d0.nodes.values() if n.agent == "chat_respond")
    n1 = next(n for n in d1.nodes.values() if n.agent == "chat_respond")
    assert d0.signature() != d1.signature()
    assert n0.prefix_tokens != n1.prefix_tokens


def test_scheduler_prices_resident_prefix_into_the_plan():
    """With a warm session prefix on the cluster, the planner's estimate
    for that session is cheaper than a cold session's."""
    system = _system()
    job = chat.make_chat_job(session="warm", turn=3)
    dag = system.lower(job)
    node = next(n for n in dag.nodes.values() if n.agent == "chat_respond")
    cm = system.cluster
    lease = cm.alloc("v5e", 2, t=0.0)
    impl = system.library.impls["deepseek-7b-chat"]
    inst = Instance("deepseek-7b-chat", "v5e", 2, lease=lease,
                    cache_cap_bytes=kv_cache_cap(
                        V5E, 2, impl.params_bytes, impl.kv_bytes_per_token))
    cm.add_instance(inst)
    cm.cache_insert(inst, "warm", node.prefix_tokens,
                    impl.kv_bytes_per_token * node.prefix_tokens, t=0.0)
    from repro.core.constraints import MIN_COST
    sched = system.scheduler
    floor = {"chat_respond": 0.85, "embed": 0.85}
    warm = sched.plan(dag, MIN_COST, floor, session="warm")
    cold = sched.plan(dag, MIN_COST, floor, session="cold")
    assert warm.configs[node.id].est_latency_s \
        < cold.configs[node.id].est_latency_s
    assert warm.configs[node.id].impl == "deepseek-7b-chat"


def test_chat_affinity_beats_blind_end_to_end():
    """The PR's headline on a short stream: affinity wins p95 AND energy
    at equal-or-better priority attainment, with a real hit rate."""
    warm = _chat_run(affinity=True)
    cold = _chat_run(affinity=False)
    assert warm.cache_hit_rate > cold.cache_hit_rate > 0.0
    assert warm.prefill_tokens_saved > cold.prefill_tokens_saved > 0.0
    assert warm.energy_wh < cold.energy_wh
    w_att = warm.per_class["priority"]["slo_attainment"]
    c_att = cold.per_class["priority"]["slo_attainment"]
    assert w_att >= c_att


# (the chat fast-vs-reference byte-identity witness moved to
# test_engine_identity.py, parametrized with the other three scenarios)


def test_cacheless_stream_unchanged_by_kv_machinery():
    """Digest-style scenarios declare no KV footprint: the trace with the
    cache subsystem enabled is byte-identical to it disabled, on both
    dispatch paths — the PR 6 baselines cannot move."""
    def run(kv, fast):
        return _system().open_loop(
            PoissonArrivals(rate_per_s=0.25, mix={"rag": 1.0}, seed=4),
            horizon_s=300.0, warmup_s=30.0, kv_cache=kv,
            fast_dispatch=fast)
    on, off = run(True, True), run(False, True)
    assert on.trace == off.trace
    assert on.energy_wh == off.energy_wh
    assert on.per_class == off.per_class
    assert on.cache_hit_rate == 0.0 == off.cache_hit_rate
    ref = run(True, False)
    assert on.trace == ref.trace and on.energy_wh == ref.energy_wh
