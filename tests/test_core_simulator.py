"""Simulator invariants: dependency order, capacity safety, energy/time
accounting consistency, warm-instance reuse (+ hypothesis over random DAGs)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import MIN_COST, MIN_LATENCY, Murakkab
from repro.core.dag import DAG, TaskNode
from repro.core.simulator import Simulator
from repro.core.workflow import Job, VideoInput
from repro.configs.workflow_video import make_declarative_job


def _run(system, job):
    dag, plan = system.plan(job)
    sim = Simulator(system.cluster, system.library, system.profiles)
    return dag, plan, sim.run({"wf": (dag, plan, 0.0)})


@pytest.fixture()
def system():
    return Murakkab.tpu_cluster(v5e=32, v5p=0, v4_harvest=0, host_cores=64)


def test_simulator_import_shim_is_warning_free():
    """``repro.core.simulator`` is the stable import surface over the
    ``core/engine`` package (DESIGN.md §12): a fresh import of every
    public name must emit no warnings — no deprecation shims, no lazy
    fallbacks — and the façade must re-export the engine's report types
    unchanged."""
    import importlib
    import warnings

    import repro.core.simulator as shim
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mod = importlib.reload(shim)
        for name in ("Simulator", "Submission", "SimReport",
                     "OpenLoopReport", "TraceEntry", "render_trace"):
            assert getattr(mod, name) is not None
    from repro.core.engine import OpenLoopReport, SimReport
    assert mod.SimReport is SimReport
    assert mod.OpenLoopReport is OpenLoopReport


def test_dependency_order(system):
    dag, plan, rep = _run(system, make_declarative_job())
    start = {e.task: e.start for e in rep.trace}
    end = {e.task: e.end for e in rep.trace}
    for tid, node in dag.nodes.items():
        for d in node.deps:
            assert start[tid] >= end[d] - 1e-9, (tid, d)


def test_capacity_never_exceeded(system):
    """At every trace instant, per-pool device usage <= capacity."""
    dag, plan, rep = _run(system, make_declarative_job())
    events = []
    for e in rep.trace:
        events.append((e.start, e.pool, e.devices))
        events.append((e.end, e.pool, -e.devices))
    for pool in system.cluster.pools.values():
        level, peak = 0, 0
        # at equal timestamps the engine releases before it starts
        for t, p, d in sorted(events, key=lambda x: (x[0], x[2])):
            if p == pool.name:
                level += d
                peak = max(peak, level)
        assert peak <= pool.capacity, pool.name


def test_energy_accounting_consistent(system):
    _, _, rep = _run(system, make_declarative_job())
    assert math.isclose(rep.energy_wh, rep.active_wh + rep.idle_wh,
                        rel_tol=1e-9)
    # idle floor = sum over metered pools of capacity * idle_w * makespan
    expect_idle = sum(p.capacity * p.spec.idle_w * rep.makespan_s / 3600.0
                      for p in system.cluster.pools.values()
                      if p.spec.metered)
    assert math.isclose(rep.idle_wh, expect_idle, rel_tol=1e-9)
    assert rep.makespan_s >= max(e.end for e in rep.trace) - 1e-9


def test_warm_instance_reuse(system):
    """Second identical job hits warm instances (no cold notes)."""
    job = make_declarative_job()
    dag, plan = system.plan(job)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({"a": (dag, plan, 0.0), "b": (dag, plan, 500.0)})
    notes = {}
    for e in rep.trace:
        if e.impl.startswith(("opencv", "clip")):
            continue
        notes.setdefault(e.workflow, []).append(e.note)
    assert "cold" in notes["a"]
    assert all(n == "warm" for n in notes["b"]), notes["b"]


def test_degradation_under_scarcity():
    """Plan asks for fan-out; a tiny cluster degrades to fewer instances
    instead of deadlocking."""
    big = Murakkab.tpu_cluster(v5e=64, v5p=0, v4_harvest=0, host_cores=64)
    job = Job(description="Describe the video",
              inputs=(VideoInput("x.mov", scenes=8),),
              constraints=MIN_LATENCY, quality_floor=0.8)
    dag, plan = big.plan(job)
    small = Murakkab.tpu_cluster(v5e=2, v5p=0, v4_harvest=0, host_cores=8)
    sim = Simulator(small.cluster, small.library, small.profiles)
    rep = sim.run({"wf": (dag, plan, 0.0)})
    assert {e.task for e in rep.trace} == set(dag.nodes)   # all ran


def test_multitenant_arrivals(system):
    jobs = {f"w{i}": (make_declarative_job(), 5.0 * i) for i in range(3)}
    wfs = {}
    for wid, (job, arr) in jobs.items():
        dag, plan = system.plan(job)
        wfs[wid] = (dag, plan, arr)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run(wfs)
    for i in range(3):
        assert rep.per_workflow[f"w{i}"]["finish"] >= 5.0 * i
    assert rep.makespan_s == max(v["finish"] for v in
                                 rep.per_workflow.values())


@given(st.integers(1, 6), st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_chain_makespan_additivity(n_chain, n_par):
    """A chain's makespan >= sum of its stage durations; independent tasks
    overlap (makespan < sum)."""
    system = Murakkab.tpu_cluster(v5e=32, v5p=0, v4_harvest=0, host_cores=64)
    nodes = []
    for i in range(n_chain):
        nodes.append(TaskNode(id=f"c{i}", description="", agent="summarize",
                              deps=(f"c{i-1}",) if i else (),
                              work_items=2, tokens_in=400, tokens_out=60))
    for j in range(n_par):
        nodes.append(TaskNode(id=f"p{j}", description="",
                              agent="speech_to_text", work_items=2))
    dag = DAG(nodes)
    plan = system.scheduler.plan(dag, (MIN_COST,), 0.0)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({"wf": (dag, plan, 0.0)})
    chain_time = sum(e.end - e.start for e in rep.trace
                     if e.task.startswith("c"))
    assert rep.makespan_s >= chain_time - 1e-6
    total = sum(e.end - e.start for e in rep.trace)
    if n_par:
        assert rep.makespan_s < total + 1e-6
