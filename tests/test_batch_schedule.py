"""Remainder-step schedule + joint (count x batch) search + pinned batch
curves (DESIGN.md §7.2): edge cases, the never-worse-than-ceil property,
estimate/actual parity with remainders, curve interpolation, and joint
search dominating the sequential lever order."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CATALOG, Murakkab, Work
from repro.core.dag import TaskNode
from repro.core.energy import knee_batch_grid
from repro.core.profiles import CostQuery, _as_curve, _curve_per_item
from repro.core.simulator import Simulator

V5E = CATALOG["tpu-v5e"]


def _q(impl, work, *, batch=1, items=1, elapsed=0.0):
    return CostQuery(impl=impl, spec=V5E, n_devices=1, work=work,
                     batch=batch, items=items, elapsed_s=elapsed)


def _work(pf, df, pb, db, wb, steps):
    return Work.two_phase(prefill_flops=pf, decode_flops=df,
                          prefill_bytes=pb, decode_bytes=db,
                          weight_bytes=wb, decode_steps=steps)


WORK_STRATS = (st.floats(1e9, 1e15), st.floats(1e9, 1e15),
               st.floats(0.0, 1e12), st.floats(0.0, 1e12),
               st.floats(1e8, 2e11), st.integers(1, 512))


def _store():
    system = Murakkab.tpu_cluster()
    return system, system.profiles, system.library.impls["gemma2-9b-digest"]


# -- the remainder schedule ---------------------------------------------------


def test_schedule_exact_multiple_is_full_steps_only():
    """items % b == 0: the schedule is exactly items/b full steps."""
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    step = prof.step_latency(_q(impl, work, batch=8))
    assert prof.schedule_latency(_q(impl, work, batch=8, items=64)) == \
        pytest.approx(8 * step, rel=1e-12)


def test_schedule_items_below_batch_charges_one_small_step():
    """items < b: one step at the *items'* price, not the full batch's."""
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    got = prof.schedule_latency(_q(impl, work, batch=64, items=10))
    assert got == pytest.approx(prof.step_latency(_q(impl, work, batch=10)),
                                rel=1e-12)
    # strictly cheaper than the legacy full-step charge (10 items are
    # weights-streaming-bound well below the 64-batch compute time)
    assert got < prof.step_latency(_q(impl, work, batch=64))


def test_schedule_batch_one_is_per_item_sum():
    """b == 1: items sequential unbatched steps."""
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    assert prof.schedule_latency(_q(impl, work, batch=1, items=7)) == \
        pytest.approx(7 * prof.step_latency(_q(impl, work, batch=1)),
                      rel=1e-12)


def test_schedule_zero_items_is_free():
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    assert prof.schedule_latency(_q(impl, work, batch=8, items=0)) == 0.0


@settings(max_examples=60)
@given(*WORK_STRATS, st.integers(1, 7), st.integers(1, 300))
def test_schedule_never_exceeds_ceil_full_step_charge(pf, df, pb, db, wb,
                                                      steps, log_b, items):
    """The remainder schedule never exceeds the legacy ``ceil(items/b)``
    full-step charge it replaces — splitting the tail can only shave."""
    system, prof, impl = _store()
    w = _work(pf, df, pb, db, wb, steps)
    b = 2 ** log_b
    sched = prof.schedule_latency(_q(impl, w, batch=b, items=items))
    old = math.ceil(items / b) * prof.step_latency(_q(impl, w, batch=b))
    assert sched <= old * (1 + 1e-12)


def test_remainder_shaves_strictly_below_knee():
    """A remainder below the knee runs at its own (smaller) step price."""
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    b, items = 64, 70       # remainder 6, far below the knee
    sched = prof.schedule_latency(_q(impl, work, batch=b, items=items))
    old = math.ceil(items / b) * prof.step_latency(_q(impl, work, batch=b))
    assert sched < old * 0.99


def test_estimate_actual_parity_with_remainder():
    """Scheduler estimate == simulator actual for a remainder schedule."""
    system, prof, impl = _store()
    node = TaskNode(id="t", description="", agent="digest", work_items=70,
                    chunkable=True, tokens_in=700, tokens_out=90)
    cfg = system.scheduler.estimate(node, impl, "v5e", 1, batch=32)
    sim = Simulator(system.cluster, system.library, system.profiles)
    dur, compute, _ = sim._duration(node, cfg, n_inst=1, new_instances=1)
    assert dur == pytest.approx(cfg.est_latency_s, rel=1e-12)
    assert compute == pytest.approx(cfg.est_latency_s - impl.load_time_s,
                                    rel=1e-12)


# -- the knee-derived batch grid ----------------------------------------------


def test_knee_grid_contains_endpoints_and_divisor():
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    grid = knee_batch_grid(work, V5E, 72, 64, impl.mxu_efficiency)
    assert grid[0] == 1 and grid[-1] == 64      # endpoints
    assert all(1 <= b <= 64 for b in grid)
    # a zero-remainder divisor of 72 at/past the knee made the grid
    assert any(72 % b == 0 and b > 1 for b in grid)
    assert grid == sorted(set(grid))


def test_knee_grid_degenerate_cases():
    system, prof, impl = _store()
    work = impl.work_fn(700, 90)
    assert knee_batch_grid(work, V5E, 1, 64) == [1]       # single item
    assert knee_batch_grid(work, V5E, 100, 1) == [1]      # unbatchable
    tool = system.library.impls["opencv"].work_fn(0, 0)   # no phase split
    assert knee_batch_grid(tool, V5E, 100, 16) == [1, 16]


# -- pinned batch curves ------------------------------------------------------


def test_pinned_curve_interpolates_power_law_exactly():
    """Log-log interpolation through power-law points reproduces the legacy
    alpha model at every batch size — calibrations migrate loss-free."""
    system, prof, impl = _store()
    alpha = 0.15
    curve = {b: 0.5 * b ** (alpha - 1) for b in (1, 8, 128)}
    prof.pin(impl.name, "tpu-v5e", 1, curve)
    work = impl.work_fn(700, 90)
    for b in (1, 3, 8, 20, 77, 128):
        assert prof.step_latency(_q(impl, work, batch=b)) == \
            pytest.approx(0.5 * b ** alpha, rel=1e-9)
    # clamped flat (per-item) beyond the measured range
    assert prof.step_latency(_q(impl, work, batch=256)) == \
        pytest.approx(256 * 0.5 * 128 ** (alpha - 1), rel=1e-9)


def test_single_point_pin_must_anchor_at_batch_one():
    """A lone measurement at batch != 1 cannot feed the alpha fallback
    (it would be misread as the batch-1 anchor and misprice every step)."""
    with pytest.raises(ValueError):
        _as_curve({4: 0.5})


def test_plan_cache_keyed_on_search_mode():
    """Toggling joint_batch must not serve stale cross-mode plans."""
    from repro.core import MIN_LATENCY
    from repro.configs.workflow_docingest import make_docingest_job
    system = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                  host_cores=32)
    job = make_docingest_job(MIN_LATENCY)
    dag = system.lower(job)
    system.plan_admitted(dag, job)
    system.scheduler.joint_batch = False
    system.plan_admitted(dag, job)
    assert system.plan_cache_hits == 0
    assert system.plan_cache_misses == 2


def test_pinned_curve_normalizes_noise_and_rejects_superlinear():
    # a noisy bump is absorbed by the running minimum
    assert _as_curve({1: 1.0, 4: 0.5, 8: 0.6}) == ((1, 1.0), (4, 0.5),
                                                   (8, 0.5))
    with pytest.raises(ValueError):
        _as_curve({1: 1.0, 8: 0.05})    # 8x batch in 0.4x wall time
    with pytest.raises(ValueError):
        _as_curve({})
    with pytest.raises(ValueError):
        _as_curve({0: 1.0})
    assert _curve_per_item(((1, 1.0), (4, 0.5)), 2) == \
        pytest.approx(math.exp(math.log(1.0) / 2 + math.log(0.5) / 2))


def test_single_point_pin_warns_on_batched_step():
    system, prof, impl = _store()
    prof.pin(impl.name, "tpu-v5e", 1, 0.5)
    work = impl.work_fn(700, 90)
    with pytest.warns(DeprecationWarning):
        prof.step_latency(CostQuery(impl=impl, spec=V5E, n_devices=1,
                                    work=work, batch=4))
    # curve pins do not warn
    prof.pin(impl.name, "tpu-v5p", 1, {1: 0.5, 8: 0.1})
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        prof.step_latency(CostQuery(impl=impl, spec=CATALOG["tpu-v5p"],
                                    n_devices=1, work=work, batch=4))


def test_pinned_batches_feed_the_search_grid():
    system, prof, impl = _store()
    prof.pin(impl.name, "tpu-v5e", 1, {1: 0.5, 8: 0.2, 32: 0.1})
    assert prof.pinned_batches(impl.name, "tpu-v5e") == [1, 8, 32]
    grid = system.scheduler._batch_grid(impl, V5E, impl.work_fn(700, 90),
                                        72)
    assert set(grid) >= {1, 8, 32, 64}   # calibrated points + max batch


# -- joint vs sequential search -----------------------------------------------


def _remainder_node(items=70):
    return TaskNode(id="t", description="", agent="digest",
                    work_items=items, chunkable=False, tokens_in=700,
                    tokens_out=90)


def test_joint_search_never_worse_and_shaves_remainder():
    """The joint (count x batch) search meets or beats the sequential lever
    order on the primary objective, and strictly wins on a remainder-heavy
    item count (the divisor schedule avoids a below-knee remainder step)."""
    from repro.core import MIN_COST, MIN_ENERGY, MIN_LATENCY
    for constraint in (MIN_LATENCY, MIN_COST, MIN_ENERGY):
        for items in (70, 72, 64, 100):
            joint_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                             host_cores=32)
            seq_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                           host_cores=32)
            seq_sys.scheduler.joint_batch = False
            node = _remainder_node(items)
            j = joint_sys.scheduler.plan_task(node, (constraint,), 0.85)
            s = seq_sys.scheduler.plan_task(node, (constraint,), 0.85)
            obj = joint_sys.scheduler._objective
            assert obj(j, constraint) <= obj(s, constraint) * (1 + 1e-9), \
                (constraint, items)
    # the strict win: 70 items, max batch 64 -> sequential charges a
    # 6-item below-knee remainder the joint divisor schedule avoids
    joint_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                     host_cores=32)
    seq_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                   host_cores=32)
    seq_sys.scheduler.joint_batch = False
    node = _remainder_node(70)
    j = joint_sys.scheduler.plan_task(node, (MIN_LATENCY,), 0.85)
    s = seq_sys.scheduler.plan_task(node, (MIN_LATENCY,), 0.85)
    assert j.est_latency_s < s.est_latency_s


def test_joint_search_unchanged_when_items_divide_batch():
    """No remainder, knee far below the max batch: both orders land on the
    same max-batch configuration (the joint search is a superset)."""
    from repro.core import MIN_COST
    joint_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                     host_cores=32)
    seq_sys = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0,
                                   host_cores=32)
    seq_sys.scheduler.joint_batch = False
    node = _remainder_node(64)
    j = joint_sys.scheduler.plan_task(node, (MIN_COST,), 0.85)
    s = seq_sys.scheduler.plan_task(node, (MIN_COST,), 0.85)
    assert j.est_usd <= s.est_usd * (1 + 1e-9)
