"""Cluster manager invariants: allocation safety, workflow awareness,
harvest preemption (+ hypothesis: never oversubscribe, never double-book)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.agents import default_library
from repro.core.cluster import ClusterManager, Instance, Pool
from repro.core.dag import DAG, TaskNode


def _cm(cap=16, harvest=0):
    pools = [Pool("gpu", "a100-80g", capacity=cap)]
    if harvest:
        pools.append(Pool("spot", "tpu-v4", capacity=harvest,
                          harvestable=True))
    return ClusterManager(pools)


def test_alloc_release_roundtrip():
    cm = _cm()
    lease = cm.alloc("gpu", 8, t=0.0)
    assert lease is not None and cm.free("gpu") == 8
    assert cm.alloc("gpu", 9, t=0.0) is None     # over capacity
    cm.release(lease, t=1.0)
    assert cm.free("gpu") == 16
    with pytest.raises(KeyError):
        cm.release(lease, t=2.0)                  # double release


def test_harvest_preemption():
    cm = _cm(harvest=8)
    spot = cm.alloc("spot", 8, t=0.0, harvest=True)
    assert cm.free("spot") == 0
    victims = cm.preempt_harvest("spot", 4, t=1.0)
    assert victims == [spot]
    assert cm.free("spot") == 8 and cm.preemptions == 1


def test_workflow_awareness_and_rebalance():
    lib = default_library()
    cm = _cm(cap=16)
    dag = DAG([TaskNode(id="s", description="", agent="speech_to_text"),
               TaskNode(id="m", description="", agent="summarize",
                        deps=("s",))])
    cm.register_workflow("wf", dag)
    assert cm.upcoming_demand() == {"speech_to_text": 1, "summarize": 1}

    cm.add_instance(Instance("whisper-large", "gpu", 1))
    cm.add_instance(Instance("nvlm-72b", "gpu", 8))
    # both interfaces still demanded: nothing reclaimed
    assert cm.rebalance(lib, t=0.0) == []
    cm.complete_task("wf", "s")
    actions = cm.rebalance(lib, t=1.0)           # whisper now undemanded
    assert len(actions) == 1 and "whisper-large" in actions[0]
    assert [i.impl for i in cm.instances] == ["nvlm-72b"]
    cm.complete_task("wf", "m")
    assert cm.upcoming_demand() == {}            # workflow retired


def test_stats_shape():
    cm = _cm(harvest=8)
    st_ = cm.stats()
    assert st_["gpu"]["kind"] == "gpu" and st_["gpu"]["free"] == 16
    assert st_["spot"]["harvestable"] == 8


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 8)), min_size=1,
                max_size=60))
@settings(max_examples=60, deadline=None)
def test_never_oversubscribed_property(ops):
    """Arbitrary alloc/release interleavings keep 0 <= used <= capacity."""
    cm = _cm(cap=16)
    live = []
    for is_alloc, n in ops:
        if is_alloc:
            lease = cm.alloc("gpu", n, t=0.0)
            if lease is not None:
                live.append(lease)
        elif live:
            cm.release(live.pop(), t=0.0)
        used = cm.pools["gpu"].capacity - cm.free("gpu")
        assert 0 <= used <= 16
        assert used == sum(ls.n_devices for ls in live)
