"""Adaptive multi-tenant runtime: tenant classes, admission policies,
lazy (admission-time) planning, and harvest-lease preemption."""
import dataclasses

import pytest

from repro.core import (FCFS, MIN_LATENCY, Murakkab, POLICIES,
                        StrictPriority, Submission, WeightedFair, get_policy)
from repro.core.admission import Admission
from repro.core.dag import DAG, TaskNode
from repro.core.simulator import Simulator
from repro.core.workflow import Job, VideoInput
from repro.configs.workflow_video import make_declarative_job


def _tenant_job(cls, scenes=4):
    return dataclasses.replace(
        Job(description="Describe the videos",
            inputs=(VideoInput("v.mov", scenes=scenes),),
            constraints=MIN_LATENCY, quality_floor=0.8),
        tenant_class=cls)


def _summarize_dag(tid, items):
    return DAG([TaskNode(id=tid, description="", agent="summarize",
                         work_items=items, chunkable=True,
                         tokens_in=900, tokens_out=120)])


# -- tenant classes & policy registry -----------------------------------------


def test_job_tenant_class_validated():
    assert Job(description="x").tenant_class == "standard"
    for cls in ("priority", "standard", "harvest"):
        assert Job(description="x", tenant_class=cls).tenant_class == cls
    with pytest.raises(ValueError, match="tenant class"):
        Job(description="x", tenant_class="platinum")


def test_policy_registry():
    assert isinstance(get_policy(None), FCFS)
    assert isinstance(get_policy("strict-priority"), StrictPriority)
    assert isinstance(get_policy(WeightedFair()), WeightedFair)
    assert set(POLICIES) == {"fcfs", "strict-priority", "weighted-fair"}
    with pytest.raises(ValueError, match="unknown admission policy"):
        get_policy("round-robin")


def test_policy_ordering_keys():
    early_h = Admission("h", "harvest", 0.0)
    late_p = Admission("p", "priority", 9.0)
    served = {}
    assert FCFS().key(early_h, served) < FCFS().key(late_p, served)
    sp = StrictPriority()
    assert sp.key(late_p, served) < sp.key(early_h, served)
    # weighted-fair: the class that consumed less virtual time goes first
    wf = WeightedFair({"priority": 4.0, "harvest": 1.0})
    served = {"priority": 400.0, "harvest": 10.0}
    assert wf.key(early_h, served) < wf.key(late_p, served)
    served = {"priority": 0.0, "harvest": 1000.0}
    assert wf.key(late_p, served) < wf.key(early_h, served)


# -- execute_many: admission queue + lazy planning ----------------------------


def test_execute_many_legacy_tuple_form_still_works():
    system = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0, host_cores=32)
    report = system.execute_many({
        "a": (make_declarative_job(MIN_LATENCY), 0.0),
        "b": (make_declarative_job(MIN_LATENCY), 1.0),
    })
    assert set(report.per_workflow) == {"a", "b"}
    assert all(v["tenant"] == "standard"
               for v in report.per_workflow.values())
    assert report.requeues == 0


def test_plan_fn_called_at_admission():
    """Planning is deferred to the workflow's arrival event."""
    system = Murakkab.tpu_cluster(v5e=16, v5p=0, v4_harvest=0, host_cores=32)
    dag = _summarize_dag("t", 4)
    planned_at = []

    def plan_fn():
        planned_at.append(len(planned_at))
        return system.scheduler.plan(dag, (MIN_LATENCY,), 0.8)

    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({"w": Submission(dag, None, 7.0, plan_fn=plan_fn)})
    assert planned_at == [0]            # called exactly once
    assert rep.per_workflow["w"]["start"] == 7.0


def test_submission_without_plan_or_fn_rejected():
    system = Murakkab.tpu_cluster(v5e=8, v5p=0, v4_harvest=0, host_cores=16)
    sim = Simulator(system.cluster, system.library, system.profiles)
    with pytest.raises(ValueError, match="plan"):
        sim.run({"w": Submission(_summarize_dag("t", 2), None, 0.0)})


def test_strict_priority_orders_contended_start():
    """Both tenants ready at t=0 on a pool that fits one at a time: the
    priority tenant runs first under strict-priority even though the
    harvest tenant sorts first by id/arrival."""
    def spans(policy):
        system = Murakkab.tpu_cluster(v5e=8, v5p=0, v4_harvest=0,
                                      host_cores=16)
        da, dp = _summarize_dag("a", 8), _summarize_dag("b", 8)
        sim = Simulator(system.cluster, system.library, system.profiles)
        rep = sim.run({
            "h": Submission(da, system.scheduler.plan(da, (MIN_LATENCY,),
                                                      0.8), 0.0, "harvest"),
            "p": Submission(dp, system.scheduler.plan(dp, (MIN_LATENCY,),
                                                      0.8), 0.0, "priority"),
        }, policy=policy)
        return rep.workflow_span("p"), rep.workflow_span("h")

    p_strict, h_strict = spans("strict-priority")
    p_fcfs, h_fcfs = spans("fcfs")
    assert p_strict <= p_fcfs
    assert p_strict < h_strict          # priority went first


# -- preemption ---------------------------------------------------------------


def _preemption_run(policy="strict-priority"):
    system = Murakkab.tpu_cluster(v5e=8, v5p=0, v4_harvest=0, host_cores=16)
    dh = _summarize_dag("long", 400)
    dp = _summarize_dag("quick", 4)
    plan_h = system.scheduler.plan(dh, (MIN_LATENCY,), 0.8)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({
        "h": Submission(dh, plan_h, 0.0, tenant="harvest"),
        "p": Submission(dp, None, 10.0, tenant="priority",
                        plan_fn=lambda: system.scheduler.plan(
                            dp, (MIN_LATENCY,), 0.8)),
    }, policy=policy)
    return rep


def test_priority_preempts_harvest_lease():
    rep = _preemption_run()
    assert rep.preemptions >= 1
    assert rep.requeues >= 1
    notes = [e.note for e in rep.trace]
    assert "preempted" in notes         # the truncated harvest run
    # its re-execution: the chunkable victim resumes from its checkpoint
    # (note composes the restart kind with warmth, e.g. "resume+warm")
    resumed = [n for n in notes if n.split("+")[0] == "resume"]
    assert resumed
    assert rep.resumed_items > 0
    # the priority task ran immediately at its arrival
    quick = [e for e in rep.trace if e.workflow == "p"][0]
    assert quick.start == pytest.approx(10.0)
    # the harvest workflow still finished (re-enqueued, not dropped)
    assert rep.per_workflow["h"]["finish"] > 0
    pre = [e for e in rep.trace if e.note == "preempted"][0]
    req = [e for e in rep.trace
           if e.note.split("+")[0] in ("resume", "requeue")][0]
    assert pre.end <= req.start + 1e-9  # requeue strictly after preemption


def test_preemption_energy_accounting_consistent():
    """Refund on preemption keeps energy = active + idle and both
    non-negative."""
    import math
    rep = _preemption_run()
    assert math.isclose(rep.energy_wh, rep.active_wh + rep.idle_wh,
                        rel_tol=1e-9)
    assert rep.active_wh > 0 and rep.idle_wh > 0


def test_standard_tenant_never_preempts():
    system = Murakkab.tpu_cluster(v5e=8, v5p=0, v4_harvest=0, host_cores=16)
    dh = _summarize_dag("long", 400)
    dp = _summarize_dag("quick", 4)
    plan_h = system.scheduler.plan(dh, (MIN_LATENCY,), 0.8)
    plan_p = system.scheduler.plan(dp, (MIN_LATENCY,), 0.8)
    sim = Simulator(system.cluster, system.library, system.profiles)
    rep = sim.run({
        "h": Submission(dh, plan_h, 0.0, tenant="harvest"),
        "s": Submission(dp, plan_p, 10.0, tenant="standard"),
    }, policy="strict-priority")
    assert rep.preemptions == 0
    # the standard tenant waited for the harvest task to finish
    quick = [e for e in rep.trace if e.workflow == "s"][0]
    long_end = max(e.end for e in rep.trace if e.workflow == "h")
    assert quick.start >= long_end - 1e-9


def test_capacity_safe_under_preemption():
    """Per-pool device usage never exceeds capacity across the preemption/
    requeue storm."""
    rep = _preemption_run()
    system_capacity = {"v5e": 8, "cpu": 16}
    events = []
    for e in rep.trace:
        events.append((e.start, 1, e.pool, e.devices))
        events.append((e.end, -1, e.pool, -e.devices))
    for pool, cap in system_capacity.items():
        level = 0
        for _, _, p, d in sorted(events, key=lambda x: (x[0], x[3])):
            if p == pool:
                level += d
                assert level <= cap, pool


def test_harvest_pool_rejected_for_pinned_components():
    """_resources_to_pool skips harvestable pools and errors clearly when
    only preemptible capacity matches."""
    from repro.core.cluster import ClusterManager, Pool
    from repro.core.workflow import MLModel, Workflow

    wf = Workflow(MLModel(name="Whisper", resources={"GPUs": 1}))
    only_harvest = Murakkab(ClusterManager([
        Pool("gpu_spot", "a100-80g", capacity=8, harvestable=True),
        Pool("cpu", "epyc-7v12-core", capacity=32),
    ]))
    with pytest.raises(ValueError, match="harvestable"):
        only_harvest.lower_imperative(wf, ())

    mixed = Murakkab(ClusterManager([
        Pool("gpu_spot", "a100-80g", capacity=8, harvestable=True),
        Pool("gpu", "a100-80g", capacity=8),
        Pool("cpu", "epyc-7v12-core", capacity=32),
    ]))
    _, plan = mixed.lower_imperative(wf, ())
    assert all(c.pool == "gpu" for c in plan.configs.values())
