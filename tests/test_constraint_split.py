"""Critical-path-weighted Deadline/Budget split (Scheduler.split_shares).

Property tests (deterministic hypothesis fallback via _hypothesis_compat):
over random fork/join DAGs the per-task shares must (a) hand the critical
path exactly the workflow deadline (shares sum to 1 along it), (b) dominate
the legacy even split's critical-path allotment, (c) stay feasible on every
root-to-leaf path, and (d) hand the whole budget out exactly once. The
golden video plan must keep its feasibility (a loose deadline collapses to
the MIN_COST choice).
"""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Budget, Deadline, Lexicographic, MIN_COST, MinCost,
                        MinEnergy, Murakkab)
from repro.core.constraints import as_spec
from repro.core.dag import DAG, TaskNode


def _system():
    return Murakkab.tpu_cluster(v5e=32, v5p=8, v4_harvest=0, host_cores=64)


def _fork_join_dag(n_chain: int, width: int) -> DAG:
    """chain head -> `width` parallel summarize tasks -> join tail."""
    nodes = [TaskNode(id="head", description="", agent="speech_to_text",
                      work_items=4)]
    prev = "head"
    for i in range(n_chain):
        nodes.append(TaskNode(id=f"c{i}", description="", agent="summarize",
                              deps=(prev,), work_items=2 + i,
                              tokens_in=600, tokens_out=90))
        prev = f"c{i}"
    mids = []
    for j in range(width):
        nodes.append(TaskNode(id=f"w{j}", description="", agent="embed",
                              deps=(prev,), work_items=1 + j))
        mids.append(f"w{j}")
    nodes.append(TaskNode(id="tail", description="", agent="summarize",
                          deps=tuple(mids) or (prev,), work_items=2,
                          tokens_in=400, tokens_out=60))
    return DAG(nodes)


def _paths(dag: DAG):
    """All root-to-leaf paths (the DAGs here are small)."""
    out = []

    def walk(tid, acc):
        succ = dag.successors(tid)
        if not succ:
            out.append(acc + [tid])
            return
        for s in succ:
            walk(s, acc + [tid])

    for r in dag.roots():
        walk(r, [])
    return out


SPEC = Lexicographic(Deadline(s=120.0), Budget(usd=5.0), MinCost())


@given(st.integers(0, 3), st.integers(0, 4))
@settings(max_examples=12, deadline=None)
def test_split_share_properties(n_chain, width):
    system = _system()
    dag = _fork_join_dag(n_chain, width)
    sch = system.scheduler
    shares = sch.split_shares(dag, SPEC, 0.8)
    assert set(shares) == set(dag.nodes)
    for lat_frac, cost_frac in shares.values():
        assert 0.0 < lat_frac <= 1.0 + 1e-9
        assert 0.0 <= cost_frac <= 1.0 + 1e-9

    # budget shares are a partition of the workflow budget
    assert math.isclose(sum(c for _, c in shares.values()), 1.0,
                        rel_tol=1e-9)

    # recompute the pilot latencies the shares were derived from
    pilot_spec = as_spec(SPEC).per_task(len(dag))
    pilot = {tid: sch.plan_task(dag.nodes[tid], pilot_spec, 0.8)
             for tid in dag.topo_order}
    lat = {tid: cfg.est_latency_s for tid, cfg in pilot.items()}
    _, cp = dag.critical_path(lat)

    # (a) the critical path receives exactly the workflow deadline
    cp_sum = sum(shares[tid][0] for tid in cp)
    assert math.isclose(cp_sum, 1.0, rel_tol=1e-6), (cp, cp_sum)

    # (b) ... which dominates the even split's critical-path allotment
    even_cp = len(cp) / len(dag)
    assert cp_sum >= even_cp - 1e-9

    # (c) every root-to-leaf path stays feasible under per-task deadlines
    for path in _paths(dag):
        assert sum(shares[tid][0] for tid in path) <= 1.0 + 1e-6, path


def test_single_task_gets_whole_deadline():
    system = _system()
    dag = DAG([TaskNode(id="only", description="", agent="summarize",
                        work_items=2, tokens_in=500, tokens_out=80)])
    shares = system.scheduler.split_shares(dag, SPEC, 0.8)
    lat_frac, cost_frac = shares["only"]
    assert math.isclose(lat_frac, 1.0, rel_tol=1e-9)
    assert math.isclose(cost_frac, 1.0, rel_tol=1e-9)


def test_weighted_split_admits_tighter_slo_than_even():
    """The point of the refactor: a deadline the even split turns into
    infeasible per-task targets stays feasible under the weighted split
    for the task that needs the slack most."""
    system = _system()
    dag = _fork_join_dag(2, 3)
    sch = system.scheduler
    shares = sch.split_shares(dag, SPEC, 0.8)
    pilot_spec = as_spec(SPEC).per_task(len(dag))
    pilot = {tid: sch.plan_task(dag.nodes[tid], pilot_spec, 0.8)
             for tid in dag.topo_order}
    lat = {tid: cfg.est_latency_s for tid, cfg in pilot.items()}
    _, cp = dag.critical_path(lat)
    heavy = max(cp, key=lambda tid: lat[tid])
    # the heaviest critical-path task's weighted share beats 1/n
    assert shares[heavy][0] > 1.0 / len(dag)


def test_plan_without_workflow_terms_unchanged():
    """No Deadline/Budget in the ordering -> the split machinery is
    bypassed and plans are identical to the direct per-task search."""
    system = _system()
    dag = _fork_join_dag(1, 2)
    a = system.scheduler.plan(dag, (MIN_COST,), 0.8)
    b = {tid: system.scheduler.plan_task(dag.nodes[tid],
                                         as_spec(MIN_COST), 0.8)
         for tid in dag.topo_order}
    assert a.configs == b


def test_golden_video_feasibility_unchanged():
    """A loose deadline + MinCost must reproduce the golden MIN_COST video
    plan (feasibility term at zero everywhere -> secondary decides), and
    the weighted split must keep the plan's critical path within the
    deadline for a realistic target."""
    from repro.configs.workflow_video import make_declarative_job

    golden_sys = Murakkab.paper_cluster()
    dag, golden = golden_sys.plan(make_declarative_job(MIN_COST))

    sys2 = Murakkab.paper_cluster()
    _, loose = sys2.plan(make_declarative_job(
        Lexicographic(Deadline(s=1e6), MinCost())))
    assert {t: (c.impl, c.pool, c.n_devices, c.n_instances, c.batch)
            for t, c in loose.configs.items()} == \
           {t: (c.impl, c.pool, c.n_devices, c.n_instances, c.batch)
            for t, c in golden.configs.items()}

    sys3 = Murakkab.paper_cluster()
    _, tight = sys3.plan(make_declarative_job(
        Lexicographic(Deadline(s=100.0), MinEnergy())))
    lat = {tid: c.est_latency_s for tid, c in tight.configs.items()}
    cp_s, _ = dag.critical_path(lat)
    assert cp_s <= 100.0 + 1e-6


def test_budget_split_follows_cost_share():
    """Budget caps follow pilot cost shares: the expensive stage receives
    the larger slice of the workflow budget."""
    system = _system()
    dag = _fork_join_dag(2, 0)
    sch = system.scheduler
    shares = sch.split_shares(dag, Lexicographic(Budget(usd=1.0), MinCost()),
                              0.8)
    pilot_spec = as_spec(
        Lexicographic(Budget(usd=1.0), MinCost())).per_task(len(dag))
    pilot = {tid: sch.plan_task(dag.nodes[tid], pilot_spec, 0.8)
             for tid in dag.topo_order}
    costly = max(dag.nodes, key=lambda tid: pilot[tid].est_usd)
    assert shares[costly][1] == max(c for _, c in shares.values())


def test_for_share_spec_arithmetic():
    spec = as_spec(Lexicographic(Deadline(s=40.0), Budget(usd=2.0, wh=8.0),
                                 MinCost()))
    assert spec.has_workflow_terms
    sub = spec.for_share(0.25, 0.5)
    assert sub.objectives[0] == Deadline(s=10.0)
    assert sub.objectives[1] == Budget(usd=1.0, wh=4.0)
    assert isinstance(sub.objectives[2], MinCost)
    assert not as_spec(MIN_COST).has_workflow_terms
    with pytest.raises(ValueError):
        Deadline(s=10.0).scaled(0.0, 0.5)   # zero share is degenerate
