"""The two new scenarios end-to-end on the declarative API: agentic-RAG
(with the keyword-vs-vector retrieval routing lever) and doc-ingest."""
import pytest

from repro.core import MAX_QUALITY, MIN_LATENCY, Murakkab
from repro.configs.workflow_docingest import make_docingest_job
from repro.configs.workflow_rag import make_rag_job


@pytest.mark.parametrize("make_job,agents", [
    (make_rag_job, ["retrieve", "rerank", "synthesize", "embed"]),
    (make_docingest_job, ["parse_doc", "digest", "embed"]),
])
def test_scenario_end_to_end(make_job, agents):
    """Job(...).execute(system) -> JobResult with nonzero makespan/energy and
    scheduler-chosen impls, on both reference clusters."""
    for system in (Murakkab.paper_cluster(), Murakkab.tpu_cluster()):
        result = make_job().execute(system)
        assert result.makespan_s > 0
        assert result.energy_wh > 0
        assert 0 < result.quality <= 1
        assert [result.dag.nodes[t].agent for t in result.dag.topo_order] \
            == agents
        # every task got a concrete impl of the right interface
        for tid, cfg in result.plan.configs.items():
            impl = system.library.impls[cfg.impl]
            assert impl.interface == result.dag.nodes[tid].agent
        # every task ran exactly once in the trace
        assert sorted(e.task for e in result.sim.trace) == \
            sorted(result.dag.nodes)


def test_retrieval_routing_lever():
    """Impl selection routes retrieval: MIN_COST picks the keyword path,
    MAX_QUALITY pays for hybrid — same workflow definition."""
    cheap = make_rag_job().execute(Murakkab.paper_cluster())
    best = make_rag_job(MAX_QUALITY).execute(Murakkab.paper_cluster())
    def impl_of(r):
        return [c.impl for t, c in r.plan.configs.items()
                if r.dag.nodes[t].agent == "retrieve"][0]
    assert impl_of(cheap) == "bm25-keyword"
    assert impl_of(best) == "hybrid-retrieval"
    assert best.quality > cheap.quality


def test_retrieve_floor_forces_dense_route():
    """Raising the retrieve quality floor disqualifies BM25 even at
    MIN_COST — the floor is the routing knob the workflow author holds."""
    import dataclasses
    job = make_rag_job()
    strict = dataclasses.replace(
        job, quality_floor={**job.quality_floor, "retrieve": 0.9})
    result = strict.execute(Murakkab.paper_cluster())
    retr = [c.impl for t, c in result.plan.configs.items()
            if result.dag.nodes[t].agent == "retrieve"][0]
    assert retr in ("dense-retrieval", "hybrid-retrieval")


def test_docingest_batches_digest_stage():
    """The chunk-level digest stage is the batchable bulk: under MIN_COST
    the scheduler co-schedules chunks (batch > 1) on an LLM tier."""
    result = make_docingest_job().execute(Murakkab.paper_cluster())
    digest_cfg = [c for t, c in result.plan.configs.items()
                  if result.dag.nodes[t].agent == "digest"][0]
    assert digest_cfg.batch > 1
    assert result.dag.nodes["t1_digest"].work_items == 72   # 2 docs x 36


def test_rag_latency_vs_cost_tradeoff():
    r_lat = make_rag_job(MIN_LATENCY).execute(Murakkab.paper_cluster())
    r_cost = make_rag_job().execute(Murakkab.paper_cluster())
    assert r_lat.makespan_s <= r_cost.makespan_s * 1.001


def test_scenarios_share_cluster_multitenant():
    """A RAG job and an ingest job co-scheduled on one cluster both finish."""
    system = Murakkab.paper_cluster()
    report = system.execute_many({
        "rag": (make_rag_job(), 0.0),
        "ingest": (make_docingest_job(), 2.0),
    })
    assert set(report.per_workflow) == {"rag", "ingest"}
    assert all(v["finish"] > 0 for v in report.per_workflow.values())


def test_no_scenario_branches_left_in_core():
    """Acceptance guard: core lowering modules carry no scenario names."""
    import inspect

    from repro.core import orchestrator, system
    for mod in (orchestrator, system):
        src = inspect.getsource(mod)
        assert "VideoInput" not in src
        assert "scenes" not in src
        assert "SUMM_TOKENS" not in src
