"""Real executor: dataflow wiring, determinism, output-equality across
plans (the paper's 'same output in all configurations')."""
import numpy as np
import pytest

from repro.core import MIN_COST, Murakkab
from repro.core.executor import Media, RealExecutor
from repro.configs.workflow_video import (PAPER_VIDEOS,
                                          make_baseline_workflow,
                                          make_declarative_job)


@pytest.fixture(scope="module")
def media():
    return [Media.synthesize(v.name, scenes=2, fps=4, seed=i)
            for i, v in enumerate(PAPER_VIDEOS[:1])]


@pytest.fixture(scope="module")
def outputs(media):
    system = Murakkab.paper_cluster()
    dag, plan = system.plan(make_declarative_job(MIN_COST))
    return RealExecutor(system.library).run(dag, plan, media), dag


def test_shapes_and_dataflow(outputs, media):
    out, dag = outputs
    scenes = media[0].frames.shape[0]
    frames = [v for k, v in out.items() if "frame_extract" in k][0]
    transcript = [v for k, v in out.items() if "speech" in k][0]
    objects = [v for k, v in out.items() if "object" in k][0]
    summary = [v for k, v in out.items() if "summar" in k][0]
    vectors = [v for k, v in out.items() if "embed" in k][0]
    assert frames.shape[0] == scenes
    assert transcript.shape == (scenes, 8)
    assert objects.shape[:1] == (scenes,)
    assert summary.shape == (scenes, 8)
    assert vectors.shape[0] == scenes


def test_deterministic(media):
    system = Murakkab.paper_cluster()
    dag, plan = system.plan(make_declarative_job(MIN_COST))
    o1 = RealExecutor(system.library, seed=0).run(dag, plan, media)
    o2 = RealExecutor(system.library, seed=0).run(dag, plan, media)
    for k in o1:
        if k == "_timings":
            continue
        np.testing.assert_array_equal(np.asarray(o1[k]), np.asarray(o2[k]))


def test_same_outputs_across_plans(media):
    """Baseline plan and MIN_LATENCY plan compute identical summaries when
    the underlying impls match (the paper's quality-preservation claim)."""
    sys_a = Murakkab.paper_cluster()
    dag_a, plan_a = sys_a.plan(make_declarative_job(MIN_COST))
    out_a = RealExecutor(sys_a.library).run(dag_a, plan_a, media)

    sys_b = Murakkab.paper_cluster()
    dag_b, plan_b = sys_b.lower_imperative(make_baseline_workflow(),
                                           PAPER_VIDEOS[:1])
    out_b = RealExecutor(sys_b.library).run(dag_b, plan_b, media)

    summ_a = [v for k, v in out_a.items() if "summar" in k][0]
    summ_b = [v for k, v in out_b.items() if "summar" in k][0]
    np.testing.assert_array_equal(np.asarray(summ_a), np.asarray(summ_b))


def test_qa_agent(media):
    system = Murakkab.paper_cluster()
    dag, plan = system.plan(make_declarative_job(MIN_COST))
    ex = RealExecutor(system.library)
    ex.run(dag, plan, media)
    ans = ex.qa(None, "what objects appear?", None)
    assert ans.shape == (1, 8)
