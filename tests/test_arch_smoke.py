"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPE_CELLS, cell_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models.model_zoo import build_model
from repro.runtime import serve as serve_rt
from repro.runtime import train as train_rt

B, S = 2, 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inputs = {"tokens": jnp.ones((B, S), jnp.int32),
              **model.extra_inputs(B, S)}
    logits, _, aux = model.apply(params, inputs, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    opts = train_rt.TrainOptions(remat_policy=None, total_steps=10,
                                 warmup_steps=1)
    state = train_rt.init_train_state(model, jax.random.PRNGKey(0), opts)
    step = jax.jit(train_rt.build_train_step(model, opts))
    batch = batch_for_step(DataConfig(cfg.vocab_size, S, B), 0, cfg)
    state, metrics = step(state, batch)
    assert int(state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    """One decode step over a cache (the serve_step of decode shape cells)."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc_len = model.enc_len_for(S)
    cache = model.init_cache(B, S + 4, enc_len=enc_len)
    prefill = serve_rt.build_prefill_step(model, serve_rt.ServeOptions())
    inputs = {"tokens": jnp.ones((B, S), jnp.int32),
              **model.extra_inputs(B, S)}
    last, cache = prefill(params, inputs, cache)
    assert last.shape == (B, cfg.vocab_size)
    decode = serve_rt.build_decode_step(model, serve_rt.ServeOptions())
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    nxt, logits, cache = decode(params, cache, tok, jnp.asarray(S, jnp.int32))
    assert nxt.shape == (B, 1)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_shape_cell_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    runnable = {a for a in ARCH_IDS
                if cell_applicable(get_config(a), SHAPE_CELLS["long_500k"])}
    assert runnable == {"mamba2-370m", "zamba2-7b"}
    for a in ARCH_IDS:  # every other cell applies everywhere
        for cell in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(get_config(a), SHAPE_CELLS[cell])


def test_full_configs_match_assignment():
    """Spot-check the assigned hyperparameters (no reduced overrides)."""
    c = get_config("deepseek-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (30, 4096, 32, 32, 11008, 102400)
    c = get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (42, 3584, 16, 8, 256000)
    assert c.alt_local_global and c.attn_logit_softcap > 0
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.moe.num_experts, c.moe.top_k) == \
        (61, 7168, 384, 8)
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == \
        (64, 12288, 96, 33792)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (48, 1024, 128)
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (81, 3584, 64)
    c = get_config("llama-3.2-vision-90b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == \
        (100, 8192, 64, 8)
    c = get_config("seamless-m4t-large-v2")
    assert c.family == "encdec" and c.vocab_size == 256206
    c = get_config("stablelm-12b")
    assert (c.n_layers, c.d_model, c.n_kv_heads) == (40, 5120, 8)
    c = get_config("deepseek-moe-16b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (64, 6, 2)


@pytest.mark.parametrize("arch", ["deepseek-7b", "kimi-k2-1t-a32b",
                                  "mamba2-370m"])
def test_param_count_sanity(arch):
    """Full-config param counts are in the advertised ballpark."""
    model = build_model(get_config(arch))
    n = model.param_count()
    lo, hi = {"deepseek-7b": (6e9, 8e9),
              "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
              "mamba2-370m": (3.0e8, 4.5e8)}[arch]
    assert lo <= n <= hi, f"{arch}: {n:.3e}"
