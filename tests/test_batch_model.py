"""Batch roofline model (DESIGN.md §7): hypothesis properties over the
prefill/decode split, the golden knee, and the scheduler/simulator sharing
one latency source of truth."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (CATALOG, Murakkab, Work, batch_knee,
                        batch_roofline_latency, roofline_latency)
from repro.core.dag import TaskNode
from repro.core.profiles import CostQuery
from repro.core.simulator import Simulator

V5E = CATALOG["tpu-v5e"]


def _work(pf, df, pb, db, wb, steps):
    return Work.two_phase(prefill_flops=pf, decode_flops=df,
                          prefill_bytes=pb, decode_bytes=db,
                          weight_bytes=wb, decode_steps=steps)


WORK_STRATS = (st.floats(1e9, 1e15), st.floats(1e9, 1e15),
               st.floats(0.0, 1e12), st.floats(0.0, 1e12),
               st.floats(1e8, 2e11), st.integers(1, 512))


@settings(max_examples=60)
@given(*WORK_STRATS, st.integers(1, 7))
def test_per_item_latency_non_increasing_in_batch(pf, df, pb, db, wb, steps,
                                                  log_b):
    """Co-scheduling more items can never raise per-item latency."""
    w = _work(pf, df, pb, db, wb, steps)
    b = 2 ** log_b
    prev = batch_roofline_latency(w, V5E, 1, b // 2)
    cur = batch_roofline_latency(w, V5E, 1, b)
    assert cur <= prev * (1 + 1e-12)


@settings(max_examples=60)
@given(*WORK_STRATS, st.integers(1, 7))
def test_step_latency_non_decreasing_in_batch(pf, df, pb, db, wb, steps,
                                              log_b):
    """A bigger batched step can never take less wall time."""
    w = _work(pf, df, pb, db, wb, steps)
    b = 2 ** log_b
    prev = (b // 2) * batch_roofline_latency(w, V5E, 1, b // 2)
    cur = b * batch_roofline_latency(w, V5E, 1, b)
    assert cur >= prev * (1 - 1e-12)


@settings(max_examples=60)
@given(*WORK_STRATS, st.integers(1, 64))
def test_batch_one_reduces_to_seed_roofline(pf, df, pb, db, wb, steps, n):
    """At batch=1 the batch model is exactly the seed three-term roofline
    over the single-item totals — unbatched estimates are unchanged."""
    w = _work(pf, df, pb, db, wb, steps)
    got = batch_roofline_latency(w, V5E, n_devices=n, batch=1)
    seed = roofline_latency(w.flops, w.hbm_bytes, V5E, n_devices=n,
                            collective_bytes=w.coll_bytes)
    assert math.isclose(got, seed, rel_tol=1e-12)


@settings(max_examples=40)
@given(*WORK_STRATS)
def test_two_phase_totals_consistent(pf, df, pb, db, wb, steps):
    w = _work(pf, df, pb, db, wb, steps)
    assert w.has_phases
    assert math.isclose(w.flops, pf + df, rel_tol=1e-12)
    assert math.isclose(w.hbm_bytes, w.shared_bytes + w.per_item_bytes,
                        rel_tol=1e-12)
    assert math.isclose(w.shared_bytes, wb * steps, rel_tol=1e-12)


@settings(max_examples=40)
@given(*WORK_STRATS, *WORK_STRATS)
def test_work_addition_preserves_stream_split(pf, df, pb, db, wb, steps,
                                              pf2, df2, pb2, db2, wb2,
                                              steps2):
    """Summing two phased works keeps shared + per_item == hbm (so the
    b=1 == seed-roofline invariant survives composition)."""
    w = _work(pf, df, pb, db, wb, steps) + _work(pf2, df2, pb2, db2, wb2,
                                                 steps2)
    assert math.isclose(w.shared_bytes,
                        wb * steps + wb2 * steps2, rel_tol=1e-12)
    assert math.isclose(w.hbm_bytes, w.shared_bytes + w.per_item_bytes,
                        rel_tol=1e-12)
    got = batch_roofline_latency(w, V5E, 1, 1)
    seed = roofline_latency(w.flops, w.hbm_bytes, V5E)
    assert math.isclose(got, seed, rel_tol=1e-12)


@settings(max_examples=40)
@given(*WORK_STRATS, st.integers(1, 7))
def test_past_knee_is_compute_bound(pf, df, pb, db, wb, steps, log_b):
    """Beyond the knee, per-item latency equals the pure compute time."""
    w = _work(pf, df, pb, db, wb, steps)
    knee = batch_knee(w, V5E, 1)
    b = 2 ** log_b
    if math.isfinite(knee) and b >= knee:
        t_c = w.flops / (V5E.peak_flops * 0.6)
        t_p = w.per_item_bytes / V5E.hbm_bw
        expect = max(t_c, t_p, w.coll_bytes / V5E.link_bw)
        got = batch_roofline_latency(w, V5E, 1, b)
        # shared-stream share vanishes at large b but never below the floor
        assert got >= expect * (1 - 1e-12)
        assert got <= expect + w.shared_bytes / (b * V5E.hbm_bw) + 1e-15


def test_golden_knee_gemma2_9b_summarize():
    """The video scenario's reference decode stage (gemma2-9b, 900/120
    tokens) knees at b* ~ 17 on tpu-v5e: weights-streaming-bound below,
    compute-bound and flat above."""
    lib = Murakkab.tpu_cluster().library
    impl = lib.impls["gemma2-9b"]
    work = impl.work_fn(900, 120)
    knee = batch_knee(work, V5E, 1, impl.mxu_efficiency)
    assert 15.0 < knee < 19.0
    lat = {b: batch_roofline_latency(work, V5E, 1, b, impl.mxu_efficiency)
           for b in (1, 2, 8, 32, 64, 128)}
    # below the knee: the shared weights stream dominates, halving with b
    assert lat[2] == pytest.approx(lat[1] / 2, rel=1e-9)
    assert lat[8] == pytest.approx(lat[1] / 8, rel=1e-9)
    # above the knee: flat at the compute roofline
    assert lat[64] == pytest.approx(lat[32], rel=0.05)
    assert lat[128] == pytest.approx(
        work.flops / (V5E.peak_flops * impl.mxu_efficiency), rel=1e-6)


def test_lm_work_declares_phases_and_tools_do_not():
    lib = Murakkab.tpu_cluster().library
    assert lib.impls["gemma2-9b"].work_fn(900, 120).has_phases
    assert lib.impls["nvlm-72b"].work_fn(900, 120).has_phases
    assert not lib.impls["opencv"].work_fn(0, 0).has_phases
    assert not lib.impls["dense-retrieval"].work_fn(64, 0).has_phases


def test_scheduler_estimate_uses_batch_roofline_for_phased_work():
    """For impls with a work model, batch ** alpha is gone from estimates:
    the batched step matches the batch roofline exactly."""
    system = Murakkab.tpu_cluster()
    node = TaskNode(id="t", description="", agent="digest", work_items=64,
                    chunkable=True, tokens_in=700, tokens_out=90)
    impl = system.library.impls["gemma2-9b-digest"]
    b = 32
    cfg = system.scheduler.estimate(node, impl, "v5e", 1, batch=b)
    work = impl.work_fn(700, 90)
    step = impl.overhead_s + b * batch_roofline_latency(
        work, V5E, 1, b, impl.mxu_efficiency)
    steps = math.ceil(64 / b)
    assert cfg.est_latency_s == pytest.approx(
        steps * step + impl.load_time_s, rel=1e-12)
    # and it is NOT the deprecated alpha curve
    lat1 = impl.overhead_s + batch_roofline_latency(work, V5E, 1, 1,
                                                    impl.mxu_efficiency)
    assert cfg.est_latency_s != pytest.approx(
        steps * lat1 * b ** impl.batch_alpha + impl.load_time_s, rel=0.01)


def test_alpha_fallback_for_unphased_and_pinned():
    """Impls without a phase split — and measured (pinned) rows — keep the
    deprecated batch ** alpha scalar."""
    system = Murakkab.tpu_cluster()
    node = TaskNode(id="t", description="", agent="retrieve", work_items=16,
                    chunkable=True, tokens_in=64, tokens_out=0)
    impl = system.library.impls["dense-retrieval"]     # fixed work, a=0.4
    spec = V5E
    work = impl.work_fn(64, 0)
    q1 = CostQuery(impl=impl, spec=spec, n_devices=1, work=work)
    lat1 = system.profiles.step_latency(q1)
    b = 8
    cfg = system.scheduler.estimate(node, impl, "v5e", 1, batch=b)
    assert cfg.est_latency_s == pytest.approx(
        math.ceil(16 / b) * lat1 * b ** impl.batch_alpha + impl.load_time_s,
        rel=1e-12)
    # pinned rows: calibration wins and alpha still scales the batch
    system.profiles.pin("gemma2-9b-digest", "tpu-v5e", 1, 0.5)
    dimpl = system.library.impls["gemma2-9b-digest"]
    dwork = dimpl.work_fn(700, 90)
    assert system.profiles.step_latency(
        CostQuery(impl=dimpl, spec=spec, n_devices=1, work=dwork,
                  batch=4)) == \
        pytest.approx(0.5 * 4 ** dimpl.batch_alpha, rel=1e-12)


def test_simulator_actuals_match_scheduler_estimates():
    """One source of truth: the simulator's batched duration equals the
    scheduler's estimate for the same config (cold start included)."""
    system = Murakkab.tpu_cluster()
    node = TaskNode(id="t", description="", agent="digest", work_items=64,
                    chunkable=True, tokens_in=700, tokens_out=90)
    impl = system.library.impls["gemma2-9b-digest"]
    cfg = system.scheduler.estimate(node, impl, "v5e", 1, batch=32)
    sim = Simulator(system.cluster, system.library, system.profiles)
    dur, compute, _ = sim._duration(node, cfg, n_inst=1, new_instances=1)
    assert dur == pytest.approx(cfg.est_latency_s, rel=1e-12)
    assert compute == pytest.approx(cfg.est_latency_s - impl.load_time_s,
                                    rel=1e-12)
