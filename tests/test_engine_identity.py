"""The engine's dispatch-equivalence witness (DESIGN.md §12).

One parametrized test holds the load-bearing identity for every serving
scenario: the layered engine's fast path — indexed ready-set,
blocked-group memo, coalesced finish groups, vectorized pricing prewarm —
must produce *byte-identical* traces and canonical energy against the
seed's full-rescan reference (``fast_dispatch=False``). This consolidates
the per-PR identity tests that used to live in ``test_open_loop.py``
(video/rag/docingest) and ``test_cache_residency.py`` (chat): one witness,
four scenarios, both dispatch paths.
"""
import pytest

import repro.configs.workflow_chat  # noqa: F401  (registers "chat")
import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.core import Murakkab
from repro.core.arrivals import (SERVING_PRESETS, PoissonArrivals,
                                 SessionArrivals)


def _system():
    return Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128)


def _run(scenario: str, fast: bool):
    """One scenario stream through one dispatch path.

    Chat is the stateful stream (multi-turn sessions, KV/prefix residency,
    affinity placement) — it rides ``SessionArrivals``; the three
    stateless scenarios ride a single-scenario Poisson mix.
    """
    if scenario == "chat":
        return _system().open_loop(
            SessionArrivals(0.2, scenario="chat", mean_turns=6.0,
                            think_time_s=30.0, seed=7),
            horizon_s=400.0, warmup_s=60.0,
            presets={"chat": SERVING_PRESETS["chat"]},
            kv_cache=True, cache_affinity=True, fast_dispatch=fast)
    return _system().open_loop(
        PoissonArrivals(rate_per_s=0.25, mix={scenario: 1.0}, seed=4),
        horizon_s=300.0, warmup_s=30.0, fast_dispatch=fast)


@pytest.mark.parametrize("scenario", ["video", "rag", "docingest", "chat"])
def test_both_dispatch_paths_byte_identical(scenario):
    fast, ref = _run(scenario, True), _run(scenario, False)
    assert fast.trace == ref.trace
    assert fast.energy_wh == ref.energy_wh          # canonical energy
    assert fast.makespan_s == ref.makespan_s
    assert fast.per_class == ref.per_class
    assert fast.goodput_rps == ref.goodput_rps
    assert fast.cache_hit_rate == ref.cache_hit_rate
    # the fast path must actually be the fast path: never more start
    # attempts than the full rescan (strictly fewer whenever anything
    # ever queued)
    assert fast.n_attempts <= ref.n_attempts
