"""Planner: job decomposition, dataflow wiring, toolcalls, LLM protocol."""
import json

import pytest

from repro.core import Job, LLMPlanner, RulePlanner
from repro.core.agents import default_library
from repro.core.orchestrator import dag_creation_overhead
from repro.configs.workflow_video import PAPER_VIDEOS, make_declarative_job


@pytest.fixture(scope="module")
def lib():
    return default_library()


def test_paper_job_lowers_to_expected_dag(lib):
    dag = RulePlanner(lib).lower(make_declarative_job())
    agents = [dag.nodes[t].agent for t in dag.topo_order]
    assert agents == ["frame_extract", "speech_to_text", "object_detect",
                      "summarize", "embed"]
    nodes = {n.agent: n for n in dag.nodes.values()}
    # dataflow: summarize needs frames + objects + transcript
    summ_deps = {dag.nodes[d].agent for d in nodes["summarize"].deps}
    assert summ_deps == {"frame_extract", "object_detect", "speech_to_text"}
    assert {dag.nodes[d].agent for d in nodes["embed"].deps} == {"summarize"}
    assert nodes["object_detect"].deps == (nodes["frame_extract"].id,)
    # work granularity: 8 scenes, 80 frames
    assert nodes["speech_to_text"].work_items == 8
    assert nodes["summarize"].work_items == 80


def test_decomposition_without_hints(lib):
    job = Job(description="Describe what happens in the video",
              inputs=PAPER_VIDEOS)
    dag = RulePlanner(lib).lower(job)
    assert len(dag) == 5          # default template + aggregation


def test_toolcall_format(lib):
    planner = RulePlanner(lib)
    dag = planner.lower(make_declarative_job())
    calls = planner.toolcalls(dag)
    fe = [c for c in calls.values() if c.startswith("FrameExtractor")][0]
    # paper §3.2: FrameExtractor(start_time=0, end_time=60s, num_frames=10,
    #                            file="cats.mov")
    assert "file='cats.mov'" in fe
    assert "num_frames=10" in fe and "start_time=0" in fe


def test_unmatchable_task_raises(lib):
    job = Job(description="x", tasks=("Translate sanskrit poetry",),
              inputs=PAPER_VIDEOS)
    with pytest.raises(ValueError, match="no agent"):
        RulePlanner(lib).lower(job)


def test_llm_planner_protocol(lib):
    """LLMPlanner consumes any llm_fn; validates agents; builds the DAG."""
    def fake_llm(system_prompt, user_prompt):
        assert "frame_extract" in system_prompt    # library advertised
        assert "speech-to-text" in user_prompt
        return json.dumps({"tasks": [
            {"id": "a", "agent": "frame_extract", "deps": []},
            {"id": "b", "agent": "speech_to_text", "deps": []},
            {"id": "c", "agent": "summarize", "deps": ["a", "b"]},
        ]})

    dag = LLMPlanner(lib, fake_llm).lower(make_declarative_job())
    assert list(dag.topo_order) == ["a", "b", "c"]
    assert dag.nodes["c"].work_items == 80

    def bad_llm(s, u):
        return json.dumps({"tasks": [{"id": "a", "agent": "nonsense"}]})
    with pytest.raises(ValueError, match="unknown agent"):
        LLMPlanner(lib, bad_llm).lower(make_declarative_job())


def test_dag_creation_overhead_under_1pct(lib):
    dag = RulePlanner(lib).lower(make_declarative_job())
    assert dag_creation_overhead(dag, makespan_s=83.0) < 0.01


def test_interface_matching(lib):
    assert lib.match_interface("Run speech-to-text on all scenes") == \
        "speech_to_text"
    assert lib.match_interface("Detect objects in the frames") == \
        "object_detect"
    assert lib.match_interface("Summarize each scene") == "summarize"
    assert lib.match_interface("Extract frames from each video") == \
        "frame_extract"
