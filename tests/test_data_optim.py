"""Data pipeline determinism + optimizer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, DataIterator, batch_for_step
from repro.optim import adamw


class TestData:
    def test_deterministic_by_step(self):
        dc = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        a = batch_for_step(dc, 5)
        b = batch_for_step(dc, 5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        c = batch_for_step(dc, 6)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c["tokens"]))

    def test_iterator_state_roundtrip(self):
        dc = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        it = DataIterator(dc)
        next(it); next(it)
        saved = it.state()
        want = next(it)
        it2 = DataIterator(dc)
        it2.restore(saved)
        got = next(it2)
        np.testing.assert_array_equal(np.asarray(want["tokens"]),
                                      np.asarray(got["tokens"]))

    def test_labels_are_shifted_tokens(self):
        dc = DataConfig(vocab_size=50, seq_len=8, global_batch=2,
                        structure=0.0)
        b = batch_for_step(dc, 0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_modality_extras(self):
        for arch, key in (("seamless-m4t-large-v2", "frames"),
                          ("llama-3.2-vision-90b", "patches")):
            cfg = get_config(arch, reduced=True)
            b = batch_for_step(DataConfig(cfg.vocab_size, 8, 2), 0, cfg)
            assert key in b


class TestAdamW:
    def test_converges_on_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
        opt = adamw.init_opt_state(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, opt, _ = adamw.apply_updates(params, grads, opt, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros((4, 4))}
        cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
        opt = adamw.init_opt_state(params, cfg)
        huge = {"w": jnp.full((4, 4), 1e6)}
        new_p, _, metrics = adamw.apply_updates(params, huge, opt, cfg)
        assert float(metrics["grad_norm"]) > 1e5
        assert float(jnp.max(jnp.abs(new_p["w"]))) < 10.0

    @pytest.mark.parametrize("mdt", ["float32", "bfloat16", "int8"])
    def test_moment_dtypes_converge(self, mdt):
        target = jnp.linspace(-1, 1, 16)
        params = {"w": jnp.zeros(16)}
        cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=mdt)
        opt = adamw.init_opt_state(params, cfg)
        for _ in range(300):
            grads = {"w": 2 * (params["w"] - target)}
            params, opt, _ = adamw.apply_updates(params, grads, opt, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=0.05)

    def test_int8_moment_memory_shape(self):
        params = {"w": jnp.zeros((64, 64))}
        cfg = adamw.AdamWConfig(moment_dtype="int8")
        opt = adamw.init_opt_state(params, cfg)
        assert opt["m"]["w"]["q"].dtype == jnp.int8

    def test_no_decay_on_1d_params(self):
        params = {"scale": jnp.ones(8), "w": jnp.ones((8, 8))}
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5)
        opt = adamw.init_opt_state(params, cfg)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new_p, _, _ = adamw.apply_updates(params, zero_g, opt, cfg)
        np.testing.assert_array_equal(np.asarray(new_p["scale"]), 1.0)
        assert float(jnp.max(new_p["w"])) < 1.0   # decayed
