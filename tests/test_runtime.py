"""Distribution runtime: sharding rules, microbatch accumulation,
gradient compression, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.launch.mesh import make_mesh
from repro.models.model_zoo import build_model
from repro.optim import compression
from repro.optim.schedule import warmup_cosine
from repro.runtime import sharding as shd
from repro.runtime import train as train_rt


class TestShardingRules:
    def _mesh(self):
        # 1 real device; rule resolution only reads shapes/axis names
        return make_mesh((1, 1), ("data", "model"))

    def test_tp_axes_claimed_once(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        spec = shd.spec_for_axes(("embed", "mlp"), (256, 1024), mesh)
        used = [a for part in spec for a in
                ((part,) if isinstance(part, str) else (part or ()))]
        assert len(used) == len(set(used))

    def test_divisibility_fallback(self):
        """kv_heads=8 cannot take a 16-way model axis -> falls back.

        spec_for_axes only reads axis_names + device-array shape, so a
        faked production-mesh stand-in exercises the real rule table."""
        import types
        import numpy as np_
        fake = types.SimpleNamespace(axis_names=("data", "model"),
                                     devices=np_.zeros((16, 16)))
        spec = shd.spec_for_axes(("kv_heads", "head_dim"), (8, 128), fake)
        assert "model" not in (spec[0] if spec else ())   # 8 % 16 != 0
        # 32 kv heads CAN take the 16-way axis
        spec = shd.spec_for_axes(("kv_heads", "head_dim"), (32, 128), fake)
        assert spec[0] == "model"
        # batch takes (pod, data) jointly on the multi-pod mesh
        fake3 = types.SimpleNamespace(axis_names=("pod", "data", "model"),
                                      devices=np_.zeros((2, 16, 16)))
        spec = shd.spec_for_axes(("batch", None), (256, 128), fake3)
        assert spec[0] == ("pod", "data")

    def test_all_arch_param_specs_resolve(self):
        mesh = self._mesh()
        for arch in ("deepseek-7b", "kimi-k2-1t-a32b", "mamba2-370m"):
            model = build_model(get_config(arch, reduced=True))
            sh = shd.tree_shardings(model.axes(), model.abstract(), mesh)
            assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(
                model.abstract()))


class TestTraining:
    def test_microbatch_equals_fullbatch_grads(self):
        cfg = get_config("deepseek-7b", reduced=True)
        model = build_model(cfg)
        batch = batch_for_step(DataConfig(cfg.vocab_size, 32, 8), 0, cfg)
        key = jax.random.PRNGKey(0)
        outs = {}
        for mb in (1, 2, 4):
            opts = train_rt.TrainOptions(remat_policy=None, microbatches=mb,
                                         warmup_steps=1, total_steps=10)
            state = train_rt.init_train_state(model, key, opts)
            step = jax.jit(train_rt.build_train_step(model, opts))
            new_state, metrics = step(state, batch)
            outs[mb] = (jax.tree.leaves(new_state["params"]),
                        float(metrics["grad_norm"]))
        for mb in (2, 4):
            for a, b in zip(outs[1][0], outs[mb][0]):
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           atol=3e-2, rtol=3e-2)

    def test_remat_matches_no_remat(self):
        cfg = get_config("deepseek-7b", reduced=True)
        model = build_model(cfg)
        batch = batch_for_step(DataConfig(cfg.vocab_size, 16, 4), 0, cfg)
        key = jax.random.PRNGKey(0)
        losses = {}
        for pol in (None, "full", "dots"):
            opts = train_rt.TrainOptions(remat_policy=pol, warmup_steps=1,
                                         total_steps=10)
            state = train_rt.init_train_state(model, key, opts)
            step = jax.jit(train_rt.build_train_step(model, opts))
            _, m = step(state, batch)
            losses[pol] = float(m["loss"])
        assert abs(losses["full"] - losses[None]) < 1e-3
        assert abs(losses["dots"] - losses[None]) < 1e-3

    def test_loss_decreases_over_steps(self):
        from repro.optim import adamw
        cfg = get_config("deepseek-7b", reduced=True)
        model = build_model(cfg)
        # lr scaled up for the reduced config: the production default 3e-4
        # moves the tiny model too slowly to generalize within 20 steps
        opts = train_rt.TrainOptions(remat_policy=None, warmup_steps=2,
                                     total_steps=30,
                                     opt=adamw.AdamWConfig(lr=3e-3))
        state = train_rt.init_train_state(model, jax.random.PRNGKey(0), opts)
        step = jax.jit(train_rt.build_train_step(model, opts))
        dc = DataConfig(cfg.vocab_size, 32, 8)
        first = last = None
        for i in range(20):
            state, m = step(state, batch_for_step(dc, i, cfg))
            first = first if first is not None else float(m["loss"])
            last = float(m["loss"])
        assert last < first


class TestCompression:
    def test_error_feedback_unbiased_over_steps(self):
        """Sum of dequantized updates converges to sum of true gradients."""
        g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.1
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(50):
            q, scale, err = compression.compress(g, err)
            total = total + compression.decompress(q, scale)
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=1e-3)

    def test_compression_ratio(self):
        tree = {"a": jnp.zeros((1024,)), "b": jnp.zeros((2048,))}
        r = compression.compression_ratio(tree)
        assert 3.5 < r < 4.0        # fp32 -> int8 + scales


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.01          # peak after warmup
    assert lrs[99] < 0.2                       # decayed
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # mono down
