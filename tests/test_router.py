"""Learned router determinism + inertness (core/router.py, DESIGN.md §11).

Two load-bearing properties, both ISSUE acceptance criteria:

- identical ``(seed, telemetry log)`` pairs yield byte-identical routing
  decisions (hypothesis properties over seeds/epsilon/log order);
- ``router=None`` (and an attached-but-covering-nothing router) leaves
  RAG plans and traces byte-identical to the pre-router engine, on both
  open-loop dispatch paths (extending the fast-dispatch identity harness).
"""
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs.workflow_docingest  # noqa: F401
import repro.configs.workflow_rag  # noqa: F401
import repro.configs.workflow_video  # noqa: F401
from repro.configs.workflow_rag import ROUTED_QUERIES, make_rag_job
from repro.core import (Murakkab, OfflineEvaluator, Router, TelemetryStore,
                        featurize, featurize_node)
from repro.core.arrivals import PoissonArrivals
from repro.core.dag import TaskNode
from repro.core.telemetry import TaskRecord

ARMS = ["bm25-keyword", "dense-retrieval", "hybrid-retrieval"]


def _node(tid: str, text: str) -> TaskNode:
    return TaskNode(id=tid, description=text, agent="retrieve",
                    args={"query": text})


NODES = [_node(f"t{i}_retrieve", q.text)
         for i, q in enumerate(ROUTED_QUERIES)]


# -- featurization ------------------------------------------------------------

def test_featurize_buckets_split_lookup_from_semantic():
    for q in ROUTED_QUERIES[:4]:
        assert featurize(q.text).bucket().startswith("lookup:")
    for q in ROUTED_QUERIES[4:]:
        assert featurize(q.text).bucket().startswith("semantic:")


def test_featurize_node_prefers_text_args_over_description():
    n = _node("t", "10-K 2024 item 1A")
    assert featurize_node(n) == featurize("10-K 2024 item 1A")
    bare = TaskNode(id="t", description="summarize the findings",
                    agent="retrieve")
    assert featurize_node(bare) == featurize("summarize the findings")


def test_featurize_degenerate_inputs():
    f = featurize("")
    assert f.length == f.n_tokens == 0
    assert f.bucket() == "semantic:short"


# -- router construction ------------------------------------------------------

def test_epsilon_validation_and_frozen_weights():
    with pytest.raises(ValueError):
        Router(epsilon=1.5)
    with pytest.raises(ValueError):
        Router(epsilon=-0.1)
    r = Router(weights={("retrieve", "lookup:short"): {"a": 1.0}})
    with pytest.raises(TypeError):
        r.weights[("retrieve", "x")] = {}
    with pytest.raises(TypeError):
        r.weights[("retrieve", "lookup:short")]["a"] = 2.0


def test_fingerprint_tracks_identity():
    r = Router(interfaces=("retrieve",), epsilon=0.1, seed=3)
    r2 = r.with_weights({("retrieve", "lookup:short"): {"a": 1.0}})
    assert r.fingerprint() != r2.fingerprint()
    assert r2.version == r.version + 1
    assert Router(seed=3).fingerprint() == Router(seed=3).fingerprint()
    assert Router(seed=3).fingerprint() != Router(seed=4).fingerprint()


def test_untrained_router_defers_to_scheduler():
    r = Router(epsilon=0.0, seed=0)    # no weights, no exploration
    assert all(r.route(n, ARMS) is None for n in NODES)
    assert r.route(NODES[0], []) is None


# -- determinism properties ---------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_identical_routers_route_identically(seed, epsilon):
    """Decisions are a pure function of (seed, weights, task): two router
    instances built alike agree on every node, and repeated calls on one
    instance never drift."""
    weights = {("retrieve", b): {"bm25-keyword": 0.9,
                                 "dense-retrieval": 0.8}
               for b in ("lookup:short", "semantic:short",
                         "lookup:long", "semantic:long")}
    a = Router(epsilon=epsilon, seed=seed, weights=weights)
    b = Router(epsilon=epsilon, seed=seed, weights=weights)
    first = [a.route(n, ARMS) for n in NODES]
    assert [b.route(n, ARMS) for n in NODES] == first
    assert [a.route(n, ARMS) for n in NODES] == first
    # every answer is a legal arm (or a deferral)
    assert all(pick is None or pick in ARMS for pick in first)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_identical_seed_and_log_give_identical_decisions(seed):
    """ISSUE acceptance: identical (seed, telemetry log) pairs yield
    byte-identical routing decisions — including through a JSONL
    round-trip of the log."""
    store = TelemetryStore()
    for i, q in enumerate(ROUTED_QUERIES):
        arm = ARMS[i % len(ARMS)]
        store.log(TaskRecord(
            t=float(i), workflow="w", task=f"t{i}", interface="retrieve",
            impl=arm, pool="cpu", features=featurize(q.text),
            latency_s=0.5, energy_j=float(i), usd=0.001 * (i + 1),
            quality=0.9 if arm != "bm25-keyword" else 0.7))
    ev = OfflineEvaluator(quality_target=0.85, cost_weight=0.1,
                          cost_key="usd")
    base = Router(interfaces=("retrieve",), epsilon=0.05, seed=seed)
    r1 = ev.update(base, store)
    r2 = ev.update(base, TelemetryStore.from_jsonl(store.to_jsonl()))
    assert dict(r1.weights) == dict(r2.weights)
    assert [r1.route(n, ARMS) for n in NODES] == \
        [r2.route(n, ARMS) for n in NODES]


def test_exploit_picks_argmax_and_breaks_ties_lexicographically():
    w = {("retrieve", "lookup:short"): {"bm25-keyword": 0.9,
                                        "dense-retrieval": 0.9,
                                        "hybrid-retrieval": 0.2}}
    r = Router(epsilon=0.0, seed=0, weights=w)
    n = _node("t0", "10-K 2024 item 1A")
    # tie at 0.9: max over the sorted arm list keeps the first-sorted
    # of the maxima — deterministic regardless of arms-list order
    assert r.route(n, ARMS) == "bm25-keyword"
    assert r.route(n, list(reversed(ARMS))) == "bm25-keyword"
    # arms absent from the table never get picked in exploit mode
    assert r.route(n, ["missing-arm"]) is None


# -- inertness: router=None is byte-identical (tentpole acceptance) -----------

def _serving(router=None, telemetry=None, fast=True):
    sys_ = Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                host_cores=128, router=router,
                                telemetry=telemetry)
    return sys_.open_loop(
        PoissonArrivals(rate_per_s=0.25, mix={"rag": 1.0}, seed=4),
        horizon_s=300.0, warmup_s=30.0, fast_dispatch=fast)


@pytest.mark.parametrize("fast", [True, False])
def test_router_none_byte_identical_open_loop(fast):
    """router=None + attached telemetry leave the open-loop RAG stream
    byte-identical to the stock engine — on both dispatch paths."""
    stock = _serving(fast=fast)
    routed = _serving(router=None, telemetry=TelemetryStore(), fast=fast)
    assert routed.trace == stock.trace
    assert routed.energy_wh == stock.energy_wh
    assert routed.makespan_s == stock.makespan_s
    assert routed.per_class == stock.per_class


def test_non_covering_router_byte_identical():
    """A router that covers no interface defers every decision — traces
    match the stock engine exactly."""
    stock = _serving()
    inert = _serving(router=Router(interfaces=(), epsilon=0.5, seed=9))
    assert inert.trace == stock.trace
    assert inert.energy_wh == stock.energy_wh


def test_router_none_plans_byte_identical_closed_loop():
    stock = Murakkab.paper_cluster().execute(make_rag_job())
    routed = Murakkab.paper_cluster(router=None).execute(make_rag_job())
    assert routed.plan.configs == stock.plan.configs
    assert routed.sim.trace == stock.sim.trace
    assert routed.energy_wh == stock.energy_wh


def test_plan_cache_keyed_on_router_fingerprint():
    system = Murakkab.paper_cluster()
    job = make_rag_job()
    dag = system.lower(job)
    system.plan_admitted(dag, job)
    system.plan_admitted(dag, job)
    assert system.plan_cache_hits == 1
    # attaching (or retraining) a router must invalidate cached plans
    system.router = Router(interfaces=("retrieve",), epsilon=0.0, seed=1,
                           weights={("retrieve", "lookup:short"):
                                    {"bm25-keyword": 1.0}})
    misses = system.plan_cache_misses
    system.plan_admitted(dag, job)
    assert system.plan_cache_misses == misses + 1
    system.router = system.router.with_weights(
        {("retrieve", "semantic:short"): {"dense-retrieval": 1.0}})
    system.plan_admitted(dag, job)
    assert system.plan_cache_misses == misses + 2


def test_trained_router_changes_the_retrieve_arm_only_within_floor():
    """A router exploit pick narrows level-1 choice to its arm; the
    quality floor still gates — an arm below the floor is never offered
    to the router."""
    weights = {("retrieve", b): {"bm25-keyword": 1.0,
                                 "dense-retrieval": 0.5}
               for b in ("lookup:short", "semantic:short")}
    router = Router(interfaces=("retrieve",), epsilon=0.0, seed=0,
                    weights=weights)
    system = Murakkab.paper_cluster(router=router)
    job = make_rag_job(queries=ROUTED_QUERIES[:1])
    dag, plan = system.plan(job)
    retrieve = next(t for t in dag.topo_order if "retrieve" in t)
    assert plan[retrieve].impl == "bm25-keyword"

    # floor 0.9 excludes bm25 (0.82) from the router's arm list entirely
    strict = Murakkab.paper_cluster(router=router)
    dag2, plan2 = strict.plan(make_rag_job(queries=ROUTED_QUERIES[:1],
                                           quality_floor={"retrieve": 0.9}))
    retrieve2 = next(t for t in dag2.topo_order if "retrieve" in t)
    assert plan2[retrieve2].impl != "bm25-keyword"
    assert strict.profiles.quality(plan2[retrieve2].impl) >= 0.9
