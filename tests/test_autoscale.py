"""Pool autoscaler policy (core/autoscale.py, DESIGN.md §8).

The three safety properties: capacity never drops below pinned demand
(live leases), scale-downs respect the cooldown hysteresis, and
scale-to-zero is legal only for harvestable pools. Plus the dynamics:
scale-ups carry the provisioning lag with at most one in flight per pool,
and the capacity timeline feeds the idle-energy integral.
"""
import pytest

from repro.core import Murakkab
from repro.core.autoscale import Autoscaler, PoolPolicy, ScaleAction
from repro.core.cluster import ClusterManager, Pool


def _cluster(v5e=64, harvest=32) -> ClusterManager:
    return ClusterManager([
        Pool("v5e", "tpu-v5e", capacity=v5e),
        Pool("v4_harvest", "tpu-v4", capacity=harvest, harvestable=True),
    ])


# -- policy validation -------------------------------------------------------

def test_policy_envelope_validation():
    with pytest.raises(ValueError):
        PoolPolicy(min_devices=8, max_devices=4)
    with pytest.raises(ValueError):
        PoolPolicy(min_devices=-1, max_devices=4)
    with pytest.raises(ValueError):
        PoolPolicy(min_devices=0, max_devices=4, target_util=0.0)
    with pytest.raises(ValueError):
        PoolPolicy(min_devices=0, max_devices=4, target_util=1.5)
    with pytest.raises(ValueError):
        PoolPolicy(min_devices=0, max_devices=4, cooldown_s=-1.0)
    with pytest.raises(ValueError):
        Autoscaler({"v5e": PoolPolicy(1, 4)}, interval_s=0.0)


def test_validate_rejects_unknown_pool_and_reserved_scale_to_zero():
    cluster = _cluster()
    with pytest.raises(ValueError, match="unknown pool"):
        Autoscaler({"v9x": PoolPolicy(0, 8)}).validate(cluster)
    # scale-to-zero on the reserved pool: rejected
    with pytest.raises(ValueError, match="scale-to-zero"):
        Autoscaler({"v5e": PoolPolicy(0, 64)}).validate(cluster)
    # ...but fine on harvestable capacity
    Autoscaler({"v4_harvest": PoolPolicy(0, 32)}).validate(cluster)
    # ...and a warm floor on the reserved pool is fine too
    Autoscaler({"v5e": PoolPolicy(8, 64)}).validate(cluster)


# -- sizing math -------------------------------------------------------------

def test_desired_follows_demand_over_target_util():
    cluster = _cluster(v5e=64)
    sc = Autoscaler({"v5e": PoolPolicy(4, 64, target_util=0.5,
                                       scale_up_lag_s=30.0)})
    acts = sc.decide(cluster, {"v5e": 16}, t=0.0)
    # demand 16 at 50% target -> want 32; currently 64 -> scale DOWN
    assert acts == [ScaleAction("v5e", 32, lag_s=0.0)]


def test_never_below_pinned_demand():
    cluster = _cluster(v5e=64)
    cluster.alloc("v5e", 24, t=0.0)
    sc = Autoscaler({"v5e": PoolPolicy(4, 64, target_util=1.0)})
    acts = sc.decide(cluster, {"v5e": 0}, t=0.0)
    # min_devices=4 but 24 devices are held: the decision floors at used
    assert acts == [ScaleAction("v5e", 24)]
    assert sc.apply(cluster, acts[0], t=0.0) == 24
    assert cluster.pools["v5e"].capacity == 24
    # even asking for less than held is clamped by set_capacity itself
    assert cluster.set_capacity("v5e", 1, t=1.0) == 24


def test_scale_to_zero_only_when_idle_harvest():
    cluster = _cluster(harvest=32)
    sc = Autoscaler({"v4_harvest": PoolPolicy(0, 32, cooldown_s=0.0)})
    sc.validate(cluster)
    acts = sc.decide(cluster, {"v4_harvest": 0}, t=0.0)
    assert acts == [ScaleAction("v4_harvest", 0)]
    assert sc.apply(cluster, acts[0], t=0.0) == 0
    # with live harvest leases, the same decision floors at pinned demand
    cluster.set_capacity("v4_harvest", 32, t=1.0)
    cluster.alloc("v4_harvest", 8, t=1.0, harvest=True)
    acts = sc.decide(cluster, {"v4_harvest": 0}, t=100.0)
    assert acts and acts[0].capacity == 8


def test_scale_up_carries_lag_and_one_in_flight():
    cluster = _cluster(v5e=8)
    sc = Autoscaler({"v5e": PoolPolicy(4, 64, target_util=0.5,
                                       scale_up_lag_s=30.0)})
    acts = sc.decide(cluster, {"v5e": 16}, t=0.0)
    assert acts == [ScaleAction("v5e", 32, lag_s=30.0)]
    # while the scale-up is in flight, later ticks stay silent
    assert sc.decide(cluster, {"v5e": 24}, t=10.0) == []
    # once the lag elapses (the engine applies the pending resize), the
    # pool may be re-evaluated
    sc.apply(cluster, acts[0], t=30.0)
    assert cluster.pools["v5e"].capacity == 32
    acts = sc.decide(cluster, {"v5e": 32}, t=45.0)
    assert acts == [ScaleAction("v5e", 64, lag_s=30.0)]


def test_scale_down_respects_cooldown():
    cluster = _cluster(v5e=64)
    sc = Autoscaler({"v5e": PoolPolicy(4, 64, target_util=1.0,
                                       cooldown_s=60.0)})
    act = sc.decide(cluster, {"v5e": 8}, t=0.0)[0]
    sc.apply(cluster, act, t=0.0)
    assert cluster.pools["v5e"].capacity == 8
    cluster.set_capacity("v5e", 64, t=1.0)       # burst re-grew the pool
    sc._last_change["v5e"] = 1.0
    # 30s after the last change: inside the cooldown, no shrink
    assert sc.decide(cluster, {"v5e": 8}, t=31.0) == []
    # past the cooldown the shrink goes through
    assert sc.decide(cluster, {"v5e": 8}, t=61.1) == \
        [ScaleAction("v5e", 8)]


def test_scale_up_ignores_cooldown():
    """Cooldown is shrink-hysteresis only — a burst right after a change
    must still grow the pool (lag is the only up-delay)."""
    cluster = _cluster(v5e=8)
    sc = Autoscaler({"v5e": PoolPolicy(4, 64, target_util=0.5,
                                       cooldown_s=600.0,
                                       scale_up_lag_s=5.0)})
    sc._last_change["v5e"] = 0.0
    acts = sc.decide(cluster, {"v5e": 16}, t=1.0)
    assert acts == [ScaleAction("v5e", 32, lag_s=5.0)]


def test_capacity_timeline_feeds_idle_integral():
    """set_capacity logs the resize; the idle-floor integral charges the
    scaled-down pool for its *timeline*, not its final or peak size."""
    cluster = _cluster(v5e=64)
    cluster.set_capacity("v5e", 16, t=100.0)
    assert cluster.capacity_log("v5e") == [(0.0, 64), (100.0, 16)]
    # 64 devices for 100s + 16 devices for 100s
    assert cluster.capacity_device_seconds("v5e", until=200.0) == \
        pytest.approx(64 * 100 + 16 * 100)


# -- end-to-end: autoscaled open-loop serving --------------------------------

def _serving_run(autoscaler):
    from repro.core.arrivals import PoissonArrivals, default_mix
    import repro.configs.workflow_docingest  # noqa: F401
    import repro.configs.workflow_rag  # noqa: F401
    import repro.configs.workflow_video  # noqa: F401
    system = Murakkab.tpu_cluster(v5e=64, v5p=16, v4_harvest=32,
                                  host_cores=128)
    src = PoissonArrivals(rate_per_s=0.2, mix=default_mix(), seed=9)
    return system.open_loop(src, horizon_s=600.0, warmup_s=60.0,
                            autoscaler=autoscaler, collect_trace=False)


def test_open_loop_autoscaling_cuts_energy_at_equal_attainment():
    """The tentpole acceptance property at test scale: autoscaling the
    harvest pool to zero while idle beats the static cluster on energy
    without hurting priority-class SLO attainment."""
    static = _serving_run(None)
    scaled = _serving_run(Autoscaler({
        "v4_harvest": PoolPolicy(0, 32, target_util=0.75,
                                 scale_up_lag_s=15.0, cooldown_s=60.0),
    }, interval_s=15.0))
    assert scaled.scale_actions, "autoscaler never acted"
    assert scaled.energy_wh < static.energy_wh
    s_att = scaled.per_class["priority"]["slo_attainment"]
    g_att = static.per_class["priority"]["slo_attainment"]
    assert s_att is not None and s_att >= g_att
    # same offered work either way
    assert scaled.arrivals == static.arrivals
    assert scaled.completed == static.completed
