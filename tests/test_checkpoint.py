"""Checkpointing: bitwise roundtrip, corruption detection, retention,
auto-resume, async writer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import checkpoint as ckpt
from repro.checkpointing.manager import CheckpointManager


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (33, 17), jnp.bfloat16),
                   "b": jnp.arange(7, dtype=jnp.int32)},
        "opt": {"m": jax.random.normal(k, (33, 17), jnp.float32),
                "count": jnp.asarray(3, jnp.int32)},
        "step": jnp.asarray(42, jnp.int32),
    }


def test_roundtrip_bitwise(tmp_path):
    tree = _tree()
    ckpt.save(tree, str(tmp_path / "step_1"))
    back = ckpt.restore(tree, str(tmp_path / "step_1"))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_sharded_files(tmp_path):
    tree = {"a": jnp.zeros((1 << 18,), jnp.float32),
            "b": jnp.ones((1 << 18,), jnp.float32)}
    ckpt.save(tree, str(tmp_path / "s"), shard_bytes=1 << 19)
    shards = [f for f in os.listdir(tmp_path / "s") if f.startswith("arrays")]
    assert len(shards) >= 2
    back = ckpt.restore(tree, str(tmp_path / "s"))
    np.testing.assert_array_equal(np.asarray(back["b"]), 1.0)


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = str(tmp_path / "s")
    ckpt.save(tree, path)
    shard = next(f for f in os.listdir(path) if f.startswith("arrays"))
    # corrupt one shard
    import numpy as np_
    with np_.load(os.path.join(path, shard)) as z:
        data = {k: z[k].copy() for k in z.files}
    k0 = sorted(data)[0]
    data[k0][0] ^= 0xFF
    np_.savez(os.path.join(path, shard), **data)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(tree, path)


def test_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30, 40):
        mgr.save(s, {"x": jnp.asarray(s)})
    assert mgr.steps() == [30, 40]           # older GC'd
    restored, at = mgr.restore({"x": jnp.asarray(0)})
    assert at == 40 and int(restored["x"]) == 40
    restored, at = mgr.restore({"x": jnp.asarray(0)}, step=30)
    assert int(restored["x"]) == 30


def test_async_saver(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = _tree()
    mgr.save(5, tree)
    mgr.wait()
    restored, at = mgr.restore(tree)
    assert at == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["b"]),
                                  np.asarray(tree["params"]["b"]))


def test_restore_missing_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "empty"), async_save=False)
    restored, at = mgr.restore({"x": jnp.zeros(())})
    assert restored is None and at is None
