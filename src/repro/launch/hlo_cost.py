"""Production-artifact cost model: parse the optimized HLO of the *actual*
scan-over-layers lowering and scale while-loop bodies by their trip counts.

Why not depth-extrapolation from unrolled shallow variants? GSPMD can pick a
*different partitioning strategy* at different depths (measured: deepseek-7b
prefill shows 3 all-reduces/layer in the production scan body but 15 in the
2-layer unrolled variant), so extrapolating the variant 29x fabricates
collectives the real program never issues. XLA also annotates every while op
with ``backend_config={"known_trip_count": ...}``, so scaling the production
body is exact.

Two estimators over the scaled computation graph:

- ``collective_traffic``  — per-op on-link bytes (exact shapes x ring factor
  x trip scale). This is the §Roofline collective term.
- ``memory_traffic``      — sum of *materialized* buffer bytes x 2
  (produce + consume) over ENTRY + while bodies, scaled. Fusion-internal
  values are never materialized, so counting only fusion results models the
  fused execution a TPU backend performs — unlike ``cost_analysis()`` on the
  CPU backend, which meters every unfused intermediate. This is the
  §Roofline memory term.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ASSIGN = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_WHILE = re.compile(r"\bwhile\(")
_BODY = re.compile(r"body=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_KERNEL_META = re.compile(r'op_name="[^"]*\bpk_')
_DUS_META = re.compile(r'op_name="[^"]*dynamic_update_slice"')
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# ops whose result is not a (new) materialized buffer. dynamic-update-slice
# (and fusions rooted in one) executes in place (buffer aliasing): it writes
# only the update slice, already counted at the producing instruction.
_FREE_OPS = ("parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "custom-call", "dynamic-update-slice",
             "iota", "copy-start", "copy-done", "while", "conditional")


_TUPLE_TYPE = re.compile(r"^\((?:[^()]|\([^()]*\))*\)\s*")


def _op_name(rhs: str) -> str:
    """The op token: last word before the '(' that opens the arguments.

    Handles tuple-typed results: ``(s32[], f32[8,128]) parameter(0)``."""
    rest = _TUPLE_TYPE.sub("", rhs) if rhs.startswith("(") else rhs
    head = rest.split("(")[0].strip()
    return head.split()[-1] if head.split() else ""


def _is_free(lhs_name: str, rhs: str) -> bool:
    op = _op_name(rhs)
    if op in _FREE_OPS:
        return True
    # fusion whose root is an in-place dynamic-update-slice
    if op == "fusion" and "dynamic-update-slice" in lhs_name:
        return True
    return False


def _shape_bytes(head: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(head):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * b
    return total


def _traffic_factor(op: str, n: int) -> float:
    """On-link bytes per device as a fraction of the result size (ring)."""
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0   # collective-permute


def split_computations(txt: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for raw in txt.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and raw.rstrip().endswith("{"):
                name = m.group(2)
                if m.group(1):
                    name = "__ENTRY__"
                comps[name] = []
                cur = name
            continue
        if line == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps


@dataclass
class ScaledGraph:
    comps: dict[str, list[str]]
    scale: dict[str, float] = field(default_factory=dict)
    depth: dict[str, int] = field(default_factory=dict)   # while-nesting

    @classmethod
    def parse(cls, txt: str) -> "ScaledGraph":
        comps = split_computations(txt)
        g = cls(comps)
        g._compute_scales()
        return g

    def _compute_scales(self):
        whiles: dict[str, list[tuple[str, str, int]]] = {}
        for name, lines in self.comps.items():
            for line in lines:
                if _WHILE.search(line) and "body=" in line:
                    body = _BODY.search(line)
                    cond = _COND.search(line)
                    trip = _TRIP.search(line)
                    whiles.setdefault(name, []).append(
                        (body.group(1) if body else "",
                         cond.group(1) if cond else "",
                         int(trip.group(1)) if trip else 1))
        scale = {name: 0.0 for name in self.comps}
        depth = {name: 0 for name in self.comps}
        scale["__ENTRY__"] = 1.0
        # propagate until fixpoint (nesting depth is tiny)
        for _ in range(16):
            changed = False
            new = {name: 1.0 if name == "__ENTRY__" else 0.0
                   for name in self.comps}
            ndep = dict(depth)
            for parent, ws in whiles.items():
                for body, cond, trip in ws:
                    if body in new:
                        new[body] += scale[parent] * trip
                        ndep[body] = max(ndep[body], depth[parent] + 1)
                    if cond in new:
                        new[cond] += scale[parent] * (trip + 1)
                        ndep[cond] = max(ndep[cond], depth[parent] + 1)
            for k in scale:
                if abs(new[k] - scale[k]) > 1e-9 or ndep[k] != depth[k]:
                    changed = True
            scale, depth = new, ndep
            if not changed:
                break
        self.scale = scale
        self.depth = depth

    # -- executed (non-fused) computations ------------------------------------
    def _executed(self):
        for name, lines in self.comps.items():
            s = self.scale.get(name, 0.0)
            if s > 0:
                yield name, s, lines

    # -- estimators -------------------------------------------------------------
    def collective_traffic(self) -> dict:
        out: dict[str, dict] = {op: {"count": 0.0, "bytes": 0.0,
                                     "raw_bytes": 0.0}
                                for op in _COLLECTIVES}
        for name, s, lines in self._executed():
            for line in lines:
                m = _ASSIGN.match(line)
                if not m:
                    continue
                rhs = m.group(2)
                op_found = None
                for op in _COLLECTIVES:
                    if re.search(rf"\b{op}(-start)?\(", rhs):
                        op_found = op
                        break
                if not op_found or f"{op_found}-done" in rhs:
                    continue
                head = rhs.split(op_found)[0]
                nbytes = _shape_bytes(head)
                gm = _GROUPS.search(rhs)
                if gm:
                    grp = len([x for x in gm.group(1).split(",")
                               if x.strip()])
                else:
                    gi = _GROUPS_IOTA.search(rhs)
                    grp = int(gi.group(2)) if gi else 2
                rec = out[op_found]
                rec["count"] += s
                rec["raw_bytes"] += nbytes * s
                rec["bytes"] += nbytes * _traffic_factor(op_found, grp) * s
        out["total_bytes"] = sum(v["bytes"] for v in out.values()
                                 if isinstance(v, dict))
        return out

    def memory_traffic(self, max_depth: int | None = None) -> float:
        """Materialized-buffer bytes x2 (produce+consume), trip-scaled.

        ``max_depth``: ignore computations nested deeper than this many
        while levels (depth>=2 loops are the CPU stand-ins for Pallas
        kernel interiors, whose working set lives in VMEM on TPU —
        the caller substitutes the kernel's true HBM IO instead).

        TPU-semantics exclusions (each a CPU-backend artifact, documented
        in EXPERIMENTS.md §Dry-run):
        - pk_-tagged instructions: inside a Pallas-kernel boundary.
        - entry-level ``copy``/broadcast-of-constant/convert-of-parameter:
          buffer setup (donation aliasing, scan-ys zero-init, f32 staging
          of bf16 inputs for CPU dots) — a TPU executable does none of it.
        - copy/transpose fusions tagged ``dynamic_update_slice``: cache
          maintenance layout copies; TPU updates the cache in place."""
        total = 0.0
        for name, s, lines in self._executed():
            if max_depth is not None and self.depth.get(name, 0) > max_depth:
                continue
            entry = name == "__ENTRY__"
            for line in lines:
                m = _ASSIGN.match(line)
                if not m:
                    continue
                lhs, rhs = m.group(1), m.group(2)
                if _is_free(lhs, rhs):
                    continue
                if _KERNEL_META.search(line):
                    continue
                op = _op_name(rhs)
                if _DUS_META.search(line) and op in ("fusion", "copy",
                                                     "transpose"):
                    continue
                if entry:
                    if op == "copy":
                        continue
                    if op == "fusion" and (
                            "broadcast" in lhs or
                            ("convert" in lhs and "(%param" in rhs)):
                        continue
                head = rhs.split("(")[0]
                total += 2.0 * _shape_bytes(head) * s
        return total


def hlo_cost(compiled_text: str) -> dict:
    """{'coll': per-op dict, 'coll_total', 'bytes', 'bytes_outer'}."""
    g = ScaledGraph.parse(compiled_text)
    coll = g.collective_traffic()
    return {"coll": {op: coll[op] for op in _COLLECTIVES},
            "coll_total": coll["total_bytes"],
            "bytes": g.memory_traffic(),
            "bytes_outer": g.memory_traffic(max_depth=1),
            "scales": {k: v for k, v in g.scale.items() if v > 1.0}}
