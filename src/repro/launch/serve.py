"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Continuous-batched serving of queued generation requests against a zoo
model (reduced configs on CPU; the same ServeSession path the Murakkab
real-executor uses). Reports throughput and per-request latency.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
        --requests 16 --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config
from ..models.model_zoo import build_model
from ..runtime.serve import ServeOptions, ServeSession


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mamba2-370m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    sess = ServeSession(model, params,
                        opts=ServeOptions(temperature=args.temperature))

    rng = np.random.default_rng(args.seed)
    queue = [jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.prompt_len,), dtype=np.int32))
             for _ in range(args.requests)]
    extras = model.extra_inputs(args.batch, args.prompt_len)

    done, lat = 0, []
    t0 = time.time()
    while done < len(queue):
        chunk = queue[done:done + args.batch]
        while len(chunk) < args.batch:     # pad the final batch
            chunk.append(chunk[-1])
        prompts = jnp.stack(chunk)
        ts = time.time()
        out = sess.generate(prompts, max_new_tokens=args.max_new,
                            extras=extras)
        jax.block_until_ready(out)
        lat.append(time.time() - ts)
        done += args.batch
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"[serve] {args.arch}: {args.requests} reqs, "
          f"{toks / dt:.1f} tok/s, p50 batch latency "
          f"{sorted(lat)[len(lat) // 2]:.2f}s")
    return {"tok_per_s": toks / dt, "batches": len(lat)}


if __name__ == "__main__":
    main()
