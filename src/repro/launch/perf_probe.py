"""Perf probe: per-instruction collective/memory breakdown for one cell.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch X --shape Y

The §Perf hillclimb loop's 'profiler': lists the top collectives (shape,
group, trip scale, on-link bytes) and top memory contributors of the
production lowering, so each hypothesis targets a named instruction.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
import argparse
import re

from ..configs.base import SHAPE_CELLS
from ..configs.registry import ARCH_IDS, get_config
from .dryrun import _lower_cell
from .hlo_cost import (ScaledGraph, _ASSIGN, _COLLECTIVES, _GROUPS,
                       _GROUPS_IOTA, _KERNEL_META, _is_free,
                       _shape_bytes, _traffic_factor)
from .mesh import make_production_mesh


def probe(arch: str, shape: str, multi_pod: bool = False, top: int = 12,
          rules=None, opts_over=None):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    compiled = _lower_cell(cfg, SHAPE_CELLS[shape], mesh, rules=rules,
                           opts_over=opts_over).compile()
    txt = compiled.as_text()
    g = ScaledGraph.parse(txt)

    colls, mems = [], []
    for name, lines in g.comps.items():
        s = g.scale.get(name, 0.0)
        if s <= 0:
            continue
        for line in lines:
            m = _ASSIGN.match(line)
            if not m:
                continue
            lhs, rhs = m.group(1), m.group(2)
            base = None
            for cop in _COLLECTIVES:   # handles variadic (tuple) results
                if re.search(rf"\b{cop}(-start)?\(", rhs) and \
                        f"{cop}-done" not in rhs:
                    base = cop
                    break
            if base is not None:
                nbytes = _shape_bytes(rhs.split(base)[0])
                gm = _GROUPS.search(rhs)
                grp = (len([x for x in gm.group(1).split(",") if x.strip()])
                       if gm else
                       int(_GROUPS_IOTA.search(rhs).group(2))
                       if _GROUPS_IOTA.search(rhs) else 2)
                onlink = nbytes * _traffic_factor(base, grp) * s
                meta = re.search(r'op_name="([^"]+)"', line)
                colls.append((onlink, base, grp, s,
                              rhs.split("(")[0].strip()[:44],
                              meta.group(1)[-60:] if meta else ""))
            elif not _is_free(lhs, rhs) and not _KERNEL_META.search(line):
                b = 2.0 * _shape_bytes(rhs.split("(")[0]) * s
                if b > 1e8:
                    mems.append((b, lhs[:40], rhs.split("(")[0].strip()[:44],
                                 name[:24]))
    colls.sort(reverse=True)
    mems.sort(reverse=True)
    print(f"== {arch} {shape} — top collectives (on-link B/dev) ==")
    for onlink, op, grp, s, shp, meta in colls[:top]:
        print(f"  {onlink:10.3e} {op:16s} g{grp:<4d} x{s:<5.0f} {shp:<44s} "
              f"{meta}")
    print(f"  TOTAL {sum(c[0] for c in colls):.3e} B/dev")
    print(f"== top memory contributors ==")
    for b, lhs, shp, comp in mems[:top]:
        print(f"  {b:10.3e} {lhs:<40s} {shp:<44s} [{comp}]")
    return colls, mems


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=tuple(SHAPE_CELLS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    probe(args.arch, args.shape, args.multi_pod, args.top)
