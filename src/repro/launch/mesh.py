"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module touches no jax device state. The dry-run initializes the
512 placeholder host devices *before* importing anything from ``repro``.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(axes: tuple[str, ...]) -> dict:
    """``axis_types`` exists from jax 0.5; older releases default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * len(axes)}


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 = 256 chips/pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (smoke tests, elastic remesh plans)."""
    return jax.make_mesh(shape, axes, **_mesh_kwargs(axes))
