import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax-importing module (jax locks the
device count on first init). The dry-run proves the distribution config is
coherent without hardware:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        compiled.memory_analysis()   # fits?
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

Per cell it records a JSON blob (results/dryrun/) with per-device memory,
HLO FLOPs/bytes, and per-collective byte counts parsed from the optimized
HLO — the inputs to EXPERIMENTS.md §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k [--multi-pod] [--rules k=v ...]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import SHAPE_CELLS, cell_applicable
from ..configs.registry import ARCH_IDS, get_config
from ..models.model_zoo import build_model
from ..runtime import sharding as shd
from ..runtime import serve as serve_rt
from ..runtime import train as train_rt
from .hlo_cost import hlo_cost
from .mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Bytes each device puts on the links, as a fraction of the RESULT size,
# for a ring/bidirectional implementation over a group of size n.
def _traffic_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return (n - 1) / n          # result is the gathered (full) buffer
    if op == "all-reduce":
        return 2.0 * (n - 1) / n    # reduce-scatter + all-gather phases
    if op == "reduce-scatter":
        return (n - 1) * 1.0        # result is the scattered (1/n) buffer
    if op == "all-to-all":
        return (n - 1) / n
    if op == "collective-permute":
        return 1.0
    return 1.0


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * b


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective payload bytes from optimized HLO."""
    out: dict[str, dict] = {op: {"count": 0, "bytes": 0.0, "raw_bytes": 0}
                            for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op_found = None
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", rhs):
                op_found = op
                break
        if not op_found or f"{op_found}-done" in rhs:
            continue
        # result shapes = everything before the op name
        head = rhs.split(op_found)[0]
        nbytes = sum(_shape_bytes(d, dims)
                     for d, dims in _SHAPE_RE.findall(head))
        gm = _GROUPS_RE.search(rhs)
        if gm:
            group = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(rhs)
            group = int(gi.group(2)) if gi else 2
        rec = out[op_found]
        rec["count"] += 1
        rec["raw_bytes"] += nbytes
        rec["bytes"] += nbytes * _traffic_factor(op_found, group)
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def input_specs(arch: str, shape: str, cfg=None) -> dict:
    """Abstract (ShapeDtypeStruct) inputs for a cell — never allocates."""
    cfg = cfg or get_config(arch)
    cell = SHAPE_CELLS[shape]
    model = build_model(cfg)
    B, S = cell.global_batch, cell.seq_len
    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cell.kind == "train":
        return {"tokens": tok(B, S), "labels": tok(B, S),
                **model.extra_inputs(B, S, abstract=True)}
    if cell.kind == "prefill":
        return {"tokens": tok(B, S),
                **model.extra_inputs(B, S, abstract=True)}
    # decode: one new token over a cache of length S
    return {"tokens": tok(B, 1)}


def depth_variants(cfg):
    """(base_overrides, [(var_overrides, scale), ...]) for cost extrapolation.

    XLA's cost analysis counts a while-loop body once regardless of trip
    count, so per-layer costs are measured from fully-unrolled shallow
    variants at FULL width/sharding and extrapolated linearly:
        cost_full = cost(base) + sum_k (cost(var_k) - cost(base)) * scale_k
    Exact for FLOPs (group layers are homogeneous); collective/byte counts
    extrapolate the same way.
    """
    L = cfg.n_layers
    if cfg.family == "encdec":
        E = cfg.n_encoder_layers
        return (dict(n_layers=1, n_encoder_layers=1),
                [(dict(n_layers=2, n_encoder_layers=1), L - 1),
                 (dict(n_layers=1, n_encoder_layers=2), E - 1)])
    if cfg.family == "hybrid":
        per = cfg.shared_attn_every
        full, rest = divmod(L, per)
        base = dict(n_layers=per + 1)      # 1 group + 1 tail layer
        var = [(dict(n_layers=2 * per + 1), full - 1)]
        if rest:
            var.append((dict(n_layers=per + 2), rest - 1))
        return base, var
    if cfg.family == "vlm":
        ce = cfg.vision.cross_every
        return dict(n_layers=ce), [(dict(n_layers=2 * ce), L // ce - 1)]
    if cfg.alt_local_global:
        return dict(n_layers=2), [(dict(n_layers=4), L // 2 - 1)]
    if cfg.family == "moe" and cfg.moe.first_k_dense:
        k = cfg.moe.first_k_dense
        return dict(n_layers=k + 1), [(dict(n_layers=k + 2), L - k - 1)]
    return dict(n_layers=1), [(dict(n_layers=2), L - 1)]


def _lower_cell(cfg, cell, mesh, *, rules=None, opts_over=None,
                scan_unroll=1):
    """Build + lower the cell's step function. Returns the Lowered object."""
    model = build_model(cfg)
    B, S = cell.global_batch, cell.seq_len
    opts_over = opts_over or {}
    # jax.set_mesh arrived in 0.6; on older jax the Mesh is its own context
    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx:
        if cell.kind == "train":
            opts = train_rt.TrainOptions(**{"remat_policy": "full",
                                            "microbatches": 1,
                                            "scan_unroll": scan_unroll,
                                            **opts_over})
            step = train_rt.build_train_step(model, opts, mesh, rules)
            st_abs = train_rt.abstract_train_state(model, opts)
            st_sh = train_rt.state_shardings(model, mesh, opts, rules)
            batch_abs = input_specs(cfg.name, cell.name, cfg)
            b_sh = train_rt.batch_shardings(batch_abs, mesh)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))
            return jitted.lower(st_abs, batch_abs)
        if cell.kind == "prefill":
            sopts = serve_rt.ServeOptions(**{"scan_unroll": scan_unroll,
                                             **opts_over})
            fn, (p_abs, in_abs, cache_abs) = serve_rt.jit_prefill_step(
                model, sopts, mesh, B, S, rules=rules)
            return fn.lower(p_abs, in_abs, cache_abs)
        sopts = serve_rt.ServeOptions(**{"scan_unroll": scan_unroll,
                                         **opts_over})
        fn, (p_abs, cache_abs) = serve_rt.jit_decode_step(
            model, sopts, mesh, B, S, enc_len=model.enc_len_for(S),
            rules=rules)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
        return fn.lower(p_abs, cache_abs, tok_abs, idx_abs)


def kernel_io_per_device(cfg, cell, n_dev: int) -> float:
    """Analytic HBM IO of the Pallas kernels, per device per step.

    The dry-run lowers the CPU stand-ins (chunked jnp scans) whose
    intermediates materialize; on TPU the Pallas kernels keep them in VMEM
    and touch HBM only for their operands/results. This substitutes that
    true IO for the depth>=2 loop traffic hlo_cost excludes.

    flash attention fwd:  (Q + O + K + V) once       [x4.5 for train:
    ssd scan fwd:         (x + y + B + C + states)    fwd + recompute + bwd]
    decode attention:     read the whole KV cache + write one token.
    """
    from ..models.transformer import layer_plan, encoder_plan
    B, S = cell.global_batch, cell.seq_len
    hd = cfg.head_dim_
    train_f = 4.5 if cell.kind == "train" else 1.0
    total = 0.0

    def attn_io(S_q, S_kv, decode=False):
        if decode:
            return 2.0 * (2 * B * S_kv * cfg.n_kv_heads * hd
                          + 2 * B * 1 * cfg.n_kv_heads * hd
                          + 2 * B * 1 * cfg.n_heads * hd)
        return 2.0 * (2 * B * S_q * cfg.n_heads * hd
                      + 2 * B * S_kv * cfg.n_kv_heads * hd)

    def ssm_io():
        from ..models.ssm import ssm_dims
        s = cfg.ssm
        _, d_inner, nh, _ = ssm_dims(cfg)
        chunks = max(S // max(s.chunk_size, 1), 1)
        return (2.0 * 2 * B * S * d_inner
                + 2.0 * 2 * B * S * s.n_groups * s.d_state
                + 4.0 * chunks * B * nh * s.head_dim * s.d_state)

    def moe_io():
        m = cfg.moe
        # dispatch buffer in/out of the 3 grouped matmuls + expert weights
        # streamed once per step (the dominant decode term for big MoE)
        cap = max(8, int(B * (1 if cell.kind == "decode" else S)
                         * m.top_k * m.capacity_factor / m.num_experts) + 1)
        buf = m.num_experts * cap * cfg.d_model * 2.0
        hid = m.num_experts * cap * m.d_ff_expert * 2.0
        weights = m.num_experts * 3 * cfg.d_model * m.d_ff_expert * 2.0
        return (4 * buf + 3 * hid + weights) * train_f

    groups = list(layer_plan(cfg))
    if cfg.family == "encdec":
        groups += list(encoder_plan(cfg))
    dec = cell.kind == "decode"
    for gd in groups:
        for b in gd.blocks:
            if b.kind in ("attn", "parallel", "shared_attn"):
                total += gd.repeat * (attn_io(1, S, decode=True) if dec
                                      else attn_io(S, S) * train_f)
            elif b.kind == "cross_attn":
                enc = (cfg.vision.num_patches if cfg.family == "vlm"
                       else S)
                total += gd.repeat * (attn_io(1, enc, decode=True) if dec
                                      else attn_io(S, enc) * train_f)
            elif b.kind == "ssm" and not dec:
                total += gd.repeat * ssm_io() * train_f
            elif b.kind == "ssm" and dec:
                total += gd.repeat * 2.0 * B * (
                    2 * cfg.ssm.expand * cfg.d_model)
            elif b.kind == "moe":
                total += gd.repeat * moe_io()
    return total / n_dev


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0) or 0.0),
            "bytes": float(cost.get("bytes accessed", 0.0) or 0.0),
            "coll": coll}


def _extrapolate(base: dict, variants: list[tuple[dict, float]]) -> dict:
    out = {"flops": base["flops"], "bytes": base["bytes"],
           "coll": {}, "coll_total": base["coll"]["total_bytes"]}
    for op in _COLLECTIVES:
        out["coll"][op] = dict(base["coll"][op])
    for var, scale in variants:
        out["flops"] += (var["flops"] - base["flops"]) * scale
        out["bytes"] += (var["bytes"] - base["bytes"]) * scale
        out["coll_total"] += (var["coll"]["total_bytes"]
                              - base["coll"]["total_bytes"]) * scale
        for op in _COLLECTIVES:
            for k in ("count", "bytes", "raw_bytes"):
                out["coll"][op][k] += (var["coll"][op][k]
                                       - base["coll"][op][k]) * scale
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, rules=None,
             opts_over=None, verbose: bool = True,
             skip_variants: bool = False, serving_rules: bool = False) -> dict:
    if serving_rules:   # §Perf optimized sharding for serve cells
        cell0 = SHAPE_CELLS[shape]
        if cell0.kind != "train":
            rules = dict(shd.SERVING_RULES, **(rules or {}))
            opts_over = dict(opts_over or {}, expert_tp=True)
            if cell0.kind == "decode":      # §Perf B2
                opts_over["moe_capacity_cap"] = 4
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    model = build_model(cfg)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "kind": cell.kind, "ok": False,
                 "serving_rules": serving_rules}
    if not cell_applicable(cfg, cell):
        rec.update(skipped=True,
                   reason="full-attention arch at 500k ctx (DESIGN.md §4)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    B, S = cell.global_batch, cell.seq_len

    # 1) the real artifact: full depth, scan-over-layers -> memory analysis
    t0 = time.time()
    lowered = _lower_cell(cfg, cell, mesh, rules=rules, opts_over=opts_over)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    full_cost = _cost_of(compiled)
    # v2: production-artifact accounting (hlo_cost) + Pallas-kernel IO
    v2 = hlo_cost(compiled.as_text())
    n_dev = {"16x16": 256, "2x16x16": 512}[mesh_name]
    kio = kernel_io_per_device(cfg, cell, n_dev)

    # 2) per-layer costs: decode graphs are small -> cost the fully
    # unrolled lowering directly (exact); train/prefill use shallow
    # unrolled variants extrapolated over depth (exact for FLOPs).
    if cell.kind == "decode":
        unrolled = _cost_of(_lower_cell(cfg, cell, mesh, rules=rules,
                                        opts_over=opts_over,
                                        scan_unroll=4096).compile())
        cost = {"flops": unrolled["flops"], "bytes": unrolled["bytes"],
                "coll": {op: unrolled["coll"][op] for op in _COLLECTIVES},
                "coll_total": unrolled["coll"]["total_bytes"]}
    elif skip_variants:
        cost = {"flops": full_cost["flops"], "bytes": full_cost["bytes"],
                "coll": full_cost["coll"],
                "coll_total": full_cost["coll"]["total_bytes"]}
        cost["coll"] = {op: full_cost["coll"][op] for op in _COLLECTIVES}
    else:
        base_over, var_overs = depth_variants(cfg)
        base_cost = _cost_of(_lower_cell(
            cfg.replace(**base_over), cell, mesh, rules=rules,
            opts_over=opts_over, scan_unroll=64).compile())
        var_costs = [
            (_cost_of(_lower_cell(cfg.replace(**vo), cell, mesh, rules=rules,
                                  opts_over=opts_over,
                                  scan_unroll=64).compile()), sc)
            for vo, sc in var_overs]
        cost = _extrapolate(base_cost, var_costs)

    rec.update(
        ok=True,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        total_s=round(time.time() - t0, 1),
        flops_per_device=cost["flops"],
        hbm_bytes_per_device=cost["bytes"],
        collective_bytes_per_device=cost["coll_total"],
        # v2 (production artifact): see launch/hlo_cost.py
        v2_bytes_per_device=v2["bytes_outer"] + kio,
        v2_bytes_outer=v2["bytes_outer"],
        v2_bytes_alldepth=v2["bytes"],
        v2_kernel_io=kio,
        v2_collective_bytes_per_device=v2["coll_total"],
        v2_collectives={op: v2["coll"][op] for op in _COLLECTIVES},
        collectives={op: cost["coll"][op] for op in _COLLECTIVES},
        scan_cost_raw=full_cost,       # un-extrapolated (body-once) numbers
        memory={
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", -1)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", -1)),
        },
        params_total=model.param_count(),
        params_active=model.active_param_count(),
        global_batch=B, seq_len=S,
    )
    if verbose:
        m = rec["memory"]
        live = m["argument_bytes"] + m["temp_bytes"] - max(m["alias_bytes"], 0)
        print(f"[dryrun] {arch} {shape} {mesh_name}: "
              f"compile={t_compile:.0f}s total={rec['total_s']:.0f}s "
              f"flops/dev={cost['flops']:.3e} "
              f"v2bytes/dev={rec['v2_bytes_per_device']:.3e} "
              f"v2coll/dev={rec['v2_collective_bytes_per_device']:.3e}B "
              f"live/dev={live:.3e}B")
    return rec


def save_record(rec: dict, out_dir: str = RESULTS_DIR):
    os.makedirs(out_dir, exist_ok=True)
    suffix = "_opt" if rec.get("serving_rules") else ""
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPE_CELLS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--serving-rules", action="store_true",
                    help="optimized serve-time sharding (EXPERIMENTS §Perf)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    cells = ([(a, s) for a in ARCH_IDS for s in SHAPE_CELLS]
             if args.all else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               serving_rules=args.serving_rules)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16", "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
            save_record(rec, args.out)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f["arch"], f["shape"], f["mesh"], "->", f["error"])
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
