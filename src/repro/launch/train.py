"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop (checkpoint/restart + straggler
monitor) for any assigned architecture. On this CPU container use
``--reduced`` (the default) — full configs are exercised via the dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU pod: ``--mesh data,model --mesh-shape 16,16`` builds the
production mesh and jits with explicit shardings (same code path the
dry-run compiles).
"""
from __future__ import annotations

import argparse
import time

import jax

from ..checkpointing.manager import CheckpointManager
from ..configs.registry import ARCH_IDS, get_config
from ..data.pipeline import DataConfig, DataIterator
from ..models.model_zoo import build_model
from ..optim.adamw import AdamWConfig
from ..runtime import train as train_rt
from ..runtime.fault_tolerance import (RestartPolicy, StragglerMonitor,
                                       run_with_restarts)
from .mesh import make_mesh


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None,
                    choices=(None, "full", "dots", "minimal"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--moment-dtype", default="float32",
                    choices=("float32", "bfloat16", "int8"))
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--mesh", default="")           # e.g. "data,model"
    ap.add_argument("--mesh-shape", default="")     # e.g. "16,16"
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    opts = train_rt.TrainOptions(
        remat_policy=args.remat, microbatches=args.microbatches,
        opt=AdamWConfig(lr=args.lr, moment_dtype=args.moment_dtype),
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)

    mesh = None
    if args.mesh:
        axes = tuple(args.mesh.split(","))
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        mesh = make_mesh(shape, axes)

    state = train_rt.init_train_state(model, jax.random.PRNGKey(args.seed),
                                      opts)
    if mesh is not None:
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), "int32"),
            "labels": jax.ShapeDtypeStruct((args.batch, args.seq), "int32")}
        step_fn = train_rt.jit_train_step(model, opts, mesh, batch_abs)
    else:
        step_fn = jax.jit(train_rt.build_train_step(model, opts))

    data = DataIterator(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch,
                                   seed=args.seed), model_cfg=cfg)
    ckpt = CheckpointManager(args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}",
                             keep=2)
    # auto-resume
    restored, at = ckpt.restore({"state": state, "data": data.state()})
    if restored is not None:
        state = restored["state"]
        data.restore(restored["data"])
        print(f"[train] resumed from step {at}")

    mon = StragglerMonitor()
    t0 = time.time()

    def timed_step(state, batch):
        ts = time.time()
        out = step_fn(state, batch)
        jax.block_until_ready(out[1]["loss"])
        mon.record("worker0", time.time() - ts)
        return out

    state, history, failures = run_with_restarts(
        num_steps=args.steps, state=state, data_iter=data,
        step_fn=timed_step, ckpt_manager=ckpt, save_every=args.save_every,
        policy=RestartPolicy(max_failures=3), log=print)
    dt = time.time() - t0
    losses = [h["loss"] for h in history]
    print(f"[train] {args.arch} {len(history)} steps in {dt:.1f}s "
          f"({dt / max(len(history), 1):.2f}s/step)  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
          f"failures survived: {failures}")
    return {"loss_first": losses[0], "loss_last": losses[-1],
            "steps": len(history), "failures": failures}


if __name__ == "__main__":
    main()
