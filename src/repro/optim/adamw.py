"""AdamW in plain JAX with sharded, optionally low-precision moments.

Moments inherit the parameter shardings (ZeRO-style: they live wherever the
FSDP/TP rules put the parameter), so optimizer state never concentrates on
one device. ``moment_dtype``: float32 (default) | bfloat16 | int8 — int8
moments use per-tensor absmax scaling (beyond-paper memory lever recorded in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def _q_store(x, dtype: str):
    if dtype == "float32":
        return x.astype(jnp.float32)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    raise ValueError(dtype)


def _q_load(x):
    if isinstance(x, dict):
        return x["q"].astype(jnp.float32) * x["scale"]
    return x.astype(jnp.float32)


def init_opt_state(params, cfg: AdamWConfig):
    def zeros():
        return jax.tree.map(
            lambda p: _q_store(jnp.zeros(p.shape, jnp.float32),
                               cfg.moment_dtype), params)

    return {"m": zeros(), "v": zeros(), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig, lr=None):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0

    def is_q(x):
        return isinstance(x, dict) and "q" in x

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _q_load(m)
        v_f = _q_load(v)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        m_hat = m_f / (1 - cfg.b1 ** count.astype(jnp.float32))
        v_hat = v_f / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _q_store(m_f, cfg.moment_dtype), _q_store(v_f, cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_q)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
