"""Int8 error-feedback gradient compression (1-bit-Adam family, arXiv:2102.02888).

Used on the cross-pod synchronization path (the scarce inter-pod ICI links):
gradients are quantized to int8 with per-tensor absmax scales before the pod
all-reduce; the quantization residual is fed back into the next step's
gradient (error feedback preserves convergence).

Within pjit, backward-pass reductions are XLA-inserted and not interceptable,
so this module is applied where the framework controls the collective
explicitly: the elastic/async cross-pod sync in ``runtime.fault_tolerance``
and the shard_map reduction in ``runtime.train.build_train_step`` when
``compress_pod_sync=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(g, err):
    """Returns (quantized int8, scale, new error residual)."""
    g = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, err, axis_name):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    The int8 payload is what crosses the links; the fp32 scale is reduced
    with a (tiny) separate max-reduce so all shards dequantize identically.
    """
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)           # shared scale
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(1, axis_name)
    return (total.astype(jnp.float32) * scale / n), new_err


def compression_ratio(tree) -> float:
    """HBM/link bytes saved: fp32 -> int8 + one scale per tensor."""
    raw = sum(x.size * 4 for x in jax.tree.leaves(tree))
    comp = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return raw / comp
