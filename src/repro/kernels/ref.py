"""Pure-jnp reference oracles for every Pallas kernel.

Two tiers per op:

- ``*_naive``   : materializes the full intermediate (scores / states). The
                  ground-truth oracle for kernel tests.
- ``*_chunked`` : flash-style chunked jnp implementation (scan over blocks,
                  online softmax / recurrent state). Numerically equal to the
                  naive tier but with O(block) intermediates — this is the
                  CPU / dry-run execution path, and the mathematical twin of
                  the Pallas kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask(q_pos, k_pos, *, causal: bool, window: int, kv_len=None):
    """Boolean mask (..., q, k): True = attend."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window:
        m &= qp - kp < window
    if kv_len is not None:
        m &= kp < kv_len[..., None, None]
    return m


def mha_naive(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
              scale=None, q_offset=0, kv_len=None):
    """Full-scores attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KVH, D). GQA via head grouping.
    q_offset: absolute position of q[0] (for decode).
    kv_len: optional (B,) valid kv lengths (for cache decode).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    g = H // KVH
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    qf = qf.reshape(B, Sq, KVH, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = _softcap(s, logit_softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    m = _mask(q_pos[None], k_pos[None], causal=causal, window=window,
              kv_len=kv_len)  # (B or 1, q, k)
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def mha_chunked(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                scale=None, q_offset=0, kv_len=None, block_k=1024):
    """Flash-style online-softmax attention, scanning over kv blocks.

    Same signature/semantics as :func:`mha_naive`; intermediates are
    O(Sq * block_k) instead of O(Sq * Sk).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    g = H // KVH
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, Sk)
    pad = (-Sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KVH, g, D)
    q_pos = jnp.arange(Sq) + q_offset
    kb = k.reshape(B, nblk, block_k, KVH, D).astype(jnp.float32)
    vb = v.reshape(B, nblk, block_k, KVH, D).astype(jnp.float32)

    def step(carry, blk):
        m_run, l_run, acc = carry
        kc, vc, start = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc)
        s = _softcap(s, logit_softcap)
        k_pos = start + jnp.arange(block_k)
        msk = _mask(q_pos[None], k_pos[None], causal=causal, window=window,
                    kv_len=kv_len)  # (B or 1, q, k)
        valid = k_pos < Sk
        msk = msk & valid[None, None, :]
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KVH, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, g, Sq, D), jnp.float32)
    starts = jnp.arange(nblk) * block_k
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), starts))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------


def ssd_naive(x, dt, a_log, b, c, d_skip, *, chunk_size=None):
    """Quadratic-time SSD reference.

    x:  (B, L, H, P) inputs        dt: (B, L, H) softplus'd step sizes
    a_log: (H,) (A = -exp(a_log))  b, c: (B, L, G, N) input/output projections
    d_skip: (H,) skip connection.  Heads map to groups h -> h // (H // G).
    y_t = sum_{s<=t} exp(sum_{r=s+1..t} dt_r*A) (C_t.B_s) dt_s x_s + D x_t
    Returns y (B, L, H, P) and final state (B, H, P, N).
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    A = -jnp.exp(a_log.astype(jnp.float32))                       # (H,)
    dtf = dt.astype(jnp.float32)
    log_a = dtf * A                                               # (B,L,H)
    cum = jnp.cumsum(log_a, axis=1)                               # (B,L,H)
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)           # (B,L,H,N)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    u = x.astype(jnp.float32) * dtf[..., None]                    # (B,L,H,P)

    cb = jnp.einsum("bthn,bshn->bhts", ch, bh)                    # (B,H,L,L)
    decay = jnp.exp(cum.transpose(0, 2, 1)[:, :, :, None]
                    - cum.transpose(0, 2, 1)[:, :, None, :])      # (B,H,t,s)
    causal = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(causal, cb * decay, 0.0)
    y = jnp.einsum("bhts,bshp->bthp", w, u)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]

    # final state: S = sum_s exp(cum_L - cum_s) u_s b_s^T
    w_end = jnp.exp(cum[:, -1][:, None] - cum).transpose(0, 2, 1)  # (B,H,L)
    state = jnp.einsum("bhs,bshp,bshn->bhpn", w_end, u, bh)
    return y.astype(x.dtype), state


def ssd_chunked(x, dt, a_log, b, c, d_skip, *, chunk_size=128):
    """Chunked SSD: dense intra-chunk + sequential inter-chunk recurrence.

    Mathematical twin of the Pallas ``ssd_scan`` kernel. Same returns as
    :func:`ssd_naive`.
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    Q = min(chunk_size, L)
    assert L % Q == 0, f"L={L} must divide chunk {Q}"
    nc = L // Q
    A = -jnp.exp(a_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    u = x.astype(jnp.float32) * dtf[..., None]
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)

    uc = u.reshape(B, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    bc = bh.reshape(B, nc, Q, H, N).transpose(1, 0, 2, 3, 4)
    cc = ch.reshape(B, nc, Q, H, N).transpose(1, 0, 2, 3, 4)
    la = (dtf * A[None, None]).reshape(B, nc, Q, H).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, blk):
        u_, b_, c_, la_ = blk                     # (B,Q,H,P/N), (B,Q,H)
        cum = jnp.cumsum(la_, axis=1)             # (B,Q,H)
        cum_t = cum.transpose(0, 2, 1)            # (B,H,Q)
        cb = jnp.einsum("bthn,bshn->bhts", c_, b_)
        decay = jnp.exp(cum_t[:, :, :, None] - cum_t[:, :, None, :])
        w = jnp.where(causal, cb * decay, 0.0)
        y = jnp.einsum("bhts,bshp->bthp", w, u_)
        # contribution from carried state
        y = y + jnp.einsum("bthn,bhpn->bthp", c_, state) * jnp.exp(cum)[..., None]
        # state update
        tot = cum_t[:, :, -1]                                     # (B,H)
        w_end = jnp.exp(tot[:, :, None] - cum_t)                  # (B,H,Q)
        s_loc = jnp.einsum("bhs,bshp,bshn->bhpn", w_end, u_, b_)
        state = state * jnp.exp(tot)[..., None, None] + s_loc
        return state, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    state, ys = jax.lax.scan(chunk_step, s0, (uc, bc, cc, la))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H, P)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """Single-token recurrent update.

    state: (B,H,P,N); x_t: (B,H,P); dt_t: (B,H); b_t, c_t: (B,G,N).
    Returns y_t (B,H,P), new state.
    """
    H = x_t.shape[1]
    G = b_t.shape[1]
    rep = H // G
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt_t.astype(jnp.float32) * A[None])               # (B,H)
    u = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)          # (B,H,N)
    ch = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    state = state * a[..., None, None] + u[..., None] * bh[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    y = y + x_t.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# Grouped (per-expert) matmul
# ---------------------------------------------------------------------------


def gmm_naive(x, w):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f) with fp32 accumulation."""
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
