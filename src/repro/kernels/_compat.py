"""Pallas-TPU API compatibility across jax versions.

``pltpu.CompilerParams`` (new name) was ``pltpu.TPUCompilerParams`` before
jax 0.5; older releases again spell it ``dict``-compatible via
``mosaic.params``. Resolve whichever this jax ships.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(**kw):
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:                       # ancient fallback: plain mapping
        return dict(mosaic=kw)
    return cls(**kw)
