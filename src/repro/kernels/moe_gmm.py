"""Pallas TPU grouped (per-expert) matmul for MoE expert FFNs.

MegaBlocks-style grouped GEMM adapted to the TPU: tokens are pre-gathered into
a dense (E, C, d) capacity buffer (sort-based dispatch lives in
``repro.models.moe``), so the kernel is a bank of E independent GEMMs tiled
for the MXU:

grid = (E, C/bc, f/bf, d/bd); the contraction dim is innermost/``arbitrary``
with an fp32 (bc, bf) VMEM accumulator. Block sizes default to 128 (MXU
native) and are clamped to the problem size.

Oracle: ``ref.gmm_naive``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params as _compiler_params


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]                                # (bc, bd)
    w = w_ref[0]                                # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _emit():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_f", "block_d",
                                    "interpret"))
def gmm_pallas(x, w, *, block_c=128, block_f=128, block_d=512,
               interpret=False):
    """x: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    _, _, f = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_d = min(block_d, d)

    def _pad_to(a, axis, mult):
        pad = (-a.shape[axis]) % mult
        if pad:
            widths = [(0, 0)] * a.ndim
            widths[axis] = (0, pad)
            a = jnp.pad(a, widths)
        return a

    x = _pad_to(_pad_to(x, 1, block_c), 2, block_d)
    w = _pad_to(_pad_to(w, 1, block_d), 2, block_f)
    Cp, dp, fp = x.shape[1], x.shape[2], w.shape[2]

    grid = (E, Cp // block_c, fp // block_f, dp // block_d)
    out = pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :f]
