"""Jit'd kernel wrappers with backend dispatch.

On TPU the Pallas kernels run natively; on CPU (tests, dry-run lowering) we
execute the chunked pure-jnp twins from ``ref.py`` — identical math, scan-based
so the lowered HLO keeps O(block) intermediates (this is what makes the
dry-run roofline's memory term honest; see EXPERIMENTS.md §Roofline).

Set ``REPRO_FORCE_REF=1`` to force the reference path everywhere, or
``REPRO_PALLAS_INTERPRET=1`` to run Pallas kernels in interpret mode (slow;
kernel tests do this explicitly with small shapes).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .moe_gmm import gmm_pallas
from .ssd_scan import ssd_scan_pallas


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    return jax.default_backend() == "tpu" or bool(
        os.environ.get("REPRO_PALLAS_INTERPRET"))


def _interpret() -> bool:
    return bool(os.environ.get("REPRO_PALLAS_INTERPRET"))


def flash_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    scale=None, q_offset=0, kv_len=None, block_k=1024):
    """Multi-head GQA attention; see ``ref.mha_naive`` for semantics.

    kv_len: None, python int, or (B,) array of valid cache lengths.

    The ``pk_`` named scope marks the Pallas-kernel boundary: the dry-run
    cost model (launch/hlo_cost.py) excludes pk_-tagged instructions (the
    CPU stand-in materializes what the kernel keeps in VMEM) and accounts
    the kernel's true HBM IO analytically (launch/dryrun.py).
    """
    with jax.named_scope("pk_flash_attention"):
        if _use_pallas() and not isinstance(kv_len, jax.Array):
            return flash_attention_pallas(
                q, k, v, causal=causal, window=window, softcap=logit_softcap,
                scale=scale, q_offset=q_offset,
                kv_valid=kv_len if kv_len is not None else None,
                interpret=_interpret())
        kv = kv_len
        if isinstance(kv, int):
            kv = jnp.full((q.shape[0],), kv, jnp.int32)
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               logit_softcap=logit_softcap, scale=scale,
                               q_offset=q_offset, kv_len=kv, block_k=block_k)


def decode_attention(q, k, v, *, window=0, logit_softcap=0.0, scale=None,
                     q_offset, kv_len, bf16_kv: bool = True):
    """Single-token (Sq small) attention over a cache; plain jnp GEMV path.

    q_offset/kv_len may be traced arrays (dynamic decode position).

    bf16_kv (perf, EXPERIMENTS.md §Perf A1): contract K/V in their stored
    dtype with fp32 accumulation (``preferred_element_type``) instead of
    upcasting — an ``astype(f32)`` here makes XLA hoist a full-cache fp32
    copy out of the decode loop (2x HBM for the cache + 2x read traffic).
    The softmax stays fp32; P is fed to the PV product in bf16 (exactly the
    MXU mixed-precision scheme the Pallas flash kernel uses).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    g = H // KVH
    scale = scale if scale is not None else D ** -0.5
    ns = jax.named_scope("pk_decode_attention")
    ns.__enter__()
    if bf16_kv:
        qf = q.reshape(B, Sq, KVH, g, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                       preferred_element_type=jnp.float32) * scale
    else:
        qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KVH, g, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)  # (B?,Sq)
    q_pos = jnp.broadcast_to(q_pos, (B, Sq))
    k_pos = jnp.arange(Sk)
    m = k_pos[None, None, :] <= q_pos[..., None]
    kv = jnp.broadcast_to(jnp.asarray(kv_len), (B,))
    m &= k_pos[None, None, :] < kv[:, None, None]
    if window:
        m &= q_pos[..., None] - k_pos[None, None, :] < window
    s = jnp.where(m[:, None, None], s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if bf16_kv:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    out = o.reshape(B, Sq, H, D).astype(q.dtype)
    ns.__exit__(None, None, None)
    return out


def ssd_scan(x, dt, a_log, b, c, d_skip, *, chunk=128):
    with jax.named_scope("pk_ssd_scan"):
        if _use_pallas():
            return ssd_scan_pallas(x, dt, a_log, b, c, d_skip, chunk=chunk,
                                   interpret=_interpret())
        return ref.ssd_chunked(x, dt, a_log, b, c, d_skip, chunk_size=chunk)


def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    return ref.ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip)


def gmm(x, w):
    """Grouped per-expert matmul: (E, C, d) @ (E, d, f) -> (E, C, f)."""
    with jax.named_scope("pk_gmm"):
        if _use_pallas():
            return gmm_pallas(x, w, interpret=_interpret())
        return ref.gmm_naive(x, w)
