"""Pallas TPU flash attention (causal / sliding-window / softcap, GQA).

Blockwise online-softmax attention tiled for VMEM/MXU:

- grid = (batch, q_heads, Sq/block_q, Sk/block_k); the kv dim is innermost and
  ``arbitrary`` so fp32 scratch (acc, running max, running sum) carries across
  kv iterations.
- BlockSpecs stage (block_q, head_dim) of Q and (block_k, head_dim) of K/V
  into VMEM per step; blocks are 128-aligned for the MXU.
- GQA is expressed in the K/V index_map (q head -> kv head), so no KV
  repetition ever hits HBM.

The oracle is ``ref.mha_naive``; ``ops.flash_attention`` dispatches here on
TPU and to ``ref.mha_chunked`` on CPU (same math, jnp scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params as _compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 block_q: int, block_k: int, q_offset: int, kv_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_valid
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "kv_valid", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, softcap=0.0,
                           scale=None, q_offset=0, kv_valid=None,
                           block_q=128, block_k=128, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KVH, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else D ** -0.5
    kv_valid = Sk if kv_valid is None else kv_valid

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq_p, Sk_p = q.shape[1], k.shape[1]
    # (B, S, H, D) -> (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sq_p // block_q, Sk_p // block_k)
    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        q_offset=q_offset, kv_valid=min(kv_valid, Sk))

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pq:
        out = out[:, :Sq]
    return out


def vmem_bytes(block_q: int, block_k: int, d: int, dtype_bytes: int = 2) -> int:
    """Working-set estimate used by block-size selection (ops.py)."""
    io = (block_q + 2 * block_k) * d * dtype_bytes + block_q * d * dtype_bytes
    scratch = 4 * (block_q * d + 2 * block_q)
    scores = 4 * block_q * block_k
    return io + scratch + scores
