"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) scan.

TPU adaptation of the chunked SSD algorithm (arXiv:2405.21060): the sequence
is tiled into chunks of Q tokens; within a chunk the recurrence is expanded
into a dense (Q x Q) decay-masked matmul (MXU work), while the cross-chunk
recurrence is carried in an fp32 VMEM scratch state of shape (P, N) across the
innermost (``arbitrary``) grid dimension. This replaces the GPU
warp-level-scan formulation with a systolic-friendly block recurrence.

grid = (B, H, L/Q). Inputs are laid out head-major so each program instance
streams (Q, P) / (Q, N) tiles through VMEM.

Oracle: ``ref.ssd_naive`` / ``ref.ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import compiler_params as _compiler_params


def _ssd_kernel(u_ref, la_ref, b_ref, c_ref, y_ref, state_ref, s_scr, *,
                chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0, 0].astype(jnp.float32)        # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)      # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)        # (Q, N)

    cum = jnp.cumsum(la)                       # (Q,)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(si <= ti, cb * decay, 0.0)
    y = jax.lax.dot_general(w, u, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)
    # carried-state contribution: y_t += exp(cum_t) * (c_t . S_prev)
    y_state = jax.lax.dot_general(c, s_scr[...], (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y + y_state * jnp.exp(cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S = exp(tot) * S_prev + sum_s exp(tot - cum_s) u_s b_s^T
    tot = cum[chunk - 1]
    w_end = jnp.exp(tot - cum)                 # (Q,)
    s_loc = jax.lax.dot_general(u * w_end[:, None], b,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    s_scr[...] = s_scr[...] * jnp.exp(tot) + s_loc

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a_log, b, c, d_skip, *, chunk=128,
                    interpret=False):
    """Same contract as ``ref.ssd_chunked``.

    x: (B, L, H, P); dt: (B, L, H); a_log, d_skip: (H,);
    b, c: (B, L, G, N). Returns y (B, L, H, P), state (B, H, P, N) fp32.
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    chunk = min(chunk, L)
    assert L % chunk == 0, f"L={L} % chunk={chunk} != 0"
    nc = L // chunk

    A = -jnp.exp(a_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    la = (dtf * A[None, None]).transpose(0, 2, 1)           # (B, H, L)
    u = (x.astype(jnp.float32) * dtf[..., None]).transpose(0, 2, 1, 3)
    bt = b.transpose(0, 2, 1, 3)                            # (B, G, L, N)
    ct = c.transpose(0, 2, 1, 3)

    grid = (B, H, nc)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda ib, ih, ic, r=rep: (ib, ih // r, ic, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda ib, ih, ic, r=rep: (ib, ih // r, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, P, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, la, bt, ct)

    y = y.transpose(0, 2, 1, 3)
    y = y + x.astype(jnp.float32).astype(y.dtype) * \
        d_skip.astype(y.dtype)[None, None, :, None]
    return y, state
