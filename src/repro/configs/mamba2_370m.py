"""mamba2-370m — pure SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  chunk_size=256),
    source="arXiv:2405.21060",
)

REDUCED = CONFIG.replace(
    n_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1,
                  chunk_size=16))
