"""seamless-m4t-large-v2 — encoder-decoder speech/text model; the audio
frontend is a STUB (precomputed frame embeddings) [arXiv:2308.11596; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    use_bias=True,
    use_layernorm=True,
    mlp_act="gelu",
    tie_embeddings=True,
    source="arXiv:2308.11596",
)

REDUCED = CONFIG.replace(n_layers=2, n_encoder_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
