"""Multi-turn chat workflow: respond -> index the reply (session memory).

The stateful-serving scenario (DESIGN.md §9): every arrival is one *turn*
of an ongoing session. The ``chat_respond`` interface declares its token
model in history units — ``in_units="history_tokens"`` grows the prompt
with conversation length, ``prefix_units="history_tokens"`` marks that
history span as session-shared — so a turn served on an instance whose KV
cache holds the session's prefix pays prefill only for the new message.
Nothing in core knows chat exists; the engine sees ``prefix_tokens`` on
the lowered node and a ``session`` id on the job.

Deliberately *not* imported by ``SCENARIOS._ensure_builtin``: registering
the chat preset into ``default_mix()`` would shift the serving bench's
pinned baselines. Import this module explicitly (the cache bench and the
residency tests do) to register the scenario and its serving preset.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..core.spec import SCENARIOS, Scenario

# per-turn token geometry: the footprint arithmetic below makes each
# turn's history exactly the previous turn's full prompt+reply, so a
# session resident in an instance's KV cache serves the *entire* history
# prefix (see tests/test_cache_residency.py). MESSAGE/REPLY must match the
# chat_respond interface's TokenModel (tokens_in/tokens_out) for that
# identity to hold. The geometry is a tool-calling agent's: a fat system
# prompt (tool schemas + few-shot examples), fat per-turn context, short
# structured replies — prefill-compute-bound, where prefix reuse pays.
SYSTEM_TOKENS = 6000      # session-constant system prompt + tool schemas
MESSAGE_TOKENS = 640      # one user message + retrieved/tool context
REPLY_TOKENS = 24         # one short structured (tool-call) reply


@dataclass(frozen=True)
class ChatTurnInput:
    """One user turn of an ongoing chat session."""

    session: str
    turn: int = 0
    message_tokens: int = MESSAGE_TOKENS
    reply_tokens: int = REPLY_TOKENS
    system_tokens: int = SYSTEM_TOKENS

    artifact = "chat_turn"

    def units(self) -> dict[str, int]:
        """Unit breakdown driving interface cardinality/token models."""
        history = self.system_tokens + \
            self.turn * (self.message_tokens + self.reply_tokens)
        return {"turns": 1, "history_tokens": history}


CHAT_SCENARIO = SCENARIOS.register(Scenario(
    name="chat_agent",
    input_artifacts=("chat_turn",),
    default_tasks=(
        "Respond to the user's chat message with the assistant reply",
    ),
    aggregate_tasks=(
        "Insert the reply embedding into the session memory vector index",
    ),
    arg_builders={
        "chat_respond": lambda job: {"message": "$chat_turn",
                                     "max_tokens": REPLY_TOKENS},
        "embed": lambda job: {"texts": "$chat_reply"},
    }))


def make_chat_job(constraints=None, session: str = "", turn: int = 0):
    """Declarative chat-turn job (session-aware: one job per turn)."""
    from ..core.workflow import MIN_COST, Job
    return Job(
        description=f"Serve chat turn {turn} of an ongoing session",
        inputs=(ChatTurnInput(session=session or "adhoc", turn=turn),),
        constraints=MIN_COST if constraints is None else constraints,
        quality_floor={"chat_respond": 0.85, "embed": 0.85},
        session=session)


# -- open-loop serving preset (core/arrivals.py) ------------------------------
# interactive chat: tight SLO, session-aware lowering (one template per
# turn index — history grows the token footprint)
from ..core.arrivals import ServingPreset, register_preset  # noqa: E402

SERVING_PRESET = register_preset(ServingPreset(
    scenario="chat", make_job=make_chat_job, weight=0.35, base_slo_s=30.0,
    session_aware=True))
