"""Base configuration dataclasses for the model zoo and shape cells.

Every assigned architecture instantiates :class:`ModelConfig` (see the per-arch
files in this package). ``reduced()`` produces the CPU-smoke-test variant of a
config; the full configs are only ever lowered abstractly via the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class MoEConfig:
    """Fine-grained mixture-of-experts settings (DeepSeekMoE-style)."""

    num_experts: int = 0            # routed experts
    top_k: int = 0
    num_shared: int = 0             # always-on shared experts
    d_ff_expert: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01   # load-balance auxiliary loss
    first_k_dense: int = 0          # leading dense layers (DeepSeek/Kimi style)
    d_ff_dense: int = 0             # hidden dim of those dense layers


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings."""

    d_state: int = 0
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class VisionConfig:
    """Cross-attention VLM settings (modality frontend is a stub)."""

    cross_every: int = 0        # a cross-attn layer every N layers (0 = none)
    num_patches: int = 4096     # precomputed patch-embedding tokens
    d_vision: int = 1280        # frontend embedding width (projected to d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # --- attention flavor ---
    rope_theta: float = 10000.0
    rope_pct: float = 1.0       # fraction of head_dim rotated (stablelm: 0.25)
    qk_norm: bool = False
    sliding_window: int = 0     # 0 = full attention
    alt_local_global: bool = False  # gemma2: even layers local, odd global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_scale: float = 0.0     # 0 -> 1/sqrt(head_dim)
    use_bias: bool = False
    use_layernorm: bool = False  # False -> RMSNorm
    post_block_norm: bool = False  # gemma2 sandwich norms
    parallel_block: bool = False   # command-r style attn || mlp
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    mlp_act: str = "silu"       # silu | gelu  (gated)
    # --- sub-family configs ---
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    vision: VisionConfig = field(default_factory=VisionConfig)
    # hybrid (zamba2): a shared attention block applied every N ssm blocks
    shared_attn_every: int = 0
    # encoder-decoder (seamless)
    n_encoder_layers: int = 0
    # --- numerics ---
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    # --- citations / provenance ---
    source: str = ""

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode at 500k context is sub-quadratic / constant-state."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    """Whether a shape cell applies to an architecture (DESIGN.md §4)."""
    if cell.name == "long_500k":
        return cfg.supports_long_context
    return True
