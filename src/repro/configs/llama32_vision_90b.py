"""llama-3.2-vision-90b — text backbone with cross-attn image layers every
5th layer; vision frontend is a STUB (precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-90B-Vision; unverified]."""
from .base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=False,
    vision=VisionConfig(cross_every=5, num_patches=4096, d_vision=1280),
    source="hf:meta-llama/Llama-3.2-90B-Vision",
)

REDUCED = CONFIG.replace(
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    vision=VisionConfig(cross_every=5, num_patches=16, d_vision=32))
