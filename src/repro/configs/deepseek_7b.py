"""deepseek-7b — dense llama-arch decoder [arXiv:2401.02954; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2401.02954",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=256)
