"""Document-ingest workflow: parse -> digest (batch summarize) -> index.

The second scenario built purely on the declarative API: documents are
parsed into chunks (cardinality: pages), every chunk gets an LLM digest
(cardinality: chunks — the batchable bulk stage), and the digests are
indexed. The digest stage is where the scheduler's batching lever pays:
LLM decode streams the weights once per step regardless of batch size
(the batch roofline, DESIGN.md §7), so below the compute knee MIN_ENERGY/
MIN_COST plans co-schedule chunks aggressively.
"""
from __future__ import annotations

from ..core.spec import SCENARIOS, Scenario
from ..core.workflow import DocumentInput

# the default ingest batch: two quarterly filings
PAPER_DOCS = (
    DocumentInput("10k_2024.pdf", pages=12, chunks_per_page=3),
    DocumentInput("10k_2023.pdf", pages=12, chunks_per_page=3),
)


# representative decode-bound stage for the batch-roofline knee sweep
# (benchmarks/planner_bench.py): the digest interface's token footprint —
# the batchable bulk stage this scenario exists to exercise. The same knee
# seeds the joint (count x batch) search's candidate grid
# (energy.knee_batch_grid, DESIGN.md §7.2): 72 chunks don't divide the
# 64-item max batch, so the remainder-aware grid is what finds the
# zero-remainder divisor schedules here.
BATCH_KNEE_REFERENCE = ("gemma2-9b-digest", 700, 90)


def _first_doc(job) -> DocumentInput:
    docs = [d for d in job.inputs if isinstance(d, DocumentInput)]
    return docs[0] if docs else DocumentInput("input")


DOCINGEST_SCENARIO = SCENARIOS.register(Scenario(
    name="doc_ingest",
    input_artifacts=("document",),
    default_tasks=(
        "Parse and split each document into text chunks",
        "Write a digest of every text chunk",
    ),
    aggregate_tasks=(
        "Index the digests into the vector database",
    ),
    arg_builders={
        "parse_doc": lambda job: {"file": _first_doc(job).name,
                                  "chunk_tokens": 512},
        "digest": lambda job: {"chunks": "$text_chunks", "max_tokens": 90},
        "embed": lambda job: {"texts": "$chunk_summaries"},
    }))


def make_docingest_job(constraints=None, documents=PAPER_DOCS):
    """Declarative batch document-ingest job."""
    from ..core.workflow import MIN_COST, Job
    return Job(
        description="Ingest the quarterly filings and index their digests",
        inputs=documents,
        constraints=MIN_COST if constraints is None else constraints,
        quality_floor={"parse_doc": 0.85, "digest": 0.85, "embed": 0.85})


# -- open-loop serving preset (core/arrivals.py) ------------------------------
# Document ingest is throughput-oriented batch work (unloaded ~21 s): a
# moderate share with a looser SLO than RAG — ingest can queue.
from ..core.arrivals import ServingPreset, register_preset  # noqa: E402

SERVING_PRESET = register_preset(ServingPreset(
    scenario="docingest", make_job=make_docingest_job, weight=0.25,
    base_slo_s=120.0))
