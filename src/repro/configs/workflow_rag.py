"""Agentic-RAG workflow: retrieve -> rerank -> synthesize -> index.

Built purely on the declarative API (DESIGN.md §2): the scenario registers
its default decomposition and toolcall-arg builders; cardinality and token
models come from the producing interfaces. Nothing in core knows RAG exists.

The headline lever is *retrieval routing* (beyond-vector-search): the
``retrieve`` interface has a keyword (BM25), a dense (vector) and a hybrid
implementation on the same quality ladder, so constraint choice routes the
query — ``MIN_COST`` runs lexical retrieval on CPU cores, ``MAX_QUALITY``
pays for hybrid retrieval — with no change to the workflow definition.
"""
from __future__ import annotations

from ..core.spec import SCENARIOS, Scenario
from ..core.workflow import QueryInput

# a small analyst query mix over an indexed filings corpus
RAG_QUERIES = (
    QueryInput("What supply-chain risks does the 2024 10-K disclose?",
               top_k=5, candidates=20),
    QueryInput("Summarize the segment revenue trends year over year",
               top_k=5, candidates=20),
    QueryInput("Which acquisitions closed during the fiscal year?",
               top_k=5, candidates=20),
    QueryInput("What litigation contingencies are reserved for?",
               top_k=5, candidates=20),
)

# the learned-routing evaluation mix (DESIGN.md §11; benchmarks/
# routing_bench.py): half *lookup-shaped* queries — document ids, fiscal
# years, tickers, form numbers, where exact lexical (BM25) match wins —
# and half *semantic* prose needing embedding recall. The featurizer
# (core.telemetry.featurize) separates the two by digit/ID density; the
# router learns to send each bucket to its cheapest adequate arm.
ROUTED_QUERIES = (
    # lookup-shaped: id/digit-dense, short
    QueryInput("10-K 2024 item 1A", top_k=5, candidates=20),
    QueryInput("FY2024 Q3 8-K filing AMZN", top_k=5, candidates=20),
    QueryInput("CIK 0000320193 10-Q 2025", top_k=5, candidates=20),
    QueryInput("NVDA 10-K exhibit 21.1 subsidiaries", top_k=5,
               candidates=20),
    # semantic: clean prose, no identifiers
    QueryInput("How does management describe competitive pressure on "
               "margins?", top_k=5, candidates=20),
    QueryInput("Summarize the segment revenue trends year over year",
               top_k=5, candidates=20),
    QueryInput("What strategic rationale is given for the recent "
               "acquisitions?", top_k=5, candidates=20),
    QueryInput("Describe the liquidity outlook under the disclosed risk "
               "factors", top_k=5, candidates=20),
)


# representative decode-bound stage for the batch-roofline knee sweep
# (benchmarks/planner_bench.py): the synthesize interface's token footprint.
# The same knee seeds the joint (count x batch) search's candidate grid
# (energy.knee_batch_grid, DESIGN.md §7.2).
BATCH_KNEE_REFERENCE = ("gemma2-9b-synth", 1200, 200)


def _first_query(job) -> QueryInput:
    qs = [q for q in job.inputs if isinstance(q, QueryInput)]
    return qs[0] if qs else QueryInput("input")


RAG_SCENARIO = SCENARIOS.register(Scenario(
    name="agentic_rag",
    input_artifacts=("query",),
    default_tasks=(
        "Retrieve candidate passages from the corpus for the query",
        "Rerank the retrieved passages by relevance",
        "Synthesize a grounded answer from the top passages",
    ),
    aggregate_tasks=(
        "Index the answer embedding into the semantic cache",
    ),
    arg_builders={
        "retrieve": lambda job: {"query": _first_query(job).text,
                                 "k": _first_query(job).candidates},
        "rerank": lambda job: {"passages": "$passages",
                               "top_k": _first_query(job).top_k},
        "synthesize": lambda job: {"query": _first_query(job).text,
                                   "max_tokens": 200},
        "embed": lambda job: {"texts": "$grounded_answer"},
    }))


def make_rag_job(constraints=None, queries=RAG_QUERIES, *,
                 quality_floor=None):
    """Declarative agentic-RAG job over the default query mix.

    ``quality_floor`` overrides individual per-interface floors (merged
    over the defaults below) — the routing bench raises the retrieve
    floor to force the dense route (the static quality-safe baseline) and
    the synthesize floor to exercise quality-aware model selection.
    """
    from ..core.workflow import MIN_COST, Job
    # floors admit the keyword route (0.82) but gate junk impls; raise
    # the retrieve floor to force the dense/hybrid route.
    floor = {"retrieve": 0.8, "rerank": 0.85, "synthesize": 0.85,
             "embed": 0.85}
    if quality_floor:
        floor.update(quality_floor)
    return Job(
        description="Answer analyst questions over the filings corpus",
        inputs=queries,
        constraints=MIN_COST if constraints is None else constraints,
        quality_floor=floor)


# -- open-loop serving preset (core/arrivals.py) ------------------------------
# RAG is the interactive majority of the serving mix: short spans (unloaded
# ~21 s), tight SLO, highest arrival share.
from ..core.arrivals import ServingPreset, register_preset  # noqa: E402

SERVING_PRESET = register_preset(ServingPreset(
    scenario="rag", make_job=make_rag_job, weight=0.60, base_slo_s=90.0))
