"""stablelm-12b — GQA kv=8, partial rotary, per-head qk-norm
[hf:stabilityai/stablelm-2-12b; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    rope_pct=0.25,
    qk_norm=True,
    tie_embeddings=False,
    source="hf:stabilityai/stablelm-2-12b",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256)
