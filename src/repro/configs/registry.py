"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from .base import ModelConfig
from . import (command_r_plus_104b, deepseek_7b, deepseek_moe_16b, gemma2_9b,
               kimi_k2_1t, llama32_vision_90b, mamba2_370m,
               seamless_m4t_large_v2, stablelm_12b, zamba2_7b)

_MODULES = {
    "deepseek-7b": deepseek_7b,
    "gemma2-9b": gemma2_9b,
    "stablelm-12b": stablelm_12b,
    "command-r-plus-104b": command_r_plus_104b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "zamba2-7b": zamba2_7b,
    "mamba2-370m": mamba2_370m,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = _MODULES[arch]
    return mod.REDUCED if reduced else mod.CONFIG
