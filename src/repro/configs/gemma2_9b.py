"""gemma2-9b — local/global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    sliding_window=4096,
    alt_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(3584 / 16) ** -0.5,  # query_pre_attn_scalar = d_model/n_heads
    post_block_norm=True,
    embed_scale=True,
    mlp_act="gelu",
    tie_embeddings=True,
    source="arXiv:2408.00118",
)

REDUCED = CONFIG.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, head_dim=16,
                         sliding_window=16, attn_scale=(64 / 4) ** -0.5)
