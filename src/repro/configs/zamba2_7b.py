"""zamba2-7b — Mamba2 backbone + shared (parameter-tied) attention block
applied every 6th layer [arXiv:2411.15242; unverified].

Simplification recorded in DESIGN.md: the shared block consumes the current
hidden state (the released model concatenates the original embeddings and
applies per-invocation LoRA deltas)."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    shared_attn_every=6,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=2,
                  chunk_size=256),
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
    shared_attn_every=3,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=2,
                  chunk_size=16))
