"""command-r-plus-104b — parallel attn||ffn blocks, LayerNorm, no bias
[hf:CohereForAI/c4ai-command-r-plus; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    parallel_block=True,
    use_layernorm=True,
    use_bias=False,
    tie_embeddings=True,
    rope_theta=75000000.0,
    source="hf:CohereForAI/c4ai-command-r-plus",
)

REDUCED = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, rope_theta=10000.0)
