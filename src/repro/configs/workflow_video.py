"""Video-Understanding workflow: paper-cluster calibration (§4, Fig. 3, Tab. 2).

The paper's evaluation runs the OmAgent-derived workflow on 2x Azure
ND96amsr_A100_v4 (16x A100-80GB + 192 EPYC vCPUs): OpenCV frame extraction
(CPUs), NVLM frame summarization (8 GPUs) + embeddings (2 GPUs), CLIP object
detection (CPUs), Whisper STT (1 GPU or 64 CPU cores).

The constants below are the *pinned execution profiles* for that cluster —
the stand-in for the offline profiling runs the paper amortizes (§3.3a).
They are chosen so the modeled workflow reproduces the published endpoints:

    baseline   ~283-285 s, ~155 Wh        (sequential, fixed resources)
    Murakkab   77-83 s,    34-43 Wh       (three STT configs)
    MIN_COST selects the CPU config  =>  ~4.5x energy efficiency

Workload: 2 videos x 4 scenes x 10 frames (matching the paper's two-video
input; the scene/frame granularity is OmAgent's segmentation).
"""
from __future__ import annotations

from ..core.profiles import ProfileStore
from ..core.spec import SCENARIOS, Scenario
from ..core.workflow import VideoInput

# the two input videos of paper Listing 1/2
PAPER_VIDEOS = (
    VideoInput("cats.mov", duration_s=240.0, scenes=4, frames_per_scene=10),
    VideoInput("formula_1.mov", duration_s=240.0, scenes=4,
               frames_per_scene=10),
)

N_SCENES = sum(v.scenes for v in PAPER_VIDEOS)          # 8
FRAMES = N_SCENES * PAPER_VIDEOS[0].frames_per_scene    # 80

# representative decode-bound stage for the batch-roofline knee sweep
# (benchmarks/planner_bench.py): (impl, tokens_in, tokens_out) — the
# summarize interface's declared token footprint on a mid-tier LLM.
BATCH_KNEE_REFERENCE = ("gemma2-9b", 900, 120)


# pinned (impl, device, n_devices) -> latency [, power_frac]. Latency is a
# per-item scalar at batch=1, or a measured *batch curve* {batch:
# per_item_s} for impls with a batching lever (DESIGN.md §7.2) — curves
# retire the deprecated ``batch ** alpha`` fallback for these rows. The
# curve points below sit on the alpha power law the seed calibration
# implied (``per_item(b) = lat1 * b ** (alpha - 1)``), and the store's
# log-log interpolation reproduces a power law exactly, so every
# previously-chosen configuration costs the same and the published
# endpoints (Fig. 3 / Table 2) are unmoved.
# work-items: scenes for frame/stt/obj/embed; frames for summarize.
PAPER_PROFILES: dict[tuple[str, str, int], tuple[object, float]] = {
    # OpenCV frame extraction: ~4 s/scene on one vCPU
    ("opencv", "epyc-7v12-core", 1): (4.0, 1.0),
    # Whisper STT: 1 A100 ~11.5 s/scene(60s audio); 64 vCPUs ~17.5 s/scene
    # (CPU batching is off in the scheduler, so the CPU row stays scalar)
    ("whisper-large", "a100-80g", 1): (
        {1: 11.5, 2: 11.5 * 2 ** -0.5}, 1.0),
    ("whisper-large", "epyc-7v12-core", 64): (17.5, 1.0),
    # CLIP object detection: ~4 s/scene on 2 vCPUs
    ("clip", "epyc-7v12-core", 2): (4.0, 1.0),
    # NVLM summarize on 8 A100: ~1.4 s per frame sequential; decode-bound,
    # so the measured per-item latency keeps falling through the batch range
    ("nvlm-72b", "a100-80g", 8): (
        {1: 1.4, 8: 1.4 * 8 ** -0.85, 128: 1.4 * 128 ** -0.85}, 0.55),
    # NVLM embeddings on 2 A100: ~3.4 s/scene insert
    ("nvlm-embed", "a100-80g", 2): ({1: 3.4, 8: 3.4 * 8 ** -0.7}, 0.45),
}


def calibrate_paper_profiles(store: ProfileStore):
    for (impl, dev, n), (lat, pf) in PAPER_PROFILES.items():
        store.pin(impl, dev, n, lat, power_frac=pf)


# ---------------------------------------------------------------------------
# Scenario registration: the video pipeline as one workload among peers
# ---------------------------------------------------------------------------


def _first_video(job) -> VideoInput:
    vids = [v for v in job.inputs if isinstance(v, VideoInput)]
    return vids[0] if vids else VideoInput("input")


def _frame_extract_args(job) -> dict:
    first = _first_video(job)
    return {"file": first.name, "start_time": 0,
            "end_time": int(first.duration_s),
            "num_frames": first.frames_per_scene}


VIDEO_SCENARIO = SCENARIOS.register(Scenario(
    name="video_understanding",
    input_artifacts=("video",),
    # paper Listing 2's t1..t3 (RulePlanner fallback when the job gives no
    # sub-task hints) ...
    default_tasks=(
        "Extract frames from each video",
        "Run speech-to-text on all scenes",
        "Detect objects in the frames",
    ),
    # ... plus the aggregation stages of the evaluated workflow
    aggregate_tasks=(
        "Summarize each scene using the gathered context",
        "Embed the summaries into the vector database",
    ),
    arg_builders={
        "frame_extract": _frame_extract_args,
        "speech_to_text": lambda job: {"file": _first_video(job).name,
                                       "language": "en"},
        "object_detect": lambda job: {"frames": "$frames", "labels": "auto"},
        "summarize": lambda job: {"context": "$frames+$objects+$transcript",
                                  "max_tokens": 120},
        "embed": lambda job: {"texts": "$summary"},
        "qa": lambda job: {"question": job.description, "top_k": 5},
    }))


def make_baseline_workflow():
    """Paper Listing 1: pinned models, explicit resources, sequential flow."""
    from ..core.workflow import LLM, MLModel, Tool, Workflow
    frame_ext = Tool(name="OpenCV", params={"sampling_rate": 15},
                     key="ON_PREM_SSH_KEY", resources={"CPUs": 1})
    stt = MLModel(name="Whisper", key="OPENAI_API_KEY",
                  resources={"PTUs": 1})
    obj_det = MLModel(name="CLIP", key="AWS_SSH_KEY", resources={"CPUs": 2})
    summarize = LLM(
        name="llama", key="DATABRICKS_API_KEY",
        params={"context_len": 4096},
        resources={"GPUs": 8},
        system_prompt="You are an agent that can describe images in detail.",
        user_prompt="Summarize the scenes using frames, detected objects and "
                    "transcripts.")
    embed = MLModel(name="nvlm-embed", resources={"GPUs": 2})
    return Workflow(frame_ext >> stt >> obj_det >> summarize >> embed)


def make_declarative_job(constraints=None):
    """Paper Listing 2: description + optional sub-task hints + constraint."""
    from ..core.workflow import MIN_COST, Job
    return Job(
        description="List objects shown/mentioned in the videos",
        inputs=PAPER_VIDEOS,
        tasks=("Extract frames from each video",
               "Run speech-to-text on all scenes",
               "Detect objects in the frames"),
        constraints=MIN_COST if constraints is None else constraints,
        # reproduce-quality gate: per-interface floors = the baseline's impls
        # ("The execution output and accuracy are the same in all
        #  comparisons") — Whisper stays Whisper, CLIP stays CLIP.
        quality_floor={"speech_to_text": 0.97, "object_detect": 0.90,
                       "summarize": 0.96, "frame_extract": 0.9,
                       "embed": 0.9})


# -- open-loop serving preset (core/arrivals.py) ------------------------------
# Video understanding is the heavy tail of the serving mix: long chunkable
# pipelines that dominate device-seconds, so it gets a small arrival share
# and a generous span SLO (unloaded makespan ~105 s on the 64x v5e cluster).
from ..core.arrivals import ServingPreset, register_preset  # noqa: E402

SERVING_PRESET = register_preset(ServingPreset(
    scenario="video", make_job=make_declarative_job, weight=0.15,
    base_slo_s=360.0))
