"""deepseek-moe-16b — 2 shared + 64 routed top-6 fine-grained experts,
first layer dense [arXiv:2401.06066; hf]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=102400,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10944),
    source="arXiv:2401.06066",
)

# capacity_factor is large in the reduced config so smoke tests are drop-free
# (capacity-based MoE drops depend on batch composition, which would make
# prefill-vs-decode equivalence tests flaky at tiny token counts).
REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32,
                  first_k_dense=1, d_ff_dense=128, capacity_factor=64.0))
