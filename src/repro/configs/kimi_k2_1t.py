"""kimi-k2-1t-a32b — trillion-param MoE, 384 routed top-8 + 1 shared expert,
first layer dense [arXiv:2501.kimi2 (paper-table); unverified].

Assignment specifies GQA kv=8 (the released model uses MLA; we follow the
assignment's table)."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,
    vocab_size=163840,
    head_dim=112,  # d_model / n_heads
    tie_embeddings=False,
    moe=MoEConfig(num_experts=384, top_k=8, num_shared=1, d_ff_expert=2048,
                  first_k_dense=1, d_ff_dense=18432),
    source="arXiv:2501.kimi2",
)

# drop-free capacity in the reduced config (see deepseek_moe_16b.py note)
REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, vocab_size=256,
    head_dim=16,
    moe=MoEConfig(num_experts=16, top_k=4, num_shared=1, d_ff_expert=32,
                  first_k_dense=1, d_ff_dense=128, capacity_factor=64.0))
