"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Every parameter/cache leaf carries a tuple of *logical* axis names (see
``models.common.ParamSpec``). A rule table maps logical names to mesh axes
with graceful fallback: an assignment is only used if the dimension size is
divisible by the mesh-axis product and no mesh axis is claimed twice within
one tensor; otherwise the next candidate (or replication) applies.

This fallback is what lets one rule table serve all 10 architectures — e.g.
``kv_heads`` takes the ``model`` axis when it divides (deepseek-7b, kv=32) and
otherwise the KV **sequence** dimension takes it instead (command-r, kv=8),
which is exactly sequence-parallel (flash-decoding style) cache sharding.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Candidate mesh-axis assignments per logical axis, in priority order.
# Each candidate is a tuple of mesh axes the dim is sharded over (jointly).
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # data-parallel batch (pod-major so cross-pod traffic is pure DP)
    "batch": (("pod", "data"), ("data",), ()),
    # tensor parallel
    "vocab": (("model",), ()),
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "mlp": (("model",), ()),
    "experts": (("model",), ()),
    "ssm_in": (("model",), ()),
    "ssm_inner": (("model",), ()),
    "ssm_conv": (("model",), ()),
    "ssm_heads": (("model",), ()),
    # FSDP: weight-stationary dims sharded over the data axis
    "embed": (("data",), ()),
    "src_embed": (("data",), ()),
    "vision_embed": (("data",), ()),
    "expert_mlp": (("data",), ()),   # second-choice FSDP dim for experts
    # sequence parallelism (activations / KV caches)
    "kv_seq": (("model",), ()),
    "seq": ((), ()),
    # always replicated
    "layers": ((),),
    "group": ((),),
    "embed_norm": ((),),
    "head_dim": ((),),
    "state": ((),),
    "conv": ((),),
    "router_in": ((),),
    "experts_in": ((),),
}

# Serving-time rules (§Perf A2/B1/C1): weights are read-only at serve time,
# so FSDP-sharding their embed dims only forces a re-gather (dense archs) or
# a giant per-layer weight all-gather (MoE) on every step. Replicating the
# embed dims leaves dense weights TP-only and — because ``expert_mlp`` is
# the next candidate for the data axis — gives expert weights the 2D
# EP(model) x TP(data) layout, turning per-step weight movement into a small
# activation psum inside the MoE body (see models/moe.py::_moe_ep_body).
SERVING_RULES: dict[str, tuple[tuple[str, ...], ...]] = dict(
    DEFAULT_RULES,
    embed=((),), src_embed=((),), vision_embed=((),))

# Order in which dims of one tensor get to claim mesh axes (TP before FSDP
# before SP; earlier = higher priority).
PRIORITY = (
    "experts", "vocab", "heads", "mlp", "ssm_in", "ssm_inner", "ssm_heads",
    "kv_heads", "batch", "embed", "src_embed", "vision_embed", "expert_mlp",
    "ssm_conv", "kv_seq", "seq",
)


def _prio(name: str | None) -> int:
    if name in PRIORITY:
        return PRIORITY.index(name)
    return len(PRIORITY)


def spec_for_axes(axes: Sequence[str | None], shape: Sequence[int],
                  mesh: Mesh, rules: Mapping | None = None) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assignment: dict[int, tuple[str, ...]] = {}
    taken: set[str] = set()
    order = sorted(range(len(axes)), key=lambda i: _prio(axes[i]))
    for i in order:
        name = axes[i]
        if name is None:
            continue
        for cand in rules.get(name, ((),)):
            cand = tuple(a for a in cand if a in mesh_sizes)
            if not cand:
                assignment[i] = ()
                break
            prod = int(np.prod([mesh_sizes[a] for a in cand]))
            if shape[i] % prod == 0 and not (set(cand) & taken):
                assignment[i] = cand
                taken |= set(cand)
                break
        else:
            assignment[i] = ()
    parts = []
    for i in range(len(axes)):
        a = assignment.get(i, ())
        parts.append(a if len(a) > 1 else (a[0] if a else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(logical_tree, abstract_tree, mesh: Mesh,
                   rules: Mapping | None = None):
    """NamedSharding tree for a (logical-axes, ShapeDtypeStruct) tree pair."""

    def one(axes, aval):
        return NamedSharding(mesh, spec_for_axes(axes, aval.shape, mesh, rules))

    return jax.tree.map(one, logical_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_spec(shape: Sequence[int], mesh: Mesh,
              logical: Sequence[str | None] = None) -> P:
    """Sharding for an input batch array; dim 0 is the global batch."""
    logical = logical or ("batch",) + (None,) * (len(shape) - 1)
    return spec_for_axes(logical, shape, mesh)


def cache_logical_axes(cache_tree):
    """Logical axes for a decode-cache pytree (see transformer.init_cache)."""

    def one(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        kind = names[-1] if names else ""
        if kind in ("k", "v", "ck", "cv"):
            return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        if kind == "conv":
            return ("layers", "batch", "conv", "ssm_conv")
        if kind == "ssm":
            return ("layers", "batch", "ssm_heads", "head_dim", "state")
        return tuple([None] * leaf.ndim)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)
