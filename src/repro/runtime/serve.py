"""Serving steps: prefill, decode (KV cache / SSM state), sampling, batching.

``jit_prefill_step`` / ``jit_decode_step`` are the dry-run entry points for
the ``prefill_32k`` / ``decode_32k`` / ``long_500k`` shape cells; the
``ServeSession`` class is the real-execution path used by the examples
(continuous batched decoding of queued requests).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.model_zoo import Model
from ..models.moe import DistContext, LOCAL
from . import sharding as shd


@dataclass(frozen=True)
class ServeOptions:
    kv_dtype: str = "bfloat16"
    temperature: float = 0.0      # 0 = greedy
    fsdp_experts: bool = False    # serving default: keep experts TP-only
    expert_tp: bool = False       # 2D expert sharding (SERVING_RULES, §Perf)
    moe_capacity_cap: int = 0     # decode capacity cap (§Perf B2)
    scan_unroll: int = 1


def make_dist(mesh, opts: ServeOptions) -> DistContext:
    if mesh is None:
        return LOCAL
    return DistContext(mesh=mesh, data_axes=shd.batch_axes(mesh),
                       model_axis="model", fsdp_experts=opts.fsdp_experts,
                       ep=True, expert_tp=opts.expert_tp,
                       capacity_cap=opts.moe_capacity_cap)


def cache_shardings(model: Model, cache_abstract, mesh, rules=None):
    axes = shd.cache_logical_axes(cache_abstract)
    return shd.tree_shardings(axes, cache_abstract, mesh, rules)


def abstract_cache(model: Model, batch: int, max_len: int, enc_len: int = 0):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_len, enc_len=enc_len))


def build_prefill_step(model: Model, opts: ServeOptions, mesh=None):
    dist = make_dist(mesh, opts)

    def prefill(params, inputs, cache):
        logits, cache, _ = model.apply(params, inputs, mode="prefill",
                                       cache=cache, cache_index=0, dist=dist,
                                       scan_unroll=opts.scan_unroll)
        return logits[:, -1], cache

    return prefill


def build_decode_step(model: Model, opts: ServeOptions, mesh=None):
    dist = make_dist(mesh, opts)

    def decode(params, cache, tokens, index, key=None):
        """tokens: (B, 1); index: scalar int32 position. -> (next, cache)."""
        logits, cache, _ = model.apply(params, {"tokens": tokens},
                                       mode="decode", cache=cache,
                                       cache_index=index, dist=dist,
                                       scan_unroll=opts.scan_unroll)
        last = logits[:, -1]
        if opts.temperature > 0 and key is not None:
            nxt = jax.random.categorical(key, last / opts.temperature, -1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt.astype(jnp.int32)[:, None], last, cache

    return decode


def jit_decode_step(model: Model, opts: ServeOptions, mesh, batch: int,
                    max_len: int, enc_len: int = 0, rules=None):
    """pjit'd single-token decode over a sharded cache (dry-run entry)."""
    decode = build_decode_step(model, opts, mesh)
    cache_abs = abstract_cache(model, batch, max_len, enc_len=enc_len)
    c_sh = cache_shardings(model, cache_abs, mesh, rules)
    p_abs = model.abstract()
    p_sh = shd.tree_shardings(model.axes(), p_abs, mesh, rules)
    tok_sh = NamedSharding(mesh, shd.data_spec((batch, 1), mesh))
    repl = NamedSharding(mesh, P())
    fn = jax.jit(lambda params, cache, tokens, index:
                 decode(params, cache, tokens, index),
                 in_shardings=(p_sh, c_sh, tok_sh, repl),
                 out_shardings=(tok_sh, None, c_sh),
                 donate_argnums=(1,))
    return fn, (p_abs, cache_abs)


def jit_prefill_step(model: Model, opts: ServeOptions, mesh, batch: int,
                     seq_len: int, rules=None):
    prefill = build_prefill_step(model, opts, mesh)
    enc_len = model.enc_len_for(seq_len)
    cache_abs = abstract_cache(model, batch, seq_len, enc_len=enc_len)
    c_sh = cache_shardings(model, cache_abs, mesh, rules)
    p_abs = model.abstract()
    p_sh = shd.tree_shardings(model.axes(), p_abs, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
    in_abs = {"tokens": tok_abs,
              **model.extra_inputs(batch, seq_len, abstract=True)}
    in_sh = jax.tree.map(
        lambda a: NamedSharding(mesh, shd.data_spec(a.shape, mesh)), in_abs)
    fn = jax.jit(prefill,
                 in_shardings=(p_sh, in_sh, c_sh),
                 out_shardings=(None, c_sh),
                 donate_argnums=(2,))
    return fn, (p_abs, in_abs, cache_abs)


# ---------------------------------------------------------------------------
# Real-execution serving session (examples / core.executor)
# ---------------------------------------------------------------------------


class ServeSession:
    """Batched request serving against a locally-materialized model."""

    def __init__(self, model: Model, params, max_len: int = 256,
                 opts: ServeOptions = ServeOptions()):
        self.model, self.params, self.opts = model, params, opts
        self.max_len = max_len
        self._prefill = jax.jit(build_prefill_step(model, opts))
        self._decode = jax.jit(build_decode_step(model, opts))

    def generate(self, prompts, max_new_tokens: int = 32, extras=None):
        """prompts: (B, S) int32 array -> (B, max_new_tokens) int32."""
        B, S = prompts.shape
        enc_len = self.model.enc_len_for(S)
        cache = self.model.init_cache(B, S + max_new_tokens, enc_len=enc_len)
        inputs = {"tokens": prompts, **(extras or {})}
        last_logits, cache = self._prefill(self.params, inputs, cache)
        tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
        out = [tok]
        idx = jnp.asarray(S, jnp.int32)
        for _ in range(max_new_tokens - 1):
            tok, _, cache = self._decode(self.params, cache, tok, idx)
            out.append(tok)
            idx = idx + 1
        return jnp.concatenate(out, axis=1)
