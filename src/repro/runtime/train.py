"""Train-step builder: loss, microbatch accumulation, remat, shardings.

``build_train_step`` returns a pure (state, batch) -> (state, metrics)
function plus the sharding trees needed to jit it on a production mesh. The
same builder serves the smoke tests (1 CPU device, mesh=None) and the
512-device dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.model_zoo import Model
from ..models.moe import DistContext, LOCAL
from ..optim import adamw
from ..optim.schedule import warmup_cosine
from . import sharding as shd


@dataclass(frozen=True)
class TrainOptions:
    remat_policy: str | None = "full"    # None | full | dots | minimal
    microbatches: int = 1
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    fsdp_experts: bool = True
    scan_unroll: int = 1                 # big value = unroll layer scans


def cross_entropy(logits, labels):
    """logits: (B, S, V) fp32; labels: (B, S) int32. Mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_dist(mesh, opts: TrainOptions) -> DistContext:
    if mesh is None:
        return LOCAL
    return DistContext(mesh=mesh, data_axes=shd.batch_axes(mesh),
                       model_axis="model", fsdp_experts=opts.fsdp_experts,
                       ep=True)


def init_train_state(model: Model, key, opts: TrainOptions):
    params = model.init(key)
    return {"params": params, "opt": adamw.init_opt_state(params, opts.opt),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model, opts: TrainOptions):
    params = model.abstract()
    opt = jax.eval_shape(lambda p: adamw.init_opt_state(p, opts.opt), params)
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_shardings(model: Model, mesh, opts: TrainOptions, rules=None):
    """NamedSharding tree for the train state (moments inherit params)."""
    p_abs = model.abstract()
    p_shard = shd.tree_shardings(model.axes(), p_abs, mesh, rules)

    def moment_shard(ps):
        if isinstance(ps, dict):  # int8 {q, scale}: q like param, scale repl.
            return {"q": ps, "scale": NamedSharding(mesh, P())}
        return ps

    if opts.opt.moment_dtype == "int8":
        m_shard = jax.tree.map(
            lambda s: {"q": s, "scale": NamedSharding(mesh, P())}, p_shard)
    else:
        m_shard = p_shard
    repl = NamedSharding(mesh, P())
    return {"params": p_shard,
            "opt": {"m": m_shard, "v": m_shard, "count": repl},
            "step": repl}


def batch_shardings(batch_abstract, mesh):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, shd.data_spec(a.shape, mesh)),
        batch_abstract)


def build_train_step(model: Model, opts: TrainOptions, mesh=None,
                     rules=None) -> Callable:
    dist = make_dist(mesh, opts)

    def loss_fn(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, aux = model.apply(params, inputs, mode="train", dist=dist,
                                     remat_policy=opts.remat_policy,
                                     scan_unroll=opts.scan_unroll)
        ce = cross_entropy(logits, batch["labels"])
        return ce + aux, {"loss": ce, "aux_loss": aux}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def microbatched_grads(params, batch):
        k = opts.microbatches
        if k == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        split = jax.tree.map(
            lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

        def body(carry, mb):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g / k, acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, metrics), _ = jax.lax.scan(
            body, (zeros, {"loss": jnp.zeros(()),
                           "aux_loss": jnp.zeros(())}), split)
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = microbatched_grads(state["params"], batch)
        lr = warmup_cosine(state["step"], peak_lr=opts.opt.lr,
                           warmup_steps=opts.warmup_steps,
                           total_steps=opts.total_steps)
        new_p, new_opt, opt_metrics = adamw.apply_updates(
            state["params"], grads, state["opt"], opts.opt, lr=lr)
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {**metrics, **opt_metrics}

    return train_step


def jit_train_step(model: Model, opts: TrainOptions, mesh, batch_abstract,
                   rules=None):
    """pjit'd train step with explicit in/out shardings (dry-run entry)."""
    step_fn = build_train_step(model, opts, mesh, rules)
    st_sh = state_shardings(model, mesh, opts, rules)
    b_sh = batch_shardings(batch_abstract, mesh)
    return jax.jit(step_fn,
                   in_shardings=(st_sh, b_sh),
                   out_shardings=(st_sh, None),
                   donate_argnums=(0,))
