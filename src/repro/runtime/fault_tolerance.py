"""Fault tolerance: restart driver, straggler mitigation, elastic remesh.

Three mechanisms, all exercisable without real hardware:

1. **Checkpoint/restart** — ``run_with_restarts`` wraps a step loop; on any
   step failure it restores the latest checkpoint (and the data-pipeline
   cursor) and replays. Failure injection hooks make this testable.
2. **Straggler mitigation** — ``StragglerMonitor`` tracks per-step/per-worker
   durations; workers beyond ``threshold x median`` are flagged, and the
   policy emits actions (re-dispatch the shard, shrink the mesh, or ignore).
3. **Elastic remesh** — ``plan_remesh`` computes, for a device loss, the
   largest valid (pod, data, model) mesh that preserves the sharding rules'
   divisibility constraints, plus which state needs resharding. The plan is
   pure metadata — the dry-run applies it by re-lowering on the new mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median
from typing import Callable


# ---------------------------------------------------------------------------
# 1. Checkpoint / restart
# ---------------------------------------------------------------------------


#: One straggler definition for the whole repo: a worker/task running beyond
#: ``DEFAULT_STRAGGLER_THRESHOLD x`` the healthy median (or the engine's
#: CostQuery estimate) is a straggler.  ``StragglerMonitor`` and the serving
#: engine's hedge trigger (``core.faults.FaultProfile.hedge_threshold``) both
#: default to this constant.
DEFAULT_STRAGGLER_THRESHOLD = 1.5


@dataclass
class RestartPolicy:
    max_failures: int = 3
    backoff_s: float = 0.0


def run_with_restarts(*, num_steps: int, state, data_iter, step_fn,
                      ckpt_manager, save_every: int = 10,
                      policy: RestartPolicy | None = None,
                      fail_hook: Callable[[int], None] | None = None,
                      log: Callable[[str], None] = lambda s: None):
    """Run ``step_fn(state, batch) -> (state, metrics)`` with auto-restart.

    ``fail_hook(step)`` (tests) may raise to inject a failure at a step.
    Returns (state, metrics_history, failures_survived).
    """
    if policy is None:
        policy = RestartPolicy()
    failures = 0
    history = []
    step = int(state["step"])
    while step < num_steps:
        try:
            if fail_hook is not None:
                fail_hook(step)
            batch = next(data_iter)
            state, metrics = step_fn(state, batch)
            step = int(state["step"])
            history.append({k: float(v) for k, v in metrics.items()})
            if step % save_every == 0:
                ckpt_manager.save(step, {"state": state,
                                         "data": data_iter.state()})
        except KeyboardInterrupt:
            raise
        except Exception as e:  # node failure, preemption, injected fault
            failures += 1
            log(f"step {step} failed ({type(e).__name__}: {e}); "
                f"restart {failures}/{policy.max_failures}")
            if failures > policy.max_failures:
                raise
            if policy.backoff_s:
                time.sleep(policy.backoff_s)
            restored, at = ckpt_manager.restore(
                {"state": state, "data": data_iter.state()})
            if restored is None:
                raise RuntimeError("no checkpoint to restart from") from e
            state = restored["state"]
            data_iter.restore(restored["data"])
            step = int(state["step"])
    ckpt_manager.wait()
    return state, history, failures


# ---------------------------------------------------------------------------
# 2. Straggler mitigation
# ---------------------------------------------------------------------------


@dataclass
class StragglerMonitor:
    """Flags workers whose step time exceeds threshold x median."""

    threshold: float = DEFAULT_STRAGGLER_THRESHOLD
    window: int = 20
    _durations: dict[str, list[float]] = field(default_factory=dict)

    def record(self, worker: str, duration_s: float):
        self._durations.setdefault(worker, []).append(duration_s)
        self._durations[worker] = self._durations[worker][-self.window:]

    def medians(self) -> dict[str, float]:
        return {w: median(d) for w, d in self._durations.items() if d}

    def stragglers(self) -> list[str]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        overall = median(meds.values())
        return [w for w, m in meds.items() if m > self.threshold * overall]

    def action(self, worker: str) -> str:
        """Escalating mitigation: redispatch -> exclude.

        Slowness is judged against the *peer* median, matching
        ``stragglers()``: a worker with no peers has no reference population
        and can never escalate to exclusion, however bimodal its own history.
        """
        peers = [m for w, m in self.medians().items() if w != worker]
        if not peers:
            return "redispatch"
        overall = median(peers)
        n = len([d for d in self._durations.get(worker, [])
                 if d > self.threshold * overall])
        return "exclude" if n >= self.window // 2 else "redispatch"


# ---------------------------------------------------------------------------
# 3. Elastic remesh
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    devices_used: int
    devices_lost: int
    resharded_axes: tuple[str, ...]   # mesh axes whose size changed
    batch_scale: float                # keep global batch: per-device batch x


def plan_remesh(old_shape: tuple[int, ...], axis_names: tuple[str, ...],
                devices_available: int, *, model_axis: str = "model",
                min_model: int = 1) -> RemeshPlan:
    """Largest valid mesh after losing devices.

    Strategy (matches cluster-manager policy): keep the model axis if
    possible (resharding TP state is the expensive case), shrink data/pod
    axes first; fall back to halving the model axis.
    """
    import numpy as np
    old_total = int(np.prod(old_shape))
    sizes = dict(zip(axis_names, old_shape))
    model = sizes.get(model_axis, 1)
    best = None
    m = model
    while m >= min_model:
        rest = devices_available // m
        if rest == 0:            # model axis alone no longer fits
            m //= 2
            continue
        # distribute `rest` over the non-model axes, preferring powers of two
        others = [a for a in axis_names if a != model_axis]
        alloc = {}
        rem = rest
        for a in others[::-1]:          # shrink leading ('pod') axes last
            take = 1
            while take * 2 <= min(sizes[a], rem):
                take *= 2
            alloc[a] = take
            rem //= take
        new_shape = tuple(m if a == model_axis else alloc[a]
                          for a in axis_names)
        used = int(np.prod(new_shape))
        if best is None or used > best[0]:
            best = (used, new_shape)
        if used == devices_available:
            break
        m //= 2
    if best is None:             # fewer devices than any valid mesh
        best = (1, tuple(1 for _ in axis_names))
    used, new_shape = best
    resharded = tuple(a for a, o, n in
                      zip(axis_names, old_shape, new_shape) if o != n)
    return RemeshPlan(old_shape=tuple(old_shape), new_shape=new_shape,
                      axis_names=tuple(axis_names), devices_used=used,
                      devices_lost=old_total - devices_available,
                      resharded_axes=resharded,
                      batch_scale=old_total / used)
