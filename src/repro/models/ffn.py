"""Gated feed-forward (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamSpec


def ffn_specs(cfg, d_ff: int | None = None, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    spec = {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.use_bias:
        spec["b_gate"] = ParamSpec((f,), ("mlp",), init="zeros")
        spec["b_up"] = ParamSpec((f,), ("mlp",), init="zeros")
        spec["b_down"] = ParamSpec((d,), ("embed",), init="zeros")
    return spec


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def apply_ffn(p, x, *, cfg):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if cfg.use_bias:
        g = g + p["b_gate"]
        u = u + p["b_up"]
    h = _act(cfg.mlp_act)(g) * u
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if cfg.use_bias:
        out = out + p["b_down"]
    return out
