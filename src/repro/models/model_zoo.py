"""Model facade: build a (specs, init, apply, cache) bundle from a config."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import transformer
from .common import (abstract_params, dtype_of, init_params, logical_axes,
                     param_count)
from .moe import DistContext, LOCAL


class Model:
    """Thin, stateless facade over the functional model defined by ``cfg``."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs = transformer.lm_specs(cfg)

    # -- parameters ---------------------------------------------------------
    def init(self, key) -> Any:
        return init_params(self.specs, key, dtype_of(self.cfg.param_dtype))

    def abstract(self) -> Any:
        return abstract_params(self.specs, dtype_of(self.cfg.param_dtype))

    def axes(self) -> Any:
        return logical_axes(self.specs)

    def param_count(self) -> int:
        return param_count(self.specs)

    def active_param_count(self) -> int:
        """Per-token active params (MoE discount for roofline MODEL_FLOPS)."""
        cfg = self.cfg
        total = param_count(self.specs)
        if cfg.family != "moe":
            return total
        m = cfg.moe
        routed = m.num_experts * 3 * cfg.d_model * m.d_ff_expert \
            * (cfg.n_layers - m.first_k_dense)
        active = m.top_k * 3 * cfg.d_model * m.d_ff_expert \
            * (cfg.n_layers - m.first_k_dense)
        return total - routed + active

    # -- inputs -------------------------------------------------------------
    def extra_inputs(self, batch: int, seq_len: int, abstract=False):
        """Modality-stub inputs (DESIGN.md: frontends are stubs)."""
        cfg = self.cfg
        extras = {}
        if cfg.family == "encdec":
            shape = (batch, seq_len, cfg.d_model)
            extras["frames"] = (jax.ShapeDtypeStruct(shape, jnp.bfloat16)
                                if abstract else jnp.zeros(shape, jnp.bfloat16))
        if cfg.family == "vlm":
            shape = (batch, cfg.vision.num_patches, cfg.vision.d_vision)
            extras["patches"] = (jax.ShapeDtypeStruct(shape, jnp.bfloat16)
                                 if abstract else jnp.zeros(shape, jnp.bfloat16))
        return extras

    # -- execution ----------------------------------------------------------
    def apply(self, params, inputs, *, mode="train", dist: DistContext = LOCAL,
              cache=None, cache_index=None, remat_policy=None,
              scan_unroll: int = 1):
        return transformer.forward(
            params, inputs, cfg=self.cfg, dist=dist, mode=mode, cache=cache,
            cache_index=cache_index, remat_policy=remat_policy,
            scan_unroll=scan_unroll)

    def enc_len_for(self, seq_len: int) -> int:
        """Cross-attention KV length: encoder states (encdec) or image
        patches (vlm)."""
        if self.cfg.family == "encdec":
            return seq_len
        if self.cfg.family == "vlm":
            return self.cfg.vision.num_patches
        return 0

    def init_cache(self, batch: int, max_len: int, *, enc_len: int = 0):
        return transformer.init_cache(self.cfg, batch, max_len,
                                      enc_len=enc_len)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
