"""Architecture-generic decoder stack: layer plans, scan-over-layers, caches.

Every assigned architecture is expressed as a *layer plan* — a tuple of
``GroupDesc`` entries; each group is scanned ``repeat`` times over stacked
per-layer parameters (compile-time O(1) in depth). Heterogeneous depth
patterns (gemma2 local/global alternation, DeepSeek first-k-dense, Llama-3.2
cross-attn interleave, Zamba2 shared block) become multi-block groups.

Modes: ``train`` (no cache), ``prefill`` (flash attention + cache write at 0),
``decode`` (single-token step over cache / SSM state).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .attention import (apply_attention, attention_specs, compute_cross_kv,
                        cross_kv_specs)
from .common import (ParamSpec, apply_norm, norm_spec, softcap)
from .ffn import apply_ffn, ffn_specs
from .moe import DistContext, LOCAL, apply_moe, moe_specs
from .ssm import (apply_ssm, apply_ssm_decode, init_ssm_state,
                  ssm_specs)


@dataclass(frozen=True)
class BlockDesc:
    kind: str            # attn | ffn | moe | ssm | cross_attn | parallel | shared_attn
    window: int = 0
    d_ff: int = 0        # ffn width override (0 -> cfg.d_ff)
    causal: bool = True


@dataclass(frozen=True)
class GroupDesc:
    repeat: int
    blocks: tuple[BlockDesc, ...]


A, F, S = BlockDesc("attn"), BlockDesc("ffn"), BlockDesc("ssm")


def layer_plan(cfg) -> tuple[GroupDesc, ...]:
    if cfg.family == "ssm":
        return (GroupDesc(cfg.n_layers, (S,)),)
    if cfg.family == "hybrid":
        per, n = cfg.shared_attn_every, cfg.n_layers
        full, rest = divmod(n, per)
        groups = [GroupDesc(full, tuple([S] * per) + (BlockDesc("shared_attn"),))]
        if rest:
            groups.append(GroupDesc(rest, (S,)))
        return tuple(groups)
    if cfg.family == "vlm":
        ce = cfg.vision.cross_every
        assert cfg.n_layers % ce == 0
        blocks = tuple([A, F] * (ce - 1)) + (BlockDesc("cross_attn"), F)
        return (GroupDesc(cfg.n_layers // ce, blocks),)
    if cfg.family == "encdec":
        return (GroupDesc(cfg.n_layers, (A, BlockDesc("cross_attn"), F)),)
    if cfg.parallel_block:
        return (GroupDesc(cfg.n_layers, (BlockDesc("parallel"),)),)
    if cfg.alt_local_global:
        assert cfg.n_layers % 2 == 0
        return (GroupDesc(cfg.n_layers // 2,
                          (BlockDesc("attn", window=cfg.sliding_window), F,
                           A, F)),)
    if cfg.family == "moe":
        m = cfg.moe
        groups = []
        if m.first_k_dense:
            groups.append(GroupDesc(
                m.first_k_dense, (A, BlockDesc("ffn", d_ff=m.d_ff_dense))))
        groups.append(GroupDesc(cfg.n_layers - m.first_k_dense,
                                (A, BlockDesc("moe"))))
        return tuple(groups)
    # plain dense decoder
    w = cfg.sliding_window
    attn = BlockDesc("attn", window=w) if w else A
    return (GroupDesc(cfg.n_layers, (attn, F)),)


def encoder_plan(cfg) -> tuple[GroupDesc, ...]:
    return (GroupDesc(cfg.n_encoder_layers,
                      (BlockDesc("attn", causal=False), F)),)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _block_specs(cfg, b: BlockDesc) -> dict:
    if b.kind == "shared_attn":
        return {}  # parameters live at the top level (tied across repeats)
    spec: dict = {"norm": norm_spec(cfg)}
    if cfg.post_block_norm:
        spec["post_norm"] = norm_spec(cfg)
    if b.kind == "attn":
        spec["attn"] = attention_specs(cfg)
    elif b.kind == "ffn":
        spec["ffn"] = ffn_specs(cfg, d_ff=b.d_ff or cfg.d_ff)
    elif b.kind == "moe":
        spec["moe"] = moe_specs(cfg)
    elif b.kind == "ssm":
        spec["ssm"] = ssm_specs(cfg)
    elif b.kind == "cross_attn":
        spec["attn"] = attention_specs(cfg)
        spec["cross_kv"] = cross_kv_specs(cfg, cfg.d_model)
    elif b.kind == "parallel":
        spec["attn"] = attention_specs(cfg)
        spec["ffn"] = ffn_specs(cfg)
    else:
        raise ValueError(b.kind)
    return spec


def _group_specs(cfg, gd: GroupDesc) -> dict:
    from .common import stack_specs
    blocks = {f"b{i}": _block_specs(cfg, b) for i, b in enumerate(gd.blocks)}
    return stack_specs(blocks, gd.repeat)


def lm_specs(cfg) -> dict:
    spec: dict = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed", scale=0.02),
        "final_norm": norm_spec(cfg),
        "groups": {f"g{i}": _group_specs(cfg, gd)
                   for i, gd in enumerate(layer_plan(cfg))},
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                    ("embed", "vocab"))
    if cfg.family == "vlm":
        spec["vision_proj"] = ParamSpec((cfg.vision.d_vision, cfg.d_model),
                                        ("vision_embed", "embed"))
    if cfg.family == "hybrid":
        spec["shared"] = {
            "norm": norm_spec(cfg),
            "attn": attention_specs(cfg),
            "ffn": ffn_specs(cfg),
            "ffn_norm": norm_spec(cfg),
        }
    if cfg.family == "encdec":
        spec["encoder"] = {
            "in_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                 ("src_embed", "embed")),
            "final_norm": norm_spec(cfg),
            "groups": {f"g{i}": _group_specs(cfg, gd)
                       for i, gd in enumerate(encoder_plan(cfg))},
        }
    return spec


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, *, enc_len: int = 0,
               kv_dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree mirroring the layer plan."""
    hd = cfg.head_dim_
    kvh = cfg.n_kv_heads

    def attn_cache(repeat):
        shape = (repeat, batch, max_len, kvh, hd)
        return {"k": jnp.zeros(shape, kv_dtype), "v": jnp.zeros(shape, kv_dtype)}

    def cross_cache(repeat):
        shape = (repeat, batch, enc_len, kvh, hd)
        return {"ck": jnp.zeros(shape, kv_dtype), "cv": jnp.zeros(shape, kv_dtype)}

    def ssm_cache(repeat):
        st = init_ssm_state(cfg, batch, repeat)
        return st

    groups = {}
    for i, gd in enumerate(layer_plan(cfg)):
        blocks = {}
        for j, b in enumerate(gd.blocks):
            if b.kind in ("attn", "parallel", "shared_attn"):
                blocks[f"b{j}"] = attn_cache(gd.repeat)
            elif b.kind == "cross_attn":
                blocks[f"b{j}"] = cross_cache(gd.repeat)
            elif b.kind == "ssm":
                blocks[f"b{j}"] = ssm_cache(gd.repeat)
        groups[f"g{i}"] = blocks
    return {"groups": groups}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_block(bp, x, b: BlockDesc, *, cfg, dist, mode, cache, cache_index,
                 cross_states, shared_params, positions):
    """One residual block. Returns (x, new_cache|None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = None

    def maybe_post(out, p):
        return apply_norm(p["post_norm"], out, cfg) if cfg.post_block_norm else out

    if b.kind in ("attn", "shared_attn"):
        p = shared_params if b.kind == "shared_attn" else bp
        h = apply_norm(p["norm"], x, cfg)
        out, new_cache = apply_attention(
            p["attn"], h, cfg=cfg, window=b.window, positions=positions,
            cache=cache, cache_index=cache_index, causal=b.causal, mode=mode)
        x = x + maybe_post(out, p)
        if b.kind == "shared_attn":  # zamba2 shared block = attn + mlp
            h = apply_norm(p["ffn_norm"], x, cfg)
            x = x + apply_ffn(p["ffn"], h, cfg=cfg)
    elif b.kind == "parallel":  # command-r: one norm, attn || ffn
        h = apply_norm(bp["norm"], x, cfg)
        out_a, new_cache = apply_attention(
            bp["attn"], h, cfg=cfg, window=b.window, positions=positions,
            cache=cache, cache_index=cache_index, mode=mode)
        out_f = apply_ffn(bp["ffn"], h, cfg=cfg)
        x = x + out_a + out_f
    elif b.kind == "ffn":
        h = apply_norm(bp["norm"], x, cfg)
        x = x + maybe_post(apply_ffn(bp["ffn"], h, cfg=cfg), bp)
    elif b.kind == "moe":
        h = apply_norm(bp["norm"], x, cfg)
        out, aux = apply_moe(bp["moe"], h, cfg=cfg, dist=dist)
        x = x + maybe_post(out, bp)
    elif b.kind == "ssm":
        h = apply_norm(bp["norm"], x, cfg)
        if mode == "decode":
            out, new_cache = apply_ssm_decode(bp["ssm"], h, cache, cfg=cfg)
        else:
            out, new_cache = apply_ssm(bp["ssm"], h, cfg=cfg, state=cache)
        x = x + maybe_post(out, bp)
    elif b.kind == "cross_attn":
        h = apply_norm(bp["norm"], x, cfg)
        if mode == "decode":
            kv = (cache["ck"], cache["cv"])
            new_cache = cache
        else:
            k, v = compute_cross_kv(bp["cross_kv"], cross_states)
            kv = (k, v)
            if cache is not None:
                new_cache = {"ck": k.astype(cache["ck"].dtype),
                             "cv": v.astype(cache["cv"].dtype)}
        out, _ = apply_attention(bp["attn"], h, cfg=cfg, cross_kv=kv,
                                 positions=positions, mode=mode)
        x = x + maybe_post(out, bp)
    else:
        raise ValueError(b.kind)
    return x, new_cache, aux


def _maybe_remat(body, remat_policy: str | None, mode: str):
    """remat_policy: None (no remat) | 'full' | 'dots' | 'minimal'."""
    if remat_policy is None or mode != "train":
        return body
    if remat_policy == "full":
        return jax.checkpoint(body)
    if remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if remat_policy == "minimal":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.everything_saveable)
    raise ValueError(remat_policy)


def _apply_group(gp, x, gd: GroupDesc, *, cfg, dist, mode, cache, cache_index,
                 cross_states, shared_params, positions, remat_policy=None,
                 unroll: int = 1):
    """Scan the group body over its ``repeat`` stacked layers."""

    def body(carry, xs):
        h, aux = carry
        bp_all, bc_all = xs
        new_caches = {}
        for j, b in enumerate(gd.blocks):
            key = f"b{j}"
            bc = None if bc_all is None else bc_all.get(key)
            h, nc, aux_j = _apply_block(
                bp_all[key], h, b, cfg=cfg, dist=dist, mode=mode, cache=bc,
                cache_index=cache_index, cross_states=cross_states,
                shared_params=shared_params, positions=positions)
            if nc is not None:
                new_caches[key] = nc
            aux = aux + aux_j
        return (h, aux), (new_caches if new_caches else None)

    body = _maybe_remat(body, remat_policy, mode)

    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       (gp, cache),
                                       unroll=min(unroll, gd.repeat) or 1)
    return x, aux, new_cache


def forward(params, inputs, *, cfg, dist: DistContext = LOCAL, mode="train",
            cache=None, cache_index=None, remat_policy=None,
            scan_unroll: int = 1):
    """Run the model.

    inputs: {'tokens': (B, S) int32, optional 'frames': (B, S_enc, d_model)
    (encdec stub frontend), optional 'patches': (B, P, d_vision) (vlm stub)}.
    Returns (logits, new_cache|None, aux_loss).
    """
    tokens = inputs["tokens"]
    B, Sq = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.activ_dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cache_index is None:
        positions = jnp.arange(Sq)[None, :]
        cache_index = 0 if cache is not None else None
    else:
        positions = cache_index + jnp.arange(Sq)[None, :]

    cross_states = None
    if cfg.family == "vlm" and mode != "decode":
        patches = inputs["patches"].astype(x.dtype)
        cross_states = jnp.einsum("bpv,vd->bpd", patches,
                                  params["vision_proj"].astype(x.dtype))
    if cfg.family == "encdec" and mode != "decode":
        enc = params["encoder"]
        h = jnp.einsum("bse,ed->bsd", inputs["frames"].astype(x.dtype),
                       enc["in_proj"].astype(x.dtype))
        for i, gd in enumerate(encoder_plan(cfg)):
            h, _, _ = _apply_group(
                enc["groups"][f"g{i}"], h, gd, cfg=cfg, dist=dist,
                mode="train", cache=None, cache_index=None,
                cross_states=None, shared_params=None,
                positions=jnp.arange(h.shape[1])[None, :],
                remat_policy=remat_policy, unroll=scan_unroll)
        cross_states = apply_norm(enc["final_norm"], h, cfg)

    shared_params = params.get("shared")
    aux = jnp.zeros((), jnp.float32)
    new_groups = {}
    for i, gd in enumerate(layer_plan(cfg)):
        gcache = None if cache is None else cache["groups"].get(f"g{i}")
        x, aux_g, ncache = _apply_group(
            params["groups"][f"g{i}"], x, gd, cfg=cfg, dist=dist, mode=mode,
            cache=gcache, cache_index=cache_index, cross_states=cross_states,
            shared_params=shared_params, positions=positions,
            remat_policy=remat_policy, unroll=scan_unroll)
        aux = aux + aux_g
        if ncache is not None:
            new_groups[f"g{i}"] = ncache

    x = apply_norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    new_cache = {"groups": new_groups} if cache is not None else None
    return logits, new_cache, aux
