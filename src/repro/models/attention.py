"""GQA attention block (full / sliding-window / softcap) with KV cache."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamSpec, apply_rope, rms_norm


def attention_specs(cfg, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    spec = {
        "wq": ParamSpec((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        spec["bq"] = ParamSpec((cfg.n_heads, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
        spec["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return spec


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16, lead: tuple[int, ...] = ()):
    """KV cache pytree: k/v of (n_layers, *lead, batch, max_len, kv_heads, hd)."""
    hd = cfg.head_dim_
    shape = (n_layers, *lead, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _project_qkv(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def apply_attention(p, x, *, cfg, window: int = 0, positions=None,
                    cache: dict | None = None, cache_index=None,
                    cross_kv: tuple | None = None, causal: bool = True,
                    mode: str = "train"):
    """x: (B, S, d). Returns (out, new_cache_slice).

    - train: no cache IO, flash attention over x.
    - prefill: flash attention over x; k/v written into ``cache`` at 0.
    - decode: k/v written at ``cache_index``; attention over the cache.
    - cross-attention: cross_kv = (k, v) precomputed from encoder/vision
      states; causal is ignored (full visibility).
    """
    B, S, _ = x.shape
    scale = cfg.attn_scale or cfg.head_dim_ ** -0.5

    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.use_bias:
            q = q + p["bq"]
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
        k, v = cross_kv
        o = ops.flash_attention(q, k, v, causal=False, scale=scale,
                                logit_softcap=cfg.attn_logit_softcap)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if cfg.use_bias:
            out = out + p["bo"]
        return out, None

    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, rope_pct=cfg.rope_pct, theta=cfg.rope_theta)
    k = apply_rope(k, positions, rope_pct=cfg.rope_pct, theta=cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        o = ops.decode_attention(q, ck, cv, window=window,
                                 logit_softcap=cfg.attn_logit_softcap,
                                 scale=scale, q_offset=idx, kv_len=idx + S)
        new_cache = {"k": ck, "v": cv}
    else:
        o = ops.flash_attention(q, k, v, causal=causal, window=window,
                                logit_softcap=cfg.attn_logit_softcap,
                                scale=scale)
        if mode == "prefill" and cache is not None:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
            }

    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cfg.use_bias:
        out = out + p["bo"]
    return out, new_cache


def cross_kv_specs(cfg, d_src: int) -> dict:
    """K/V projections from a source modality (encoder states / patches)."""
    hd = cfg.head_dim_
    return {
        "wk": ParamSpec((d_src, cfg.n_kv_heads, hd), ("src_embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_src, cfg.n_kv_heads, hd), ("src_embed", "kv_heads", "head_dim")),
    }


def compute_cross_kv(p, src):
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    return k, v
