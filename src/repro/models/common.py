"""Parameter-spec system, norms, RoPE and init helpers.

Models are (spec, apply) pairs over plain dict pytrees. A ``ParamSpec`` tree is
the single source of truth from which we derive:

- ``init_params``      concrete arrays (for smoke tests / real execution)
- ``abstract_params``  ShapeDtypeStructs (for the 512-device dry-run — never
                       allocates)
- ``logical_axes``     per-leaf logical axis names, mapped to mesh axes by
                       ``repro.runtime.sharding``.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical axis name per dim
    init: str = "normal"           # normal | zeros | ones | embed
    scale: float = 0.0             # stddev override; 0 -> fan-in scaled
    dtype: Any = None              # None -> model param dtype


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, spec_tree):
    return jax.tree.map(fn, spec_tree, is_leaf=_is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers parameter stacks)."""

    def _stack(s: ParamSpec) -> ParamSpec:
        return s._replace(shape=(n, *s.shape), axes=(axis_name, *s.axes))

    return tree_map_specs(_stack, spec_tree)


def _fan_in(shape: tuple[int, ...], axes: tuple[str | None, ...]) -> int:
    # Fan-in = product of all dims except the last "output-ish" dim; for
    # stacked layer params, skip the leading 'layers'/stack dims.
    dims = [d for d, a in zip(shape, axes) if a not in ("layers", "group")]
    if len(dims) <= 1:
        return max(dims[0] if dims else 1, 1)
    return max(int(jnp.prod(jnp.array(dims[:-1]))), 1)


def init_params(spec_tree, key, default_dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def _init(s: ParamSpec, k):
        dt = s.dtype or default_dtype
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        if s.init == "embed":
            std = s.scale or 1.0
            return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)
        std = s.scale or 1.0 / math.sqrt(_fan_in(s.shape, s.axes))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dt)

    return jax.tree.unflatten(treedef, [_init(s, k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree, default_dtype=jnp.bfloat16):
    def _abs(s: ParamSpec):
        return jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype)

    return tree_map_specs(_abs, spec_tree)


def logical_axes(spec_tree):
    return tree_map_specs(lambda s: s.axes, spec_tree)


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, zero_centered: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if zero_centered:  # gemma-style (1 + scale)
        s = 1.0 + s
    return (y * s).astype(dt)


def layer_norm(x, scale, bias=None, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def norm_spec(cfg, d: int | None = None) -> dict:
    d = d or cfg.d_model
    spec = {"scale": ParamSpec((d,), ("embed_norm",),
                               init="zeros" if _zero_centered(cfg) else "ones")}
    if cfg.use_layernorm and cfg.use_bias:
        spec["bias"] = ParamSpec((d,), ("embed_norm",), init="zeros")
    return spec


def _zero_centered(cfg) -> bool:
    return cfg.name.startswith("gemma")


def apply_norm(p: dict, x, cfg):
    if cfg.use_layernorm:
        return layer_norm(x, p["scale"], p.get("bias"))
    return rms_norm(x, p["scale"], zero_centered=_zero_centered(cfg))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, rope_pct: float, theta: float):
    rot_dim = int(head_dim * rope_pct)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, *, rope_pct: float = 1.0, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_freqs(head_dim, rope_pct, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
