"""Mamba2 block (SSD) — projections, causal depthwise conv, gated norm.

Train/prefill runs the chunked SSD scan (Pallas kernel on TPU); decode is the
O(1)-per-token recurrent update carried in (conv_buffer, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamSpec, rms_norm


def ssm_dims(cfg, d_model: int | None = None):
    s = cfg.ssm
    d = d_model or cfg.d_model
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d, d_inner, n_heads, conv_dim


def ssm_specs(cfg, d_model: int | None = None) -> dict:
    s = cfg.ssm
    d, d_inner, nh, conv_dim = ssm_dims(cfg, d_model)
    proj_out = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    return {
        "in_proj": ParamSpec((d, proj_out), ("embed", "ssm_in")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv", "ssm_conv"),
                            scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("ssm_conv",), init="zeros"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }


def init_ssm_state(cfg, batch: int, n_layers: int, d_model: int | None = None,
                   lead: tuple[int, ...] = ()):
    s = cfg.ssm
    _, d_inner, nh, conv_dim = ssm_dims(cfg, d_model)
    return {
        "conv": jnp.zeros((n_layers, *lead, batch, s.conv_width - 1, conv_dim),
                          jnp.float32),
        "ssm": jnp.zeros((n_layers, *lead, batch, nh, s.head_dim, s.d_state),
                         jnp.float32),
    }


def _split_proj(proj, cfg, d_model=None):
    s = cfg.ssm
    _, d_inner, nh, _ = ssm_dims(cfg, d_model)
    gn = s.n_groups * s.d_state
    z, xs, b, c, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, xs, b, c, dt


def _causal_conv(x, w, b):
    """x: (B, L, C); w: (W, C) depthwise causal conv via shifted adds."""
    W = w.shape[0]
    out = x * w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out + b


def apply_ssm(p, x, *, cfg, d_model=None, state=None):
    """x: (B, L, d). Returns (out, new_state|None).

    state (decode handoff): dict(conv=(B, W-1, conv_dim), ssm=(B,H,P,N));
    when provided for prefill, the returned state reflects the sequence end.
    """
    s = cfg.ssm
    B, L, _ = x.shape
    _, d_inner, nh, conv_dim = ssm_dims(cfg, d_model)
    proj = jnp.einsum("bld,dk->blk", x, p["in_proj"])
    z, xs, bm, cm, dt = _split_proj(proj, cfg, d_model)
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, bm, cm = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(B, L, nh, s.head_dim)
    bh = bm.reshape(B, L, s.n_groups, s.d_state)
    ch = cm.reshape(B, L, s.n_groups, s.d_state)
    # pad L to a chunk multiple; dt=0 at pad positions makes the recurrence
    # an exact identity there (decay exp(0)=1, input u=0) so y and the final
    # state are unaffected.
    chunk = min(s.chunk_size, L)
    pad = (-L) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, ssm_state = ops.ssd_scan(xh, dt, p["a_log"], bh, ch, p["d_skip"],
                                chunk=chunk)
    y = y[:, :L].reshape(B, L, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"])
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"])
    new_state = None
    if state is not None:
        conv_buf = conv_in[:, -(s.conv_width - 1):].astype(jnp.float32)
        new_state = {"conv": conv_buf, "ssm": ssm_state}
    return out, new_state


def apply_ssm_decode(p, x_t, state, *, cfg, d_model=None):
    """Single-token step. x_t: (B, 1, d); state from init/prefill."""
    s = cfg.ssm
    B = x_t.shape[0]
    _, d_inner, nh, conv_dim = ssm_dims(cfg, d_model)
    proj = jnp.einsum("bld,dk->blk", x_t, p["in_proj"])[:, 0]     # (B, k)
    z, xs, bm, cm, dt = _split_proj(proj, cfg, d_model)
    conv_in = jnp.concatenate([xs, bm, cm], axis=-1)              # (B, conv_dim)
    window = jnp.concatenate(
        [state["conv"], conv_in[:, None].astype(jnp.float32)], axis=1)
    w = p["conv_w"].astype(jnp.float32)                           # (W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x_t.dtype)
    xs, bm, cm = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y, ssm_state = ops.ssd_decode_step(
        state["ssm"], xs.reshape(B, nh, s.head_dim), dt, p["a_log"],
        bm.reshape(B, s.n_groups, s.d_state), cm.reshape(B, s.n_groups, s.d_state),
        p["d_skip"])
    y = y.reshape(B, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"])
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None]      # (B, 1, d)
    new_state = {"conv": window[:, 1:], "ssm": ssm_state}
    return out, new_state
