"""Fine-grained mixture-of-experts (DeepSeekMoE / Kimi-K2 style).

Token-choice top-k routing with fixed capacity, sort-based dispatch, and the
Pallas grouped matmul (``kernels.ops.gmm``) for expert FFNs.

Distribution (TPU-native EP): the expert interior runs under ``jax.shard_map``
— each data shard routes its local tokens, builds an (E, C_local, d) dispatch
buffer, and a **tiled all-to-all over the model axis** exchanges it for an
(E_local, C_local * ep, d) buffer (the DeepSeek-EP dispatch pattern mapped to
``jax.lax.all_to_all``). Expert weights live sharded on the model axis;
optionally they are additionally FSDP-sharded over the data axis and
all-gathered just-in-time inside the shard_map body.

On a single device (smoke tests) the same local functions run without
collectives.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels import ops
from .common import ParamSpec


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map (0.6+) vs jax.experimental.shard_map (older)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


@dataclass(frozen=True)
class DistContext:
    """How apply-fns should distribute themselves (None mesh = local)."""

    mesh: object = None
    data_axes: tuple = ("data",)     # batch axes (may include 'pod')
    model_axis: str = "model"
    fsdp_experts: bool = False       # expert weights FSDP'd over data axis
    ep: bool = True                  # expert-parallel all-to-all on
    # serving (§Perf B1): expert weights stored 2D — EP over the model axis,
    # f (expert_mlp) TP over the data axes. gate/up produce f-sharded
    # hidden locally; the down projection contracts f and psums over data.
    expert_tp: bool = False
    # serving (§Perf B2): cap per-expert capacity at decode time. With a
    # handful of tokens per shard, the default floor (8) pads the dispatch
    # buffers and the EP all-to-all ~8x. 0 = default capacity rule.
    capacity_cap: int = 0


LOCAL = DistContext()


def moe_specs(cfg) -> dict:
    d, m = cfg.d_model, cfg.moe
    spec = {
        "router": ParamSpec((d, m.num_experts), ("router_in", "experts_in"),
                            dtype=jnp.float32),
        "w_gate": ParamSpec((m.num_experts, d, m.d_ff_expert),
                            ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((m.num_experts, d, m.d_ff_expert),
                          ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((m.num_experts, m.d_ff_expert, d),
                            ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        f_sh = m.num_shared * m.d_ff_expert
        spec["shared"] = {
            "w_gate": ParamSpec((d, f_sh), ("embed", "mlp")),
            "w_up": ParamSpec((d, f_sh), ("embed", "mlp")),
            "w_down": ParamSpec((f_sh, d), ("mlp", "embed")),
        }
    return spec


def _capacity(n_tokens: int, cfg, cap: int = 0) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    c = max(8, -(-c // 8) * 8)  # round up to 8
    if cap:
        c = min(c, max(cap, 1))
    return c


def _route(x2d, router_w, cfg):
    """Top-k routing. x2d: (T, d). Returns topk_idx (T,k), weights (T,k), aux."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_idx = jax.lax.top_k(probs, m.top_k)
    topk_w = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    E = m.num_experts
    f_e = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(
        1.0 / (topk_idx.size))
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e) * m.router_aux_coef
    return topk_idx, topk_w.astype(x2d.dtype), aux


def _dispatch_indices(topk_idx, E: int, C: int):
    """Sort-based dispatch metadata.

    Returns gather_idx (E, C) int32 (token index per slot; T = dropped slot)
    and, aligned with the flattened (T*k,) assignment order:
    es (expert id), pos (slot), keep (bool).
    """
    T, k = topk_idx.shape
    e_flat = topk_idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(e_flat, stable=True)
    es = e_flat[order]
    ts = (jnp.arange(T * k) // k)[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts                # exclusive cumsum
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[es]
    keep = pos < C
    gather_idx = jnp.full((E, C), T, jnp.int32)
    gather_idx = gather_idx.at[
        jnp.where(keep, es, E - 1),
        jnp.where(keep, pos, C - 1)].set(jnp.where(keep, ts, T),
                                         mode="drop")
    # inverse map for combine: slot of assignment (t, j)
    inv = jnp.zeros((T * k,), jnp.int32)
    inv = inv.at[order].set(jnp.where(keep, es * C + pos, E * C))
    return gather_idx, inv


def _expert_ffn(x_e, wg, wu, wd, cfg):
    """x_e: (E?, C?, d) grouped tokens -> grouped outputs, via Pallas gmm."""
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = ops.gmm(x_e, wg)
    u = ops.gmm(x_e, wu)
    return ops.gmm((act(g.astype(jnp.float32)) * u.astype(jnp.float32)
                    ).astype(x_e.dtype), wd)


def _moe_local(x2d, p, cfg):
    """Single-shard MoE: route -> dispatch -> gmm -> combine."""
    T, d = x2d.shape
    m = cfg.moe
    C = _capacity(T, cfg)
    topk_idx, topk_w, aux = _route(x2d, p["router"], cfg)
    gather_idx, inv = _dispatch_indices(topk_idx, m.num_experts, C)
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], 0)
    x_e = x_pad[gather_idx]                              # (E, C, d)
    y_e = _expert_ffn(x_e, p["w_gate"], p["w_up"], p["w_down"], cfg)
    y_flat = jnp.concatenate(
        [y_e.reshape(m.num_experts * C, d), jnp.zeros((1, d), y_e.dtype)], 0)
    y_tok = y_flat[inv].reshape(T, m.top_k, d)           # dropped -> zeros
    out = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32),
                     topk_w.astype(jnp.float32)).astype(x2d.dtype)
    return out, aux


def _moe_ep_body(x_local, router_w, wg, wu, wd, *, cfg, dist: DistContext):
    """shard_map body: x_local (T_loc, d); expert weights local (E_loc,...)."""
    m = cfg.moe
    T, d = x_local.shape
    C = _capacity(T, cfg, dist.capacity_cap)
    ax = dist.model_axis
    if dist.fsdp_experts and not dist.expert_tp:
        wg = jax.lax.all_gather(wg, dist.data_axes, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, dist.data_axes, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, dist.data_axes, axis=2, tiled=True)

    topk_idx, topk_w, aux = _route(x_local, router_w, cfg)
    gather_idx, inv = _dispatch_indices(topk_idx, m.num_experts, C)
    x_pad = jnp.concatenate([x_local, jnp.zeros((1, d), x_local.dtype)], 0)
    x_e = x_pad[gather_idx]                              # (E, C, d)
    # dispatch: split experts across shards, concat capacity
    x_e = jax.lax.all_to_all(x_e, ax, split_axis=0, concat_axis=1,
                             tiled=True)                 # (E_loc, C*ep, d)
    if dist.expert_tp:
        # weights (E_loc, d, f_loc)/(E_loc, f_loc, d): gate/up emit an
        # f-sharded hidden locally; down contracts f -> psum over data.
        act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
        g = ops.gmm(x_e, wg)
        u = ops.gmm(x_e, wu)
        h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)
             ).astype(x_e.dtype)
        y_e = jax.lax.psum(ops.gmm(h, wd), dist.data_axes)
    else:
        y_e = _expert_ffn(x_e, wg, wu, wd, cfg)
    # combine: reverse exchange
    y_e = jax.lax.all_to_all(y_e, ax, split_axis=1, concat_axis=0,
                             tiled=True)                 # (E, C, d)
    E = m.num_experts
    y_flat = jnp.concatenate(
        [y_e.reshape(E * C, d), jnp.zeros((1, d), y_e.dtype)], 0)
    y_tok = y_flat[inv].reshape(T, m.top_k, d)
    out = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32),
                     topk_w.astype(jnp.float32)).astype(x_local.dtype)
    aux = jax.lax.pmean(aux, dist.data_axes)
    aux = jax.lax.pmean(aux, ax)
    return out, aux


def apply_moe(p, x, *, cfg, dist: DistContext = LOCAL):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    m = cfg.moe

    if dist.mesh is None or not dist.ep:
        out, aux = _moe_local(x2d, p, cfg)
    else:
        if dist.expert_tp:     # 2D: EP over model, f TP'd over data
            ep_w_spec = P(dist.model_axis, None, dist.data_axes)
            ep_wd_spec = P(dist.model_axis, dist.data_axes, None)
        elif dist.fsdp_experts:
            ep_w_spec = P(dist.model_axis, dist.data_axes, None)
            ep_wd_spec = P(dist.model_axis, None, dist.data_axes)
        else:
            ep_w_spec = ep_wd_spec = P(dist.model_axis, None, None)
        out, aux = _shard_map(
            lambda xl, rw, wg, wu, wd: _moe_ep_body(
                xl, rw, wg, wu, wd, cfg=cfg, dist=dist),
            mesh=dist.mesh,
            in_specs=(P(dist.data_axes, None), P(None, None),
                      ep_w_spec, ep_w_spec, ep_wd_spec),
            out_specs=(P(dist.data_axes, None), P()),
        )(x2d, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.num_shared:
        from .ffn import apply_ffn
        out = out + apply_ffn(p["shared"], x, cfg=cfg).reshape(B * S, d)
    return out.reshape(B, S, d), aux
