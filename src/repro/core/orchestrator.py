"""Job decomposition + task->agent mapping (paper §3.2).

Two planners behind one interface:

- ``RulePlanner`` — deterministic: keyword/schema matching over the agent
  library, dataflow edges derived from interface produces/consumes artifact
  types. This is the offline stand-in for the paper's orchestrator LLM
  (DESIGN.md §5.3 records the substitution; the paper itself measures DAG
  creation at <1% of workflow time, so the swap does not distort the
  evaluation).
- ``LLMPlanner`` — the paper's NVLM/ReAct protocol: agent library via system
  prompt, task descriptions via user prompt, JSON DAG back. Takes any
  ``llm_fn(system, user) -> str`` (tests inject a fake; production would bind
  a served model from the zoo).

Both are scenario-agnostic: work-item cardinality and token footprints come
from the producing interface's declared ``CardinalityModel``/``TokenModel``,
default decompositions and toolcall args from the matched registered
``Scenario`` (DESIGN.md §2). Both emit toolcalls in the paper's format, e.g.
``FrameExtractor(end_time=60, file='cats.mov', num_frames=10, start_time=0)``.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Callable

from .agents import AgentLibrary
from .dag import DAG, TaskNode
from .spec import SCENARIOS, TaskSpec, build_node, input_units
from .workflow import Job


class RulePlanner:
    """Deterministic job -> DAG lowering via the agent library."""

    def __init__(self, library: AgentLibrary):
        self.library = library

    def decompose(self, job: Job) -> list[TaskSpec]:
        """Job description -> typed task specs (hints kept if sufficient)."""
        scenario = SCENARIOS.match(job.inputs)
        if scenario is not None:
            unknown = set(scenario.arg_builders) - set(self.library.interfaces)
            if unknown:
                raise ValueError(
                    f"scenario {scenario.name!r} has arg_builders for "
                    f"interfaces unknown to this library: {sorted(unknown)}")
        texts = list(job.tasks)
        if not texts:
            if scenario is None:
                raise ValueError(
                    "job has no sub-task hints and no registered scenario "
                    f"matches its inputs; scenarios: {SCENARIOS.names()}")
            texts = list(scenario.default_tasks)
        # ensure the job's deliverable is produced: aggregation stages
        mapped = {self.library.match_interface(t) for t in texts}
        for extra in (scenario.aggregate_tasks if scenario else ()):
            m = self.library.match_interface(extra)
            if m not in mapped:
                texts.append(extra)
                mapped.add(m)
        specs: list[TaskSpec] = []
        for text in texts:
            iface_name = self.library.match_interface(text)
            if iface_name is None:
                raise ValueError(
                    f"no agent in the library matches task {text!r}")
            args = scenario.args_for(iface_name, job) if scenario else {}
            specs.append(TaskSpec(description=text, interface=iface_name,
                                  args=args))
        return specs

    def lower(self, job: Job) -> DAG:
        """Decompose the job and wire tasks by artifact dataflow."""
        specs = self.decompose(job)
        units = input_units(job.inputs)
        nodes: list[TaskNode] = []
        produced: dict[str, str] = {}         # artifact type -> producer id
        for i, ts in enumerate(specs):
            iface = self.library.interfaces[ts.interface]
            deps = tuple(produced[c] for c in iface.consumes if c in produced)
            tid = f"t{i}_{iface.name}"
            nodes.append(build_node(tid, ts.description, iface, deps,
                                    ts.args, units))
            produced[iface.produces] = tid
        return DAG(nodes)

    def toolcalls(self, dag: DAG) -> dict[str, str]:
        """Rendered executable toolcall per task (paper §3.2 example)."""
        return {tid: self.library.toolcall(dag.nodes[tid].agent,
                                           dag.nodes[tid].args)
                for tid in dag.topo_order}


# ---------------------------------------------------------------------------
# LLM planner (the paper's protocol, pluggable model)
# ---------------------------------------------------------------------------

_SYSTEM_TMPL = """You are a workflow orchestrator (ReAct). Available agents:
{agents}
Decompose the user's job into tasks, one agent each. Respond with JSON:
{{"tasks": [{{"id": str, "agent": str, "description": str,
             "deps": [str], "args": {{...}}}}]}}"""


@dataclass
class LLMPlanner:
    """ReAct-style decomposition through an LLM (paper §3.2).

    ``llm_fn(system_prompt, user_prompt) -> str`` is any text-completion
    callable — a zoo model served by the runtime, or a test fake. Falls back
    to ``RulePlanner`` output validation: whatever the LLM returns must parse
    into a valid DAG over known agents.
    """

    library: AgentLibrary
    llm_fn: Callable[[str, str], str]

    def system_prompt(self) -> str:
        """The ReAct system prompt listing every library interface."""
        lines = [f"- {i.name}({', '.join(i.schema)}): {i.description} "
                 f"[consumes: {','.join(i.consumes) or '-'}; "
                 f"produces: {i.produces}]"
                 for i in self.library.interfaces.values()]
        return _SYSTEM_TMPL.format(agents="\n".join(lines))

    def lower(self, job: Job) -> DAG:
        """Ask the LLM for a task decomposition and validate it."""
        user = job.description
        if job.tasks:
            user += "\nSub-tasks: " + "; ".join(job.tasks)
        raw = self.llm_fn(self.system_prompt(), user)
        spec = json.loads(raw)
        units = input_units(job.inputs)
        nodes = []
        for t in spec["tasks"]:
            if t["agent"] not in self.library.interfaces:
                raise ValueError(f"LLM mapped to unknown agent {t['agent']!r}")
            iface = self.library.interfaces[t["agent"]]
            nodes.append(build_node(
                t["id"], t.get("description", ""), iface,
                tuple(t.get("deps", ())), t.get("args", {}), units))
        return DAG(nodes)


def dag_creation_overhead(dag: DAG, makespan_s: float,
                          llm_latency_s: float = 0.15) -> float:
    """Fraction of workflow time spent on DAG creation (paper §3.3b: <1%).

    One short-in/short-out LLM query per task node.
    """
    if makespan_s <= 0:
        return math.inf
    return len(dag) * llm_latency_s / makespan_s
