"""Job decomposition + task->agent mapping (paper §3.2).

Two planners behind one interface:

- ``RulePlanner`` — deterministic: keyword/schema matching over the agent
  library, dataflow edges derived from interface produces/consumes types.
  This is the offline stand-in for the paper's orchestrator LLM (DESIGN.md
  §5.3 records the substitution; the paper itself measures DAG creation at
  <1% of workflow time, so the swap does not distort the evaluation).
- ``LLMPlanner`` — the paper's NVLM/ReAct protocol: agent library via system
  prompt, task descriptions via user prompt, JSON DAG back. Takes any
  ``llm_fn(system, user) -> str`` (tests inject a fake; production would bind
  a served model from the zoo).

Both emit toolcalls in the paper's format, e.g.
``FrameExtractor(end_time=60, file='cats.mov', num_frames=10, start_time=0)``.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Callable, Sequence

from .agents import AgentLibrary
from .dag import DAG, TaskNode
from .workflow import Job, VideoInput

# Default NL decomposition templates per job genre (RulePlanner fallback when
# the job gives no sub-task hints). Mirrors paper Listing 2's t1..t3 plus the
# aggregation stages of the evaluated workflow (summarize + embed).
_VIDEO_TASKS = (
    "Extract frames from each video",
    "Run speech-to-text on all scenes",
    "Detect objects in the frames",
)
_AGGREGATE_TASKS = (
    "Summarize each scene using the gathered context",
    "Embed the summaries into the vector database",
)


def _scenes(inputs: Sequence) -> tuple[int, int]:
    """(total scenes, frames per scene) across the job's video inputs."""
    vids = [v for v in inputs if isinstance(v, VideoInput)]
    if not vids:
        return 1, 1
    return (sum(v.scenes for v in vids),
            max(v.frames_per_scene for v in vids))


class RulePlanner:
    """Deterministic job -> DAG lowering via the agent library."""

    # per-frame summarize context: frame caption + objects + transcript chunk
    SUMM_TOKENS_IN = 900
    SUMM_TOKENS_OUT = 120

    def __init__(self, library: AgentLibrary):
        self.library = library

    def decompose(self, job: Job) -> list[str]:
        """Job description -> NL sub-tasks (hints kept if sufficient)."""
        tasks = list(job.tasks)
        if not tasks:
            tasks = list(_VIDEO_TASKS)
        # ensure the job's deliverable is produced: aggregation stages
        mapped = {self.library.match_interface(t) for t in tasks}
        for extra in _AGGREGATE_TASKS:
            if self.library.match_interface(extra) not in mapped:
                tasks.append(extra)
                mapped.add(self.library.match_interface(extra))
        return tasks

    def lower(self, job: Job) -> DAG:
        tasks = self.decompose(job)
        scenes, fps = _scenes(job.inputs)
        nodes: list[TaskNode] = []
        produced: dict[str, str] = {}         # dataflow type -> producer id
        for i, text in enumerate(tasks):
            iface_name = self.library.match_interface(text)
            if iface_name is None:
                raise ValueError(
                    f"no agent in the library matches task {text!r}")
            iface = self.library.interfaces[iface_name]
            deps = tuple(produced[c] for c in iface.consumes if c in produced)
            tid = f"t{i}_{iface_name}"
            work_items = scenes * fps if iface_name == "summarize" else scenes
            tok_in = self.SUMM_TOKENS_IN if iface_name in ("summarize", "qa") \
                else 0
            tok_out = self.SUMM_TOKENS_OUT if iface_name in ("summarize", "qa") \
                else 0
            nodes.append(TaskNode(
                id=tid, description=text, agent=iface_name, deps=deps,
                args=self.toolcall_args(iface_name, job),
                work_items=work_items, chunkable=True,
                tokens_in=tok_in, tokens_out=tok_out))
            produced[iface.produces] = tid
        return DAG(nodes)

    def toolcall_args(self, iface: str, job: Job) -> dict:
        vids = [v for v in job.inputs if isinstance(v, VideoInput)]
        first = vids[0] if vids else VideoInput("input")
        if iface == "frame_extract":
            return {"file": first.name, "start_time": 0,
                    "end_time": int(first.duration_s),
                    "num_frames": first.frames_per_scene}
        if iface == "speech_to_text":
            return {"file": first.name, "language": "en"}
        if iface == "object_detect":
            return {"frames": "$frames", "labels": "auto"}
        if iface == "summarize":
            return {"context": "$frames+$objects+$transcript",
                    "max_tokens": self.SUMM_TOKENS_OUT}
        if iface == "embed":
            return {"texts": "$summary"}
        if iface == "qa":
            return {"question": job.description, "top_k": 5}
        return {}

    def toolcalls(self, dag: DAG) -> dict[str, str]:
        return {tid: self.library.toolcall(dag.nodes[tid].agent,
                                           dag.nodes[tid].args)
                for tid in dag.topo_order}


# ---------------------------------------------------------------------------
# LLM planner (the paper's protocol, pluggable model)
# ---------------------------------------------------------------------------

_SYSTEM_TMPL = """You are a workflow orchestrator (ReAct). Available agents:
{agents}
Decompose the user's job into tasks, one agent each. Respond with JSON:
{{"tasks": [{{"id": str, "agent": str, "description": str,
             "deps": [str], "args": {{...}}}}]}}"""


@dataclass
class LLMPlanner:
    """ReAct-style decomposition through an LLM (paper §3.2).

    ``llm_fn(system_prompt, user_prompt) -> str`` is any text-completion
    callable — a zoo model served by the runtime, or a test fake. Falls back
    to ``RulePlanner`` output validation: whatever the LLM returns must parse
    into a valid DAG over known agents.
    """

    library: AgentLibrary
    llm_fn: Callable[[str, str], str]

    def system_prompt(self) -> str:
        lines = [f"- {i.name}({', '.join(i.schema)}): {i.description} "
                 f"[consumes: {','.join(i.consumes) or '-'}; "
                 f"produces: {i.produces}]"
                 for i in self.library.interfaces.values()]
        return _SYSTEM_TMPL.format(agents="\n".join(lines))

    def lower(self, job: Job) -> DAG:
        user = job.description
        if job.tasks:
            user += "\nSub-tasks: " + "; ".join(job.tasks)
        raw = self.llm_fn(self.system_prompt(), user)
        spec = json.loads(raw)
        scenes, fps = _scenes(job.inputs)
        nodes = []
        for t in spec["tasks"]:
            if t["agent"] not in self.library.interfaces:
                raise ValueError(f"LLM mapped to unknown agent {t['agent']!r}")
            items = scenes * fps if t["agent"] == "summarize" else scenes
            nodes.append(TaskNode(
                id=t["id"], description=t.get("description", ""),
                agent=t["agent"], deps=tuple(t.get("deps", ())),
                args=t.get("args", {}), work_items=items, chunkable=True,
                tokens_in=RulePlanner.SUMM_TOKENS_IN
                if t["agent"] in ("summarize", "qa") else 0,
                tokens_out=RulePlanner.SUMM_TOKENS_OUT
                if t["agent"] in ("summarize", "qa") else 0))
        return DAG(nodes)


def dag_creation_overhead(dag: DAG, makespan_s: float,
                          llm_latency_s: float = 0.15) -> float:
    """Fraction of workflow time spent on DAG creation (paper §3.3b: <1%).

    One short-in/short-out LLM query per task node.
    """
    if makespan_s <= 0:
        return math.inf
    return len(dag) * llm_latency_s / makespan_s
