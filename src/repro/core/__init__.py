"""Murakkab core: the paper's contribution as a composable system.

Public API::

    from repro.core import (Job, Workflow, Tool, MLModel, LLM,
                            MIN_COST, MIN_ENERGY, MIN_LATENCY, MAX_QUALITY,
                            Deadline, Budget, Weighted, Lexicographic,
                            Murakkab, VideoInput, DocumentInput, QueryInput)

    system = Murakkab.paper_cluster()
    result = Job("List objects shown/mentioned in the videos",
                 inputs=videos, constraints=MIN_COST).execute(system)
"""
from .admission import (POLICIES, TENANT_CLASSES, Admission, AdmissionPolicy,
                        FCFS, StrictPriority, WeightedFair, get_policy)
from .agents import (AgentImpl, AgentInterface, AgentLibrary, Work,
                     default_library)
from .arrivals import (DEFAULT_TENANT_SHARES, SERVING_PRESETS, ArrivalEvent,
                       ArrivalProcess, MMPPArrivals, PoissonArrivals,
                       ServingPreset, TraceArrivals, default_mix,
                       register_preset)
from .autoscale import Autoscaler, PoolPolicy, ScaleAction
from .cluster import ClusterManager, Instance, Pool
from .constraints import (Budget, Constraint, ConstraintSpec, Deadline,
                          Lexicographic, MaxQuality, MinCost, MinEnergy,
                          MinLatency, Objective, Weighted, as_spec)
from .dag import DAG, TaskNode
from .energy import (CATALOG, DeviceSpec, EnergyLedger, batch_knee,
                     batch_roofline_latency, roofline_latency)
from .faults import FaultProfile, RetryPolicy
from .orchestrator import LLMPlanner, RulePlanner, dag_creation_overhead
from .profiles import Profile, ProfileStore
from .router import OfflineEvaluator, Router
from .scheduler import ExecutionPlan, Scheduler, TaskConfig
from .telemetry import (QueryFeatures, TaskRecord, TelemetryStore, featurize,
                        featurize_node)
from .simulator import (OpenLoopReport, SimReport, Simulator, Submission,
                        TraceEntry, render_trace)
from .spec import (ARTIFACTS, SCENARIOS, Artifact, ArtifactRegistry,
                   CardinalityModel, InputSet, Scenario, ScenarioRegistry,
                   TaskSpec, TokenModel, build_node, input_artifacts,
                   input_units)
from .system import JobResult, Murakkab
from .workflow import (LLM, MAX_QUALITY, MIN_COST, MIN_ENERGY, MIN_LATENCY,
                       DocumentInput, ImperativeWorkflow, Job, MLModel,
                       QueryInput, Tool, VideoInput, Workflow)

__all__ = [
    "POLICIES", "TENANT_CLASSES", "Admission", "AdmissionPolicy", "FCFS",
    "StrictPriority", "WeightedFair", "get_policy",
    "AgentImpl", "AgentInterface", "AgentLibrary", "Work", "default_library",
    "ClusterManager", "Instance", "Pool", "DAG", "TaskNode",
    "CATALOG", "DeviceSpec", "EnergyLedger", "batch_knee",
    "batch_roofline_latency", "roofline_latency",
    "LLMPlanner", "RulePlanner", "dag_creation_overhead",
    "Profile", "ProfileStore", "ExecutionPlan", "Scheduler", "TaskConfig",
    "OfflineEvaluator", "Router",
    "QueryFeatures", "TaskRecord", "TelemetryStore", "featurize",
    "featurize_node",
    "OpenLoopReport", "SimReport", "Simulator", "Submission", "TraceEntry",
    "render_trace",
    "DEFAULT_TENANT_SHARES", "SERVING_PRESETS", "ArrivalEvent",
    "ArrivalProcess", "MMPPArrivals", "PoissonArrivals", "ServingPreset",
    "TraceArrivals", "default_mix", "register_preset",
    "Autoscaler", "PoolPolicy", "ScaleAction",
    "FaultProfile", "RetryPolicy",
    "JobResult", "Murakkab",
    "ARTIFACTS", "SCENARIOS", "Artifact", "ArtifactRegistry",
    "CardinalityModel", "InputSet", "Scenario", "ScenarioRegistry",
    "TaskSpec", "TokenModel", "build_node", "input_artifacts", "input_units",
    "Budget", "Constraint", "ConstraintSpec", "Deadline", "Lexicographic",
    "MaxQuality", "MinCost", "MinEnergy", "MinLatency", "Objective",
    "Weighted", "as_spec",
    "LLM", "MAX_QUALITY", "MIN_COST", "MIN_ENERGY", "MIN_LATENCY",
    "DocumentInput", "ImperativeWorkflow", "Job", "MLModel", "QueryInput",
    "Tool", "VideoInput", "Workflow",
]
