"""Workflow-aware cluster manager (paper §3.2).

Tracks resource pools (TPU slices / GPUs / CPU-host cores), serves
allocations to the scheduler, and — the paper's key point — *sees workflow
DAGs*, so it can anticipate demand: pre-warm model instances for upcoming
tasks and reclaim instances no registered workflow will need
("if no workflows are expected to require a Speech-To-Text agent soon, it
can reallocate GPU resources from Whisper to Llama").

Also exposes *harvestable* capacity (the spot/harvest-VM analogue): devices
that are free right now but may be reclaimed; the orchestrator uses them for
optional execution paths (CoT top-k) but not for critical-path work.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

from .dag import DAG
from .energy import CATALOG, DeviceSpec


@dataclass
class Pool:
    """One homogeneous capacity pool of a single device SKU."""

    name: str
    device: str                # DeviceSpec name
    capacity: int
    reserved: int = 0          # devices reserved for priority tenants
    harvestable: bool = False  # spot-like: allocs may be preempted

    @property
    def spec(self) -> DeviceSpec:
        """The pool's hardware SKU record."""
        return CATALOG[self.device]


@dataclass(frozen=True)
class Lease:
    """A granted device allocation (preemptible when ``harvest``)."""

    id: int
    pool: str
    n_devices: int
    t_start: float
    harvest: bool = False      # preemptible allocation


@dataclass
class Instance:
    """A warm model instance: weights resident on a device group."""

    impl: str
    pool: str
    n_devices: int
    busy_until: float = 0.0
    warm_since: float = 0.0
    lease: "Lease | None" = None   # the devices this instance holds


class ClusterManager:
    """Pools + leases + warm instances + workflow-aware reclamation."""

    def __init__(self, pools: list[Pool]):
        self.pools: dict[str, Pool] = {p.name: p for p in pools}
        self._used: dict[str, int] = {p.name: 0 for p in pools}
        self._leases: dict[int, Lease] = {}
        self._ids = itertools.count()
        self.instances: list[Instance] = []
        self._dags: dict[str, DAG] = {}
        self._done: dict[str, set[str]] = {}
        self.preemptions: int = 0

    # -- allocation ------------------------------------------------------------
    def free(self, pool: str) -> int:
        """Unallocated devices in ``pool`` right now."""
        p = self.pools[pool]
        return p.capacity - self._used[pool]

    def alloc(self, pool: str, n: int, t: float,
              harvest: bool = False) -> Lease | None:
        """Grant ``n`` devices, or None when they don't fit."""
        if n <= 0 or self.free(pool) < n:
            return None
        self._used[pool] += n
        lease = Lease(next(self._ids), pool, n, t, harvest=harvest)
        self._leases[lease.id] = lease
        return lease

    def release(self, lease: Lease, t: float):
        """Return a lease's devices; double release is an error."""
        if lease.id not in self._leases:
            raise KeyError(f"double release of lease {lease.id}")
        del self._leases[lease.id]
        self._used[lease.pool] -= lease.n_devices

    def lease_active(self, lease: Lease) -> bool:
        """True while the lease still holds devices (not yet released)."""
        return lease.id in self._leases

    def harvest_devices(self, pool: str) -> int:
        """Devices currently held by preemptible (harvest) leases."""
        return sum(lease.n_devices for lease in self._leases.values()
                   if lease.pool == pool and lease.harvest)

    def preempt_harvest(self, pool: str, n_needed: int, t: float) \
            -> list[Lease]:
        """Reclaim harvest leases to make room (spot semantics)."""
        victims = []
        for lease in list(self._leases.values()):
            if lease.pool == pool and lease.harvest and n_needed > 0:
                victims.append(lease)
                n_needed -= lease.n_devices
        for v in victims:
            self.release(v, t)
            self.preemptions += 1
        return victims

    # -- stats for the orchestrator (paper: "continuously receives stats") -----
    def stats(self) -> dict[str, dict]:
        """Per-pool scheduling facts: device/kind/capacity/free/harvestable."""
        out = {}
        for name, p in self.pools.items():
            free = self.free(name)
            out[name] = {
                "device": p.device, "kind": p.spec.kind,
                "capacity": p.capacity, "free": free,
                "harvestable": free if p.harvestable else
                    max(free - p.reserved, 0),
            }
        return out

    def pools_of_kind(self, kind: str) -> list[Pool]:
        """Pools whose device kind matches (gpu | cpu | tpu)."""
        return [p for p in self.pools.values() if p.spec.kind == kind]

    def digest(self) -> tuple:
        """Hashable snapshot of every cluster fact the scheduler reads.

        Pool occupancy (``stats()``'s free/harvestable derive from it) plus
        the warm-instance set (plan_task's warmth check). Equal digests ⟹
        the deterministic scheduler returns identical plans, which is what
        makes the admission-time plan cache sound (DESIGN.md §7). Instance
        busy-times and lease identities are deliberately excluded — the
        planner never reads them.
        """
        return (tuple(sorted(self._used.items())),
                frozenset((i.impl, i.pool) for i in self.instances))

    # -- workflow awareness ------------------------------------------------------
    def register_workflow(self, wf_id: str, dag: DAG):
        """Announce an admitted workflow's DAG (feeds upcoming_demand)."""
        self._dags[wf_id] = dag
        self._done[wf_id] = set()

    def complete_task(self, wf_id: str, task_id: str):
        """Mark a task done; fully-done workflows stop counting as demand."""
        if wf_id in self._done:
            self._done[wf_id].add(task_id)
            if self._done[wf_id] >= set(self._dags[wf_id].nodes):
                del self._dags[wf_id], self._done[wf_id]

    def upcoming_demand(self) -> dict[str, int]:
        """Pending task count per agent interface, across registered DAGs."""
        demand: dict[str, int] = {}
        for wf_id, dag in self._dags.items():
            done = self._done[wf_id]
            for tid, node in dag.nodes.items():
                if tid not in done:
                    demand[node.agent] = demand.get(node.agent, 0) + 1
        return demand

    # -- warm instances ------------------------------------------------------------
    def find_instance(self, impl: str, t: float) -> Instance | None:
        """Earliest-available warm instance of ``impl``."""
        cands = [i for i in self.instances if i.impl == impl]
        return min(cands, key=lambda i: i.busy_until) if cands else None

    def add_instance(self, inst: Instance):
        """Track a newly-provisioned warm model instance."""
        self.instances.append(inst)

    def rebalance(self, library, t: float) -> list[str]:
        """Reclaim warm instances for interfaces with no upcoming demand.

        Returns a log of actions (tested; the paper's Whisper->Llama example).
        """
        demand = self.upcoming_demand()
        actions = []
        for inst in list(self.instances):
            iface = library.impls[inst.impl].interface
            if demand.get(iface, 0) == 0 and inst.busy_until <= t:
                self.evict_instance(inst, t)
                actions.append(f"reclaim {inst.impl} ({inst.n_devices} dev "
                               f"of {inst.pool}): no upcoming {iface} demand")
        return actions

    def evict_instance(self, inst: Instance, t: float):
        """Remove a warm instance and free its devices."""
        self.instances.remove(inst)
        if inst.lease is not None and inst.lease.id in self._leases:
            self.release(inst.lease, t)

    def audit(self):
        """Assert the instance/lease bookkeeping invariants.

        Used by tests around the preemption/eviction paths: (1) per-pool
        usage equals the sum of live lease sizes and never exceeds
        capacity; (2) every instance's lease, when still live, belongs to
        the lease table and matches the instance's pool and device count;
        (3) no two instances share a lease. Raises ``AssertionError`` with
        the violated fact otherwise.
        """
        by_pool: dict[str, int] = {name: 0 for name in self.pools}
        for lease in self._leases.values():
            by_pool[lease.pool] += lease.n_devices
        for name, p in self.pools.items():
            assert self._used[name] == by_pool[name], (
                f"pool {name}: used={self._used[name]} but live leases "
                f"hold {by_pool[name]}")
            assert 0 <= self._used[name] <= p.capacity, (
                f"pool {name}: used={self._used[name]} outside "
                f"[0, {p.capacity}]")
        seen: set[int] = set()
        for inst in self.instances:
            if inst.lease is None:
                continue
            assert inst.lease.id not in seen, (
                f"lease {inst.lease.id} held by two instances")
            seen.add(inst.lease.id)
            assert inst.lease.id in self._leases, (
                f"instance {inst.impl}@{inst.pool} holds released lease "
                f"{inst.lease.id} (dangling warm shell)")
            assert self._leases[inst.lease.id] is inst.lease
            assert inst.lease.pool == inst.pool
            assert inst.lease.n_devices == inst.n_devices

    def utilization(self) -> dict[str, float]:
        """Allocated fraction per pool (0..1)."""
        return {name: self._used[name] / p.capacity
                for name, p in self.pools.items() if p.capacity}
