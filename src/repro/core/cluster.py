"""Workflow-aware cluster manager (paper §3.2).

Tracks resource pools (TPU slices / GPUs / CPU-host cores), serves
allocations to the scheduler, and — the paper's key point — *sees workflow
DAGs*, so it can anticipate demand: pre-warm model instances for upcoming
tasks and reclaim instances no registered workflow will need
("if no workflows are expected to require a Speech-To-Text agent soon, it
can reallocate GPU resources from Whisper to Llama").

Also exposes *harvestable* capacity (the spot/harvest-VM analogue): devices
that are free right now but may be reclaimed; the orchestrator uses them for
optional execution paths (CoT top-k) but not for critical-path work.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .dag import DAG
from .energy import CATALOG, DeviceSpec

# fraction of an instance's post-weights HBM headroom budgeted for the
# KV/prefix cache (the rest is activations/fragmentation slack)
KV_BUDGET_FRAC = 0.9


def kv_cache_cap(spec: DeviceSpec, n_devices: int, params_bytes: float,
                 kv_bytes_per_token: float) -> float:
    """HBM bytes an instance can devote to resident prefix KV.

    Weights are sharded across the device group, so the budget is the
    group's aggregate HBM minus one copy of the weights, scaled by
    :data:`KV_BUDGET_FRAC`. Zero when the implementation declares no KV
    footprint (tools, non-attention models) — such instances never cache.
    """
    if kv_bytes_per_token <= 0:
        return 0.0
    return max(spec.hbm_bytes * n_devices - params_bytes, 0.0) \
        * KV_BUDGET_FRAC


@dataclass(slots=True)
class CacheEntry:
    """One resident prefix: a session's KV bytes held on an instance."""

    session: str
    tokens: int                # prefix tokens the entry can serve
    bytes: float               # HBM residency (kv_bytes_per_token * tokens)
    last_used: float           # LRU recency (sim time)


@dataclass
class Pool:
    """One homogeneous capacity pool of a single device SKU."""

    name: str
    device: str                # DeviceSpec name
    capacity: int
    reserved: int = 0          # devices reserved for priority tenants
    harvestable: bool = False  # spot-like: allocs may be preempted

    @property
    def spec(self) -> DeviceSpec:
        """The pool's hardware SKU record."""
        return CATALOG[self.device]


class Lease:
    """A granted device allocation (preemptible when ``harvest``).

    A plain ``__slots__`` class rather than a frozen dataclass: the engine
    mints one per allocation on its hot path, and slot assignment is several
    times cheaper than the frozen-dataclass ``object.__setattr__`` chain.
    Only ``harvest`` is ever reassigned (the engine's lease relabeling);
    treat everything else as immutable. ``session`` is the chat/agent-loop
    session the allocation serves, when known — an attribution hint for
    debugging and audits, not a scheduling input.
    """

    __slots__ = ("id", "pool", "n_devices", "t_start", "harvest", "session")

    def __init__(self, id: int, pool: str, n_devices: int, t_start: float,
                 harvest: bool = False, session: str = ""):
        self.id = id
        self.pool = pool
        self.n_devices = n_devices
        self.t_start = t_start
        self.harvest = harvest            # preemptible allocation
        self.session = session            # serving-session attribution hint

    def __repr__(self):
        return (f"Lease(id={self.id}, pool={self.pool!r}, "
                f"n_devices={self.n_devices}, t_start={self.t_start}, "
                f"harvest={self.harvest})")


@dataclass(eq=False, slots=True)
class Instance:
    """A warm model instance: weights resident on a device group.

    Identity equality (``eq=False``): instances are unique live objects,
    and the eviction path removes them from lists — value equality would
    make every ``list.remove`` compare all fields of every element.
    """

    impl: str
    pool: str
    n_devices: int
    busy_until: float = 0.0
    warm_since: float = 0.0
    lease: "Lease | None" = None   # the devices this instance holds
    # provisioning sequence number (assigned by ``add_instance``): lets
    # index-driven scans reproduce the global instance-list order exactly
    seq: int = 0
    # KV/prefix-cache residency (DESIGN.md §9): HBM budget left after the
    # weights, and the prefix entries resident in it, keyed by session.
    # Entries live and die with the instance — eviction drops them.
    cache_cap_bytes: float = 0.0
    cache: dict[str, CacheEntry] = field(default_factory=dict)


class ClusterManager:
    """Pools + leases + warm instances + workflow-aware reclamation."""

    def __init__(self, pools: list[Pool]):
        self.pools: dict[str, Pool] = {p.name: p for p in pools}
        self._used: dict[str, int] = {p.name: 0 for p in pools}
        self._leases: dict[int, Lease] = {}
        self._ids = itertools.count()
        self.instances: list[Instance] = []
        self._dags: dict[str, DAG] = {}
        self._done: dict[str, set[str]] = {}
        self.preemptions: int = 0
        # dirty-flag-cached digest (DESIGN.md §8): recomputed only after a
        # mutation that the planner could observe (alloc/release/instance
        # add/evict/capacity change) instead of on every admission
        self._digest: tuple | None = None
        # per-pool availability epoch: bumped whenever a blocked task could
        # newly fit (devices freed, capacity raised, preemptible supply
        # appeared). The simulator's dispatch memo skips re-attempting
        # tasks whose pool epoch hasn't moved since they last failed.
        self.free_epoch: dict[str, int] = {p.name: 0 for p in pools}
        # sum of all per-pool epoch bumps: lets the dispatcher prove a
        # whole re-scan pass would be a no-op (nothing became available)
        self.epoch_total: int = 0
        # capacity timeline per pool: [(t, capacity), ...] — the idle-power
        # floor integrates over this (autoscaled pools stop paying idle for
        # capacity they scaled away)
        self._cap_log: dict[str, list[tuple[float, int]]] = {
            p.name: [(0.0, p.capacity)] for p in pools}
        # warm-instance index: (impl, pool, n_devices) -> instances, so the
        # engine's reuse scan is O(matching) not O(all instances)
        self._inst_index: dict[tuple[str, str, int], list[Instance]] = {}
        # per-pool instance index (insertion-ordered like ``instances``, so
        # scans over one pool see victims in the same order a scan over the
        # global list would): the engine's idle-eviction and crash-victim
        # scans are O(pool) not O(cluster)
        self._pool_insts: dict[str, list[Instance]] = {
            p.name: [] for p in pools}
        # per-impl instance index + provisioning sequence: ``rebalance``
        # scans only the dead interfaces' instances (merged back into
        # global provisioning order via ``Instance.seq``) instead of the
        # whole cluster
        self._impl_insts: dict[str, list[Instance]] = {}
        self._iseq = itertools.count()
        # session -> instances holding a resident prefix entry for it (the
        # scheduler's affinity lookup; mirrors Instance.cache exactly)
        self._cache_index: dict[str, list[Instance]] = {}
        # incrementally-maintained pending-task count per agent interface
        # (upcoming_demand used to rescan every registered DAG)
        self._demand: dict[str, int] = {}
        # set when some interface's pending count just hit zero — the only
        # moment rebalance() can newly reclaim instances, so the engine
        # gates its per-finish rebalance call on this
        self.demand_zeroed: bool = False

    # -- allocation ------------------------------------------------------------
    def free(self, pool: str) -> int:
        """Unallocated devices in ``pool`` right now."""
        p = self.pools[pool]
        return p.capacity - self._used[pool]

    def alloc(self, pool: str, n: int, t: float, harvest: bool = False, *,
              session: str = "") -> Lease | None:
        """Grant ``n`` devices, or None when they don't fit.

        ``session`` (keyword-only) attributes the allocation to a serving
        session — recorded on the lease for audits/debugging; it does not
        change what fits.
        """
        if n <= 0 or self.pools[pool].capacity - self._used[pool] < n:
            return None
        self._used[pool] += n
        lease = Lease(next(self._ids), pool, n, t, harvest, session)
        self._leases[lease.id] = lease
        self._digest = None
        if harvest:
            # new preemptible supply: a blocked priority task that could
            # not preempt its way in before may fit now
            self.free_epoch[pool] += 1
            self.epoch_total += 1
        return lease

    def release(self, lease: Lease, t: float):
        """Return a lease's devices; double release is an error."""
        if lease.id not in self._leases:
            raise KeyError(f"double release of lease {lease.id}")
        del self._leases[lease.id]
        self._used[lease.pool] -= lease.n_devices
        self._digest = None
        self.free_epoch[lease.pool] += 1
        self.epoch_total += 1

    # -- elastic capacity (core/autoscale.py) -----------------------------------
    def set_capacity(self, pool: str, capacity: int, t: float) -> int:
        """Resize a pool (autoscaler lever); returns the applied capacity.

        Never shrinks below the devices currently allocated (live leases are
        pinned demand — the autoscaler cannot preempt by resizing), and
        records the change on the capacity timeline so the idle-power floor
        integrates capacity *over time* instead of charging the final size
        for the whole run.
        """
        p = self.pools[pool]
        capacity = max(int(capacity), self._used[pool])
        if capacity == p.capacity:
            return capacity
        grew = capacity > p.capacity
        p.capacity = capacity
        self._cap_log[pool].append((t, capacity))
        self._digest = None
        if grew:
            self.free_epoch[pool] += 1
            self.epoch_total += 1
        return capacity

    def capacity_device_seconds(self, pool: str, until: float) -> float:
        """∫ capacity dt over [0, until] (the idle-floor integral)."""
        log = self._cap_log[pool]
        total = 0.0
        for (t0, cap), (t1, _) in zip(log, log[1:]):
            total += cap * (min(t1, until) - min(t0, until))
        t_last, cap_last = log[-1]
        total += cap_last * max(until - t_last, 0.0)
        return total

    def capacity_log(self, pool: str) -> list[tuple[float, int]]:
        """The pool's capacity timeline [(t, capacity), ...]."""
        return list(self._cap_log[pool])

    def lease_active(self, lease: Lease) -> bool:
        """True while the lease still holds devices (not yet released)."""
        return lease.id in self._leases

    def harvest_devices(self, pool: str) -> int:
        """Devices currently held by preemptible (harvest) leases."""
        return sum(lease.n_devices for lease in self._leases.values()
                   if lease.pool == pool and lease.harvest)

    def preempt_harvest(self, pool: str, n_needed: int, t: float) \
            -> list[Lease]:
        """Reclaim harvest leases to make room (spot semantics)."""
        victims = []
        for lease in list(self._leases.values()):
            if lease.pool == pool and lease.harvest and n_needed > 0:
                victims.append(lease)
                n_needed -= lease.n_devices
        for v in victims:
            self.release(v, t)
            self.preemptions += 1
        return victims

    # -- stats for the orchestrator (paper: "continuously receives stats") -----
    def stats(self) -> dict[str, dict]:
        """Per-pool scheduling facts: device/kind/capacity/free/harvestable."""
        out = {}
        for name, p in self.pools.items():
            free = self.free(name)
            out[name] = {
                "device": p.device, "kind": p.spec.kind,
                "capacity": p.capacity, "free": free,
                "harvestable": free if p.harvestable else
                    max(free - p.reserved, 0),
            }
        return out

    def pools_of_kind(self, kind: str) -> list[Pool]:
        """Pools whose device kind matches (gpu | cpu | tpu)."""
        return [p for p in self.pools.values() if p.spec.kind == kind]

    def digest(self) -> tuple:
        """Hashable snapshot of every cluster fact the scheduler reads.

        Pool occupancy (``stats()``'s free/harvestable derive from it) plus
        the warm-instance set (plan_task's warmth check). Equal digests ⟹
        the deterministic scheduler returns identical plans, which is what
        makes the admission-time plan cache sound (DESIGN.md §7). Instance
        busy-times and lease identities are deliberately excluded — the
        planner never reads them.

        Cached behind a dirty flag: ``alloc``/``release`` (which covers
        ``preempt_harvest`` and ``evict_instance``), ``add_instance`` and
        ``set_capacity`` invalidate; every other read returns the memo, so
        admission-time plan-cache lookups stop rescanning pools/instances.
        Pool capacities are part of the digest because the autoscaler makes
        them dynamic and the planner reads them.
        """
        if self._digest is None:
            self._digest = (
                tuple(sorted(self._used.items())),
                tuple(sorted((name, p.capacity)
                             for name, p in self.pools.items())),
                frozenset((i.impl, i.pool) for i in self.instances),
                # resident prefix entries: session-affinity planning reads
                # them, so equal digests must mean equal cache state (a
                # sorted tuple, not a frozenset — two same-shaped instances
                # may both hold a session and multiplicity matters).
                # last_used is excluded: the planner never reads recency.
                tuple(sorted((i.impl, i.pool, s, e.tokens)
                             for i in self.instances
                             for s, e in i.cache.items())))
        return self._digest

    # -- workflow awareness ------------------------------------------------------
    def register_workflow(self, wf_id: str, dag: DAG):
        """Announce an admitted workflow's DAG (feeds upcoming_demand)."""
        self._dags[wf_id] = dag
        self._done[wf_id] = set()
        d = self._demand
        for node in dag.nodes.values():
            d[node.agent] = d.get(node.agent, 0) + 1

    def complete_task(self, wf_id: str, task_id: str):
        """Mark a task done; fully-done workflows stop counting as demand."""
        done = self._done.get(wf_id)
        if done is not None and task_id not in done:
            done.add(task_id)
            dag = self._dags[wf_id]
            agent = dag.nodes[task_id].agent
            demand = self._demand
            demand[agent] -= 1
            if demand[agent] == 0:
                self.demand_zeroed = True
            if len(done) >= len(dag.nodes):
                del self._dags[wf_id], self._done[wf_id]

    def abandon_workflow(self, wf_id: str):
        """Drop a dead-lettered workflow's remaining demand (fault path).

        The engine calls this when a workflow exhausts its retry budget:
        its unfinished tasks will never run, so they must stop counting as
        upcoming demand (otherwise the autoscaler would hold capacity for
        work that can no longer arrive). Safe to call for unknown ids.
        """
        dag = self._dags.pop(wf_id, None)
        if dag is None:
            return
        done = self._done.pop(wf_id, set())
        d = self._demand
        for tid, node in dag.nodes.items():
            if tid in done:
                continue
            d[node.agent] -= 1
            if d[node.agent] == 0:
                self.demand_zeroed = True

    def upcoming_demand(self) -> dict[str, int]:
        """Pending task count per agent interface, across registered DAGs.

        Maintained incrementally (+1 per node at ``register_workflow``, -1
        at ``complete_task``) — the seed rescanned every registered DAG on
        each call, which the open-loop rebalance cadence can't afford.
        """
        return {agent: n for agent, n in self._demand.items() if n > 0}

    # -- warm instances ------------------------------------------------------------
    def find_instance(self, impl: str, t: float) -> Instance | None:
        """Earliest-available warm instance of ``impl``."""
        cands = self._impl_insts.get(impl)
        return min(cands, key=lambda i: i.busy_until) if cands else None

    def warm_instances(self, impl: str, pool: str,
                       n_devices: int) -> list[Instance]:
        """Instances matching (impl, pool, n_devices) exactly — O(matching)
        via the instance index (the simulator's reuse scan)."""
        return self._inst_index.get((impl, pool, n_devices), ())

    def pool_instances(self, pool: str) -> list[Instance]:
        """Live warm instances on ``pool``, in provisioning order."""
        return self._pool_insts.get(pool, ())

    def add_instance(self, inst: Instance):
        """Track a newly-provisioned warm model instance."""
        inst.seq = next(self._iseq)
        self.instances.append(inst)
        key = (inst.impl, inst.pool, inst.n_devices)
        rows = self._inst_index.get(key)
        if rows is None:
            rows = self._inst_index[key] = []
        rows.append(inst)
        rows = self._pool_insts.get(inst.pool)
        if rows is None:
            rows = self._pool_insts[inst.pool] = []
        rows.append(inst)
        rows = self._impl_insts.get(inst.impl)
        if rows is None:
            rows = self._impl_insts[inst.impl] = []
        rows.append(inst)
        self._digest = None

    # -- KV/prefix-cache ledger (DESIGN.md §9) ----------------------------------
    def cached_instances(self, session: str) -> list[Instance]:
        """Instances holding a resident prefix entry for ``session``."""
        return list(self._cache_index.get(session, ()))

    def cache_tokens(self, inst: Instance, session: str) -> int:
        """Prefix tokens resident for ``session`` on ``inst`` (0 if none)."""
        entry = inst.cache.get(session)
        return entry.tokens if entry is not None else 0

    def cache_residency(self, inst: Instance) -> float:
        """Total HBM bytes ``inst``'s resident prefix entries occupy."""
        return sum(e.bytes for e in inst.cache.values())

    def cache_touch(self, inst: Instance, session: str, t: float):
        """Refresh an entry's LRU recency (a task just reused the prefix).

        Recency is not part of the digest (the planner reads presence and
        token counts, never last-used times), so touching stays O(1) with
        no plan-cache invalidation.
        """
        entry = inst.cache.get(session)
        if entry is not None:
            entry.last_used = t

    def cache_insert(self, inst: Instance, session: str, tokens: int,
                     nbytes: float, t: float) -> bool:
        """Insert or refresh a session's prefix entry, LRU-evicting to fit.

        Returns False without touching the ledger when the instance has no
        cache budget or the entry alone exceeds it. Otherwise older entries
        (least-recently-used first, session name as the deterministic
        tie-break) are evicted until the new entry fits; residency never
        exceeds ``cache_cap_bytes`` (an ``audit()`` invariant). Mutations
        invalidate the digest so the admission plan cache re-keys.
        """
        if not session or inst.cache_cap_bytes <= 0 \
                or nbytes > inst.cache_cap_bytes:
            return False
        old = inst.cache.pop(session, None)
        resident = sum(e.bytes for e in inst.cache.values())
        while inst.cache and resident + nbytes > inst.cache_cap_bytes:
            lru = min(inst.cache,
                      key=lambda s: (inst.cache[s].last_used, s))
            resident -= inst.cache[lru].bytes
            self._drop_entry(inst, lru)
        inst.cache[session] = CacheEntry(session, int(tokens), float(nbytes),
                                         t)
        if old is None:
            self._cache_index.setdefault(session, []).append(inst)
        self._digest = None
        return True

    def _drop_entry(self, inst: Instance, session: str):
        """Remove one prefix entry, keeping the session index in sync."""
        del inst.cache[session]
        group = self._cache_index.get(session)
        if group is not None:
            group.remove(inst)
            if not group:
                del self._cache_index[session]

    def rebalance(self, library, t: float) -> list[str]:
        """Reclaim warm instances for interfaces with no upcoming demand.

        Returns a log of actions (tested; the paper's Whisper->Llama example).
        A shell holding resident session prefixes is *not* reclaimed here:
        KV residency is a first-class resource (DESIGN.md §9), and pending
        demand undercounts it — the sessions whose prefixes live on the
        shell return after think-time gaps the demand ledger cannot see.
        Such shells still fall to allocation-pressure eviction
        (``evict_instance`` via the engine's alloc path) and to harvest
        preemption, both of which drop the cache with the shell.
        """
        # only interfaces whose pending count sits at zero can lose
        # instances — when none do (the common case), skip the scan
        dead = {iface for iface, n in self._demand.items() if n <= 0}
        if not dead:
            return []
        actions = []
        impls = library.impls
        # scan only the dead interfaces' instances via the per-impl index,
        # merged back into global provisioning order (Instance.seq) so the
        # eviction sequence — and the actions log — is exactly what a scan
        # over the full instance list would produce
        cands: list[Instance] = []
        for impl_name, group in self._impl_insts.items():
            if group and impls[impl_name].interface in dead:
                cands.extend(group)
        cands.sort(key=lambda i: i.seq)
        for inst in cands:
            if inst.busy_until <= t and not inst.cache:
                iface = impls[inst.impl].interface
                self.evict_instance(inst, t)
                actions.append(f"reclaim {inst.impl} ({inst.n_devices} dev "
                               f"of {inst.pool}): no upcoming {iface} demand")
        return actions

    def evict_instance(self, inst: Instance, t: float):
        """Remove a warm instance and free its devices.

        The instance's resident prefix entries die with it — harvest
        preemption therefore evicts the preempted instance's KV cache
        (DESIGN.md §9): a resumed task re-plans against a cluster that no
        longer advertises those prefixes.
        """
        for session in list(inst.cache):
            self._drop_entry(inst, session)
        self.instances.remove(inst)
        self._inst_index[(inst.impl, inst.pool, inst.n_devices)].remove(inst)
        self._pool_insts[inst.pool].remove(inst)
        self._impl_insts[inst.impl].remove(inst)
        self._digest = None
        if inst.lease is not None and inst.lease.id in self._leases:
            self.release(inst.lease, t)

    def audit(self):
        """Assert the instance/lease bookkeeping invariants.

        Used by tests around the preemption/eviction paths: (1) per-pool
        usage equals the sum of live lease sizes and never exceeds
        capacity; (2) every instance's lease, when still live, belongs to
        the lease table and matches the instance's pool and device count;
        (3) no two instances share a lease; (4) cache-ledger invariants —
        no prefix entry indexed on a dead instance, per-instance residency
        never above the HBM cache budget, and the session index mirroring
        the per-instance entry dicts exactly. Raises ``AssertionError``
        with the violated fact otherwise.
        """
        by_pool: dict[str, int] = {name: 0 for name in self.pools}
        for lease in self._leases.values():
            by_pool[lease.pool] += lease.n_devices
        for name, p in self.pools.items():
            assert self._used[name] == by_pool[name], (
                f"pool {name}: used={self._used[name]} but live leases "
                f"hold {by_pool[name]}")
            assert 0 <= self._used[name] <= p.capacity, (
                f"pool {name}: used={self._used[name]} outside "
                f"[0, {p.capacity}]")
        seen: set[int] = set()
        for inst in self.instances:
            if inst.lease is None:
                continue
            assert inst.lease.id not in seen, (
                f"lease {inst.lease.id} held by two instances")
            seen.add(inst.lease.id)
            assert inst.lease.id in self._leases, (
                f"instance {inst.impl}@{inst.pool} holds released lease "
                f"{inst.lease.id} (dangling warm shell)")
            assert self._leases[inst.lease.id] is inst.lease
            assert inst.lease.pool == inst.pool
            assert inst.lease.n_devices == inst.n_devices
        # instance index mirrors the instance list exactly
        indexed = [i for group in self._inst_index.values() for i in group]
        assert len(indexed) == len(self.instances), (
            f"instance index holds {len(indexed)} entries but "
            f"{len(self.instances)} instances are live")
        for inst in self.instances:
            assert inst in self._inst_index.get(
                (inst.impl, inst.pool, inst.n_devices), ()), (
                f"instance {inst.impl}@{inst.pool} missing from index")
        pooled = [i for group in self._pool_insts.values() for i in group]
        assert len(pooled) == len(self.instances), (
            f"pool index holds {len(pooled)} entries but "
            f"{len(self.instances)} instances are live")
        by_impl = [i for group in self._impl_insts.values() for i in group]
        assert len(by_impl) == len(self.instances), (
            f"impl index holds {len(by_impl)} entries but "
            f"{len(self.instances)} instances are live")
        for inst in self.instances:
            assert inst in self._pool_insts.get(inst.pool, ()), (
                f"instance {inst.impl}@{inst.pool} missing from pool index")
            assert inst in self._impl_insts.get(inst.impl, ()), (
                f"instance {inst.impl}@{inst.pool} missing from impl index")
        # cache ledger: index entries live, residency within budget, and
        # index <-> per-instance entry dicts mirror each other
        live = {id(i) for i in self.instances}
        for session, group in self._cache_index.items():
            for inst in group:
                assert id(inst) in live, (
                    f"cache entry for session {session!r} on a dead "
                    f"instance ({inst.impl}@{inst.pool})")
                assert session in inst.cache, (
                    f"session {session!r} indexed on {inst.impl}@"
                    f"{inst.pool} but absent from its entry dict")
        for inst in self.instances:
            resident = sum(e.bytes for e in inst.cache.values())
            assert resident <= inst.cache_cap_bytes + 1e-6, (
                f"instance {inst.impl}@{inst.pool}: cache residency "
                f"{resident:.3e} B exceeds budget "
                f"{inst.cache_cap_bytes:.3e} B")
            for session in inst.cache:
                assert inst in self._cache_index.get(session, ()), (
                    f"entry for session {session!r} on {inst.impl}@"
                    f"{inst.pool} missing from the session index")

    def utilization(self) -> dict[str, float]:
        """Allocated fraction per pool (0..1)."""
        return {name: self._used[name] / p.capacity
                for name, p in self.pools.items() if p.capacity}
