"""Agent library: interfaces, schemas, implementations (paper §3.2).

An *interface* is what tasks bind to ("speech_to_text"); an *implementation*
is a concrete model/tool that satisfies it ("whisper-large",
"seamless-m4t-large-v2"), each with its own quality score, hardware support
and workload model. Murakkab selects among implementations at runtime — this
indirection is the fungibility the paper builds on.

Implementations backed by the model zoo carry ``arch=<assigned architecture>``;
their FLOP/byte workload models are derived from the config (same math as the
roofline analysis), and the real executor can run their reduced configs on
CPU end-to-end.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable

from ..configs.registry import get_config
from ..models.model_zoo import build_model
from .spec import ARTIFACTS, CardinalityModel, TokenModel


@dataclass(frozen=True)
class Work:
    """Device-agnostic workload of one task invocation.

    ``flops``/``hbm_bytes`` are the single-item totals the seed roofline
    consumes. A work model may additionally declare a *prefill/decode phase
    split* (DESIGN.md §7): per-phase FLOPs plus the HBM traffic partitioned
    into ``weight_bytes`` — the parameter stream, read once per decode step
    *regardless of batch size* — and per-item activation/KV bytes. The
    split is what makes ``energy.batch_roofline_latency`` batch-aware:
    shared weight streams amortize across a batch, per-item bytes do not.
    """

    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    # -- prefill/decode phase split (all zero => no phase info) --------------
    prefill_flops: float = 0.0     # forward over the prompt, per item
    decode_flops: float = 0.0      # autoregressive steps, per item
    prefill_bytes: float = 0.0     # per-item prompt/activation HBM traffic
    decode_bytes: float = 0.0      # per-item KV/activation traffic, all steps
    weight_bytes: float = 0.0      # parameter bytes streamed per decode step
    decode_steps: float = 0.0      # number of decode steps (~tokens_out)

    @property
    def has_phases(self) -> bool:
        """True when the model declared a prefill/decode split."""
        return self.weight_bytes > 0.0 and self.decode_steps > 0.0

    @property
    def shared_bytes(self) -> float:
        """HBM traffic amortized across a batch: the weights stream, read
        once per decode step however many items are co-scheduled."""
        return self.weight_bytes * self.decode_steps

    @property
    def per_item_bytes(self) -> float:
        """HBM traffic that scales with batch size (activations, KV)."""
        return max(self.hbm_bytes - self.shared_bytes, 0.0)

    @staticmethod
    def two_phase(prefill_flops: float, decode_flops: float,
                  prefill_bytes: float, decode_bytes: float,
                  weight_bytes: float, decode_steps: float,
                  coll_bytes: float = 0.0) -> "Work":
        """Build a phased Work whose legacy totals are consistent with the
        split, so the batch model reduces to the seed roofline at batch=1."""
        steps = max(decode_steps, 0.0)
        return Work(flops=prefill_flops + decode_flops,
                    hbm_bytes=weight_bytes * steps + prefill_bytes
                    + decode_bytes,
                    coll_bytes=coll_bytes,
                    prefill_flops=prefill_flops, decode_flops=decode_flops,
                    prefill_bytes=prefill_bytes, decode_bytes=decode_bytes,
                    weight_bytes=weight_bytes, decode_steps=steps)

    def __mul__(self, k: float) -> "Work":
        # k items: extensive quantities scale; the resident weights do not
        # (k items means k * decode_steps weight streams, not k * weights).
        return Work(self.flops * k, self.hbm_bytes * k, self.coll_bytes * k,
                    self.prefill_flops * k, self.decode_flops * k,
                    self.prefill_bytes * k, self.decode_bytes * k,
                    self.weight_bytes, self.decode_steps * k)

    __rmul__ = __mul__

    def __add__(self, o: "Work") -> "Work":
        # combined shared stream must equal the sum of both works' streams
        # (keeps shared + per_item == hbm and the b=1 == seed invariant);
        # the larger residency stands in as the stream granularity.
        wb = max(self.weight_bytes, o.weight_bytes)
        shared = self.shared_bytes + o.shared_bytes
        return Work(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes,
                    self.coll_bytes + o.coll_bytes,
                    self.prefill_flops + o.prefill_flops,
                    self.decode_flops + o.decode_flops,
                    self.prefill_bytes + o.prefill_bytes,
                    self.decode_bytes + o.decode_bytes,
                    wb,
                    shared / wb if wb else
                    self.decode_steps + o.decode_steps)


@dataclass(frozen=True)
class AgentInterface:
    """A capability tasks can bind to, with a toolcall schema.

    The interface *declares* its workload shape (DESIGN.md §2): how one
    invocation fans out over the job's input units (``cardinality``) and its
    per-item token footprint (``tokens``). Planners read these; no lowering
    path carries per-interface constants.
    """

    name: str
    description: str
    schema: dict[str, str]            # arg name -> type (toolcall schema)
    keywords: tuple[str, ...]         # rule-planner matching terms
    produces: str                     # artifact type: frames|transcript|...
    consumes: tuple[str, ...] = ()
    cardinality: CardinalityModel = CardinalityModel()
    tokens: TokenModel = TokenModel()


@dataclass(frozen=True)
class AgentImpl:
    """One concrete model/tool implementing an interface."""

    name: str
    interface: str
    quality: float                      # relative result quality in [0, 1]
    hw_kinds: tuple[str, ...]           # device kinds this impl can run on
    # workload per work-item as a function of (tokens_in, tokens_out):
    work_fn: Callable[[int, int], Work]
    min_devices: dict[str, int] = field(default_factory=dict)
    max_devices: dict[str, int] = field(default_factory=dict)
    chunkable: bool = True              # intra-task fan-out allowed
    mxu_efficiency: float = 0.6         # fraction of peak when compute-bound
    power_frac: float = 1.0             # fraction of (active-idle) power drawn
    load_time_s: float = 0.0            # cold-start (weights load) latency
    arch: str | None = None             # model-zoo backing (real execution)
    params_bytes: float = 0.0
    overhead_s: float = 0.0             # per-step invocation overhead
    # batching lever. Impls whose work model declares a prefill/decode phase
    # split (``Work.has_phases``) get the batch-aware roofline
    # (``energy.batch_roofline_latency``): weights stream once per decode
    # step regardless of batch, so per-item latency falls until the compute
    # knee. ``batch_alpha`` is the DEPRECATED scalar fallback — time(batch
    # of b) = per_item * b**alpha — kept only for impls without a phase
    # split and for *single-point* pinned (measured) profile rows; pinned
    # rows with a per-batch latency curve (ProfileStore.pin, DESIGN.md
    # §7.2) batch over their calibration instead.
    max_batch: int = 1
    batch_alpha: float = 1.0
    # KV-cache bytes one context token keeps resident (2 * kv_heads *
    # head_dim * layers * 2 B for bf16 K+V). Zero means the impl never
    # caches prefixes (tools, encoder models) — the serving engine only
    # builds a prefix ledger for impls that declare a footprint
    # (DESIGN.md §9).
    kv_bytes_per_token: float = 0.0


@functools.lru_cache(maxsize=None)
def _lm_work(arch: str) -> tuple[Callable[[int, int], Work], float]:
    """LLM workload model from a zoo config, as a two-phase ``Work``.

    prefill: flops = 2 * N_active * tokens_in — compute-bound; weights are
             read ~once for the whole (batched) forward, negligible against
             the decode stream below, so no per-item byte charge. (The seed
             model charged 2 * N_active bytes *per prompt token* here —
             contradicting its own "negligible" note and drowning the
             batch-shared decode stream; the roofline split removes it.)
    decode:  flops = 2 * N_active * tokens_out; weights (params_bytes)
             stream once per decode step — ``max(tokens_out, 1)`` steps,
             the floor standing in for the single prefill pass of
             decode-free works — shared across every item co-scheduled in
             a batch. Per-item KV/activation traffic is negligible at
             these context lengths (decode_bytes=0).
    """
    cfg = get_config(arch)
    model = build_model(cfg)
    n_active = model.active_param_count()
    pbytes = model.param_count() * 2.0  # bf16

    def work(tokens_in: int, tokens_out: int) -> Work:
        """Two-phase LLM workload for one (tokens_in, tokens_out) item."""
        return Work.two_phase(
            prefill_flops=2.0 * n_active * tokens_in,
            decode_flops=2.0 * n_active * tokens_out,
            prefill_bytes=0.0,
            decode_bytes=0.0,
            weight_bytes=pbytes,
            decode_steps=max(tokens_out, 1))

    return work, pbytes


def _fixed_work(flops: float, bytes_: float) -> Callable[[int, int], Work]:
    return lambda ti, to: Work(flops=flops, hbm_bytes=bytes_)


# ---------------------------------------------------------------------------
# Library
# ---------------------------------------------------------------------------


class AgentLibrary:
    """Registry of agent interfaces and their implementations."""

    def __init__(self):
        self.interfaces: dict[str, AgentInterface] = {}
        self.impls: dict[str, AgentImpl] = {}

    def register_interface(self, iface: AgentInterface):
        """Add a capability; its artifact types must be registered."""
        ARTIFACTS[iface.produces]             # typo -> registration error
        for c in iface.consumes:
            ARTIFACTS[c]
        self.interfaces[iface.name] = iface

    def register_impl(self, impl: AgentImpl):
        """Add a model/tool implementing a registered interface."""
        if impl.interface not in self.interfaces:
            raise KeyError(f"unknown interface {impl.interface!r}")
        self.impls[impl.name] = impl

    def impls_for(self, interface: str) -> list[AgentImpl]:
        """All registered implementations of one interface."""
        return [i for i in self.impls.values() if i.interface == interface]

    def match_interface(self, text: str) -> str | None:
        """Keyword-match a task description to an interface (rule planner)."""
        low = text.lower()
        best, score = None, 0
        for iface in self.interfaces.values():
            s = sum(len(k) for k in iface.keywords if k in low)
            if s > score:
                best, score = iface.name, s
        return best

    def toolcall(self, interface: str, args: dict) -> str:
        """Render the executable toolcall string (paper §3.2 example)."""
        iface = self.interfaces[interface]
        known = {k: v for k, v in args.items() if k in iface.schema}
        arg_s = ", ".join(f"{k}={v!r}" for k, v in sorted(known.items()))
        return f"{_camel(interface)}({arg_s})"


_TOOLNAMES = {"frame_extract": "FrameExtractor", "speech_to_text":
              "SpeechToText", "object_detect": "ObjectDetector"}


def _camel(s: str) -> str:
    if s in _TOOLNAMES:
        return _TOOLNAMES[s]
    return "".join(p.capitalize() for p in s.split("_"))


# ---------------------------------------------------------------------------
# Default library: the Video-Understanding agents + zoo-backed LLM tiers
# ---------------------------------------------------------------------------


def default_library() -> AgentLibrary:
    """The built-in library: video/RAG/doc-ingest interfaces + zoo tiers."""
    lib = AgentLibrary()

    lib.register_interface(AgentInterface(
        "frame_extract", "Extract frames from video at a sampling rate",
        schema={"file": "str", "start_time": "float", "end_time": "float",
                "num_frames": "int"},
        keywords=("frame", "extract", "sample", "video"),
        produces="frames", consumes=("video",),
        cardinality=CardinalityModel(("scenes",))))
    lib.register_interface(AgentInterface(
        "speech_to_text", "Transcribe speech audio to text",
        schema={"file": "str", "language": "str"},
        keywords=("speech", "transcri", "audio", "text", "stt"),
        produces="transcript", consumes=("video",),
        cardinality=CardinalityModel(("scenes",))))
    lib.register_interface(AgentInterface(
        "object_detect", "Detect/classify objects in images",
        schema={"frames": "list", "labels": "list"},
        keywords=("object", "detect", "classif", "recogni"),
        produces="objects", consumes=("frames",),
        cardinality=CardinalityModel(("scenes",))))
    lib.register_interface(AgentInterface(
        "summarize", "Summarize scenes from frames, objects and transcripts",
        schema={"context": "str", "max_tokens": "int"},
        keywords=("summar", "describe", "caption"),
        produces="summary", consumes=("frames", "objects", "transcript"),
        cardinality=CardinalityModel(("frames",)),
        tokens=TokenModel(tokens_in=900, tokens_out=120)))
    lib.register_interface(AgentInterface(
        "embed", "Embed text into a vector DB for retrieval",
        schema={"texts": "list"},
        keywords=("embed", "vector", "index", "insert"),
        produces="vectors",
        consumes=("summary", "grounded_answer", "chunk_summaries",
                  "chat_reply"),
        cardinality=CardinalityModel(("scenes", "chunks", "queries",
                                      "turns"))))
    lib.register_interface(AgentInterface(
        "qa", "Answer questions over retrieved context",
        schema={"question": "str", "top_k": "int"},
        keywords=("answer", "question", "qa"),
        produces="answer", consumes=("vectors",),
        cardinality=CardinalityModel(("queries", "scenes")),
        tokens=TokenModel(tokens_in=900, tokens_out=120)))

    # ---- retrieval-augmented generation interfaces ----
    lib.register_interface(AgentInterface(
        "retrieve", "Retrieve candidate passages for a query from a corpus",
        schema={"query": "str", "k": "int"},
        keywords=("retriev", "corpus", "search"),
        produces="passages", consumes=("query", "vectors"),
        cardinality=CardinalityModel(("queries",)),
        tokens=TokenModel(tokens_in=64, tokens_out=0)))
    lib.register_interface(AgentInterface(
        "rerank", "Rerank retrieved passages by relevance to the query",
        schema={"passages": "list", "top_k": "int"},
        keywords=("rerank", "relevance"),
        produces="ranked_passages", consumes=("passages",),
        cardinality=CardinalityModel(("passages",)),
        tokens=TokenModel(tokens_in=256, tokens_out=8)))
    lib.register_interface(AgentInterface(
        "synthesize", "Synthesize a grounded answer from ranked passages",
        schema={"query": "str", "max_tokens": "int"},
        keywords=("synthes", "grounded", "compose"),
        produces="grounded_answer", consumes=("ranked_passages", "query"),
        cardinality=CardinalityModel(("queries",)),
        tokens=TokenModel(tokens_in=1200, tokens_out=200)))

    # ---- document-ingest interfaces ----
    lib.register_interface(AgentInterface(
        "parse_doc", "Parse a document and split it into text chunks",
        schema={"file": "str", "chunk_tokens": "int"},
        keywords=("parse", "ingest", "ocr", "pdf", "chunk"),
        produces="text_chunks", consumes=("document",),
        cardinality=CardinalityModel(("pages", "documents"))))
    lib.register_interface(AgentInterface(
        "digest", "Write a digest of each document chunk",
        schema={"chunks": "list", "max_tokens": "int"},
        keywords=("digest", "condense"),
        produces="chunk_summaries", consumes=("text_chunks",),
        cardinality=CardinalityModel(("chunks",)),
        tokens=TokenModel(tokens_in=700, tokens_out=90)))

    # ---- multi-turn chat interface (the stateful-serving scenario) ----
    # the prompt grows with the conversation (in_units adds the history to
    # tokens_in) and that same history is the session-shared prefix a
    # resident KV cache can serve (prefix_units, DESIGN.md §9)
    lib.register_interface(AgentInterface(
        "chat_respond", "Generate the assistant's reply for one chat turn",
        schema={"message": "str", "max_tokens": "int"},
        keywords=("chat", "respond", "reply", "assistant", "converse"),
        produces="chat_reply", consumes=("chat_turn",),
        cardinality=CardinalityModel(("turns",)),
        # tool-calling-agent geometry: a fat prompt (user message plus
        # retrieved/tool context) and a short structured reply, so turn
        # latency is prefill-compute-bound — the regime where a resident
        # session prefix actually moves the roofline (DESIGN.md §9)
        tokens=TokenModel(tokens_in=640, tokens_out=24,
                          in_units="history_tokens",
                          prefix_units="history_tokens")))

    # ---- tools ----
    lib.register_impl(AgentImpl(
        "opencv", "frame_extract", quality=1.0, hw_kinds=("cpu",),
        work_fn=_fixed_work(flops=2.0e9, bytes_=6.0e8),   # per scene
        max_devices={"cpu": 16}, power_frac=1.0, overhead_s=0.5))
    lib.register_impl(AgentImpl(
        "clip", "object_detect", quality=0.90, hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=4.0e11, bytes_=3.0e10),  # per scene (frames)
        max_devices={"cpu": 8, "gpu": 1, "tpu": 1}, power_frac=0.5,
        overhead_s=0.5))

    # ---- STT tiers ----
    lib.register_impl(AgentImpl(
        "whisper-large", "speech_to_text", quality=0.97,
        hw_kinds=("cpu", "gpu", "tpu"),
        # ~60 s of audio per scene; enc-dec forward + decode streaming
        work_fn=_fixed_work(flops=6.0e12, bytes_=2.5e11),
        min_devices={"cpu": 8}, max_devices={"cpu": 64, "gpu": 1, "tpu": 1},
        power_frac=0.55, load_time_s=4.0, params_bytes=3.2e9,
        max_batch=2, batch_alpha=0.5, overhead_s=1.0))
    lib.register_impl(AgentImpl(
        "fast-conformer", "speech_to_text", quality=0.93,
        hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=1.2e12, bytes_=6.0e10),
        min_devices={"cpu": 8}, max_devices={"cpu": 64, "gpu": 1, "tpu": 1},
        power_frac=0.5, load_time_s=2.0, params_bytes=2.3e8))
    stt_work, stt_bytes = _lm_work("seamless-m4t-large-v2")
    lib.register_impl(AgentImpl(
        "seamless-m4t-large-v2", "speech_to_text", quality=0.96,
        hw_kinds=("tpu",), work_fn=lambda ti, to: stt_work(1500, 200),
        max_devices={"tpu": 8}, power_frac=0.6, load_time_s=6.0,
        arch="seamless-m4t-large-v2", params_bytes=stt_bytes,
        max_batch=8, batch_alpha=0.3, overhead_s=0.5))

    # ---- vision tier (zoo) ----
    vlm_work, vlm_bytes = _lm_work("llama-3.2-vision-90b")
    lib.register_impl(AgentImpl(
        "llama-3.2-vision-90b", "object_detect", quality=0.98,
        hw_kinds=("tpu",), work_fn=lambda ti, to: vlm_work(4096, 128),
        min_devices={"tpu": 8}, max_devices={"tpu": 64}, power_frac=0.7,
        load_time_s=30.0, arch="llama-3.2-vision-90b",
        params_bytes=vlm_bytes))

    # ---- summarize / LLM tiers (the model-zoo ladder) ----
    # (quality scores: relative ladder for the scheduler, not benchmarks)
    for arch, quality, hw in [
        ("deepseek-7b", 0.88, ("gpu", "tpu")),
        ("gemma2-9b", 0.90, ("gpu", "tpu")),
        ("stablelm-12b", 0.89, ("gpu", "tpu")),
        ("deepseek-moe-16b", 0.87, ("gpu", "tpu")),
        ("zamba2-7b", 0.86, ("gpu", "tpu")),
        ("command-r-plus-104b", 0.97, ("tpu",)),
        ("kimi-k2-1t-a32b", 0.99, ("tpu",)),
    ]:
        wfn, pbytes = _lm_work(arch)
        big = pbytes > 60e9
        lib.register_impl(AgentImpl(
            arch, "summarize", quality=quality, hw_kinds=hw,
            work_fn=wfn,
            min_devices={"tpu": 8 if big else 1, "gpu": 8 if big else 1},
            max_devices={"tpu": 256, "gpu": 8},
            power_frac=0.65, load_time_s=8.0 if not big else 45.0,
            arch=arch, params_bytes=pbytes, max_batch=128, batch_alpha=0.15,
            overhead_s=0.3))

    # NVLM-class profile from the paper's setup (8xA100 summarize)
    lib.register_impl(AgentImpl(
        "nvlm-72b", "summarize", quality=0.96, hw_kinds=("gpu",),
        work_fn=lambda ti, to: Work.two_phase(
            prefill_flops=2.0 * 72e9 * ti, decode_flops=2.0 * 72e9 * to,
            prefill_bytes=0.0, decode_bytes=0.0,
            weight_bytes=144e9, decode_steps=max(to, 1)),
        min_devices={"gpu": 8}, max_devices={"gpu": 8},
        power_frac=0.55, load_time_s=40.0, params_bytes=144e9,
        max_batch=128, batch_alpha=0.15, overhead_s=0.3))
    lib.register_impl(AgentImpl(
        "nvlm-embed", "embed", quality=1.0, hw_kinds=("gpu", "tpu"),
        work_fn=_fixed_work(flops=1.5e12, bytes_=1.5e11),
        min_devices={"gpu": 2}, max_devices={"gpu": 2, "tpu": 2},
        power_frac=0.45, load_time_s=20.0, overhead_s=0.5,
        max_batch=8, batch_alpha=0.3))

    lib.register_impl(AgentImpl(
        "minilm-embed", "embed", quality=0.88, hw_kinds=("cpu",),
        work_fn=_fixed_work(flops=2.0e10, bytes_=2.0e9),
        max_devices={"cpu": 8}, power_frac=0.8, load_time_s=1.0,
        overhead_s=0.3, max_batch=8, batch_alpha=0.4))

    # ---- qa tiers (zoo) ----
    for arch, quality in [("command-r-plus-104b", 0.97),
                          ("kimi-k2-1t-a32b", 0.99),
                          ("deepseek-7b", 0.85)]:
        wfn, pbytes = _lm_work(arch)
        big = pbytes > 60e9
        lib.register_impl(AgentImpl(
            f"{arch}-qa", "qa", quality=quality, hw_kinds=("tpu",),
            work_fn=wfn, min_devices={"tpu": 8 if big else 1},
            max_devices={"tpu": 256}, power_frac=0.65,
            load_time_s=45.0 if big else 8.0, arch=arch,
            params_bytes=pbytes, max_batch=16, batch_alpha=0.15,
            overhead_s=0.3))

    # draft/cheap tier: attention-free SSM
    mwork, mbytes = _lm_work("mamba2-370m")
    lib.register_impl(AgentImpl(
        "mamba2-370m-draft", "summarize", quality=0.55,
        hw_kinds=("cpu", "gpu", "tpu"), work_fn=mwork,
        max_devices={"cpu": 16, "gpu": 1, "tpu": 1}, power_frac=0.4,
        load_time_s=1.0, arch="mamba2-370m", params_bytes=mbytes,
        overhead_s=0.2))

    # ---- retrieval tiers: the keyword-vs-vector routing lever ----
    # (beyond-vector-search: lexical BM25 is orders of magnitude cheaper and
    #  often good enough; dense/hybrid retrieval buys recall with compute)
    lib.register_impl(AgentImpl(
        "bm25-keyword", "retrieve", quality=0.82, hw_kinds=("cpu",),
        work_fn=_fixed_work(flops=5.0e9, bytes_=2.0e9),
        max_devices={"cpu": 8}, power_frac=0.9, overhead_s=0.1))
    lib.register_impl(AgentImpl(
        "dense-retrieval", "retrieve", quality=0.92,
        hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=2.0e11, bytes_=2.0e10),
        max_devices={"cpu": 16, "gpu": 1, "tpu": 1}, power_frac=0.5,
        load_time_s=2.0, params_bytes=4.0e8, max_batch=16, batch_alpha=0.4,
        overhead_s=0.2))
    lib.register_impl(AgentImpl(
        "hybrid-retrieval", "retrieve", quality=0.97,
        hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=3.0e11, bytes_=3.2e10),
        max_devices={"cpu": 16, "gpu": 1, "tpu": 1}, power_frac=0.55,
        load_time_s=2.0, params_bytes=4.0e8, max_batch=16, batch_alpha=0.4,
        overhead_s=0.3))

    # ---- rerank tiers ----
    lib.register_impl(AgentImpl(
        "minilm-cross-encoder", "rerank", quality=0.90,
        hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=4.0e10, bytes_=4.0e9),
        max_devices={"cpu": 16, "gpu": 1, "tpu": 1}, power_frac=0.6,
        load_time_s=1.0, params_bytes=1.3e8, max_batch=32, batch_alpha=0.3,
        overhead_s=0.1))
    rr_work, rr_bytes = _lm_work("gemma2-9b")
    lib.register_impl(AgentImpl(
        "gemma2-9b-rerank", "rerank", quality=0.97, hw_kinds=("gpu", "tpu"),
        work_fn=rr_work, max_devices={"gpu": 8, "tpu": 8}, power_frac=0.65,
        load_time_s=8.0, arch="gemma2-9b", params_bytes=rr_bytes,
        max_batch=64, batch_alpha=0.2, overhead_s=0.2))

    # ---- synthesis tiers (zoo ladder over the synthesize interface) ----
    for arch, quality, hw in [
        ("deepseek-7b", 0.86, ("gpu", "tpu")),
        ("gemma2-9b", 0.90, ("gpu", "tpu")),
        ("command-r-plus-104b", 0.97, ("tpu",)),
    ]:
        wfn, pbytes = _lm_work(arch)
        big = pbytes > 60e9
        lib.register_impl(AgentImpl(
            f"{arch}-synth", "synthesize", quality=quality, hw_kinds=hw,
            work_fn=wfn,
            min_devices={"tpu": 8 if big else 1, "gpu": 8 if big else 1},
            max_devices={"tpu": 64, "gpu": 8}, power_frac=0.65,
            load_time_s=45.0 if big else 8.0, arch=arch, params_bytes=pbytes,
            max_batch=32, batch_alpha=0.15, overhead_s=0.3))

    # ---- document parsing tiers ----
    lib.register_impl(AgentImpl(
        "pypdf-parse", "parse_doc", quality=0.90, hw_kinds=("cpu",),
        work_fn=_fixed_work(flops=1.0e9, bytes_=5.0e8),    # per page
        max_devices={"cpu": 16}, power_frac=1.0, overhead_s=0.2))
    lib.register_impl(AgentImpl(
        "layout-ocr", "parse_doc", quality=0.98,
        hw_kinds=("cpu", "gpu", "tpu"),
        work_fn=_fixed_work(flops=8.0e11, bytes_=6.0e10),
        max_devices={"cpu": 16, "gpu": 1, "tpu": 1}, power_frac=0.55,
        load_time_s=3.0, params_bytes=9.0e8, max_batch=8, batch_alpha=0.4,
        overhead_s=0.3))

    # ---- digest tiers (batch summarization over chunks) ----
    for arch, quality in [("deepseek-7b", 0.87), ("gemma2-9b", 0.90),
                          ("stablelm-12b", 0.88),
                          ("command-r-plus-104b", 0.97)]:
        wfn, pbytes = _lm_work(arch)
        big = pbytes > 60e9
        lib.register_impl(AgentImpl(
            f"{arch}-digest", "digest", quality=quality,
            hw_kinds=("tpu",) if big else ("gpu", "tpu"), work_fn=wfn,
            min_devices={"tpu": 8 if big else 1, "gpu": 8 if big else 1},
            max_devices={"tpu": 64, "gpu": 8}, power_frac=0.65,
            load_time_s=45.0 if big else 8.0, arch=arch, params_bytes=pbytes,
            max_batch=64, batch_alpha=0.15, overhead_s=0.3))

    # ---- chat tiers (zoo ladder with declared KV footprints) ----
    # kv_bytes_per_token ~ 2 (K+V) * 2 B (bf16) * layers * kv_heads *
    # head_dim (GQA keeps it ~1e5 for the small tiers); min 2 devices so
    # weights + a useful prefix budget fit the smallest SKU. overhead_s is
    # low: these run in a high-QPS serving stack, not a batch harness.
    for arch, quality, hw, kvb in [
        ("deepseek-7b", 0.86, ("gpu", "tpu"), 1.3e5),
        ("gemma2-9b", 0.90, ("gpu", "tpu"), 1.7e5),
        ("command-r-plus-104b", 0.97, ("tpu",), 4.1e5),
    ]:
        wfn, pbytes = _lm_work(arch)
        big = pbytes > 60e9
        lib.register_impl(AgentImpl(
            f"{arch}-chat", "chat_respond", quality=quality, hw_kinds=hw,
            work_fn=wfn,
            min_devices={"tpu": 8 if big else 2, "gpu": 8 if big else 2},
            max_devices={"tpu": 64, "gpu": 8}, power_frac=0.65,
            load_time_s=45.0 if big else 8.0, arch=arch, params_bytes=pbytes,
            kv_bytes_per_token=kvb, max_batch=32, batch_alpha=0.15,
            overhead_s=0.05))
    return lib
