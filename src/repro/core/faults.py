"""Seeded fault injection + recovery policy for the serving engine.

DESIGN.md §10.  A :class:`FaultProfile` describes *what goes wrong* — per-pool
exponential MTBF instance crashes, per-task transient failure probability,
straggler slowdowns — and a :class:`RetryPolicy` describes *how the engine
recovers* — per-tenant-class attempt budgets, exponential backoff with seeded
jitter, and dead-letter accounting once a workflow exhausts its budget.

Everything is a pure function of ``(profile.seed, identity)`` so fault runs
replay byte-identically:

* per-task draws come from a dedicated ``random.Random`` keyed by
  ``(seed, workflow, task, attempt)`` — independent of dispatch order;
* per-pool crash processes come from ``pool_stream(pool)``, a fresh generator
  per run whose event times depend only on the seed.

``random.Random(str)`` hashes the seed string with SHA-512, so streams are
stable across processes and Python versions (no ``PYTHONHASHSEED`` exposure).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Mapping

from ..runtime.fault_tolerance import DEFAULT_STRAGGLER_THRESHOLD

#: Default per-tenant-class attempt budgets: priority work is retried hardest,
#: harvest work is cheapest to abandon.
DEFAULT_MAX_ATTEMPTS: Mapping[str, int] = MappingProxyType(
    {"priority": 4, "standard": 3, "harvest": 2})


@dataclass(frozen=True)
class RetryPolicy:
    """How failed tasks are retried, backed off, and eventually abandoned."""

    #: tenant class -> max execution attempts (first run counts as one).
    max_attempts: Mapping[str, int] = \
        field(default_factory=lambda: DEFAULT_MAX_ATTEMPTS)
    #: attempts a tenant class not listed in ``max_attempts`` gets.
    default_attempts: int = 3
    backoff_base_s: float = 2.0     # delay after the first failure
    backoff_mult: float = 2.0       # exponential growth per failure
    backoff_cap_s: float = 60.0     # ceiling on any single delay
    jitter_frac: float = 0.25       # +-fraction of seeded jitter on the delay
    #: failures of one task before the workflow is replanned against the
    #: (degraded) live cluster; 0 disables degradation replanning.
    replan_after: int = 2

    def attempts_for(self, tenant: str) -> int:
        """Max execution attempts for ``tenant`` (always at least one)."""
        return max(int(self.max_attempts.get(tenant, self.default_attempts)),
                   1)

    def backoff_s(self, fails: int, u: float) -> float:
        """Delay before retry number ``fails`` (>=1); ``u`` in [0,1) jitters."""
        base = min(self.backoff_base_s * self.backoff_mult ** (fails - 1),
                   self.backoff_cap_s)
        return base * (1.0 + self.jitter_frac * (2.0 * u - 1.0))


@dataclass(frozen=True)
class FaultProfile:
    """Seeded description of cluster failures injected into a run.

    With an instantiated-but-empty profile (no MTBF entries, zero
    probabilities) the engine's event heap, float-op order, traces, and
    ledgers are byte-identical to ``faults=None``.
    """

    seed: int = 0
    #: pool name -> mean time between instance crashes (s); absent pools
    #: never crash.
    instance_mtbf_s: Mapping[str, float] = field(default_factory=dict)
    #: mean time to restore a crashed device group's capacity (s).
    repair_s: float = 300.0
    #: probability any one task attempt fails mid-compute.
    task_fail_p: float = 0.0
    #: probability any one task attempt runs slow by ``straggler_mult``.
    straggler_p: float = 0.0
    straggler_mult: float = 4.0
    #: launch a duplicate attempt for detected stragglers (first wins).
    hedge: bool = True
    #: a task is a straggler when its slowdown vs the CostQuery estimate
    #: reaches this factor — same definition as runtime.StragglerMonitor.
    hedge_threshold: float = DEFAULT_STRAGGLER_THRESHOLD
    retry: RetryPolicy = field(default_factory=RetryPolicy)

    def __post_init__(self):
        for pool, mtbf in self.instance_mtbf_s.items():
            if mtbf <= 0:
                raise ValueError(f"MTBF for pool {pool!r} must be > 0")
        if self.instance_mtbf_s and self.repair_s <= 0:
            raise ValueError("repair_s must be > 0 when crashes are enabled "
                             "(permanent capacity loss can wedge a run)")
        if not 0.0 <= self.task_fail_p <= 1.0:
            raise ValueError("task_fail_p must be in [0, 1]")
        if not 0.0 <= self.straggler_p <= 1.0:
            raise ValueError("straggler_p must be in [0, 1]")
        if self.straggler_p and self.straggler_mult <= 1.0:
            raise ValueError("straggler_mult must be > 1")
        if self.hedge_threshold <= 1.0:
            raise ValueError("hedge_threshold must be > 1")

    # -- seeded streams ------------------------------------------------------

    def task_draws(self, wid: str, tid: str,
                   attempt: int) -> tuple[float, float, float]:
        """(u_fail, u_frac, u_straggle) for one task attempt.

        All three are always drawn so a profile change (say, enabling
        stragglers) never perturbs the failure stream.
        """
        rng = random.Random(f"{self.seed}:task:{wid}:{tid}:{attempt}")
        return rng.random(), rng.random(), rng.random()

    def retry_jitter(self, wid: str, tid: str, fails: int) -> float:
        """Seeded u in [0, 1) jittering the backoff after failure ``fails``."""
        return random.Random(
            f"{self.seed}:retry:{wid}:{tid}:{fails}").random()

    def pool_stream(self, pool: str) -> random.Random:
        """Fresh per-run crash-process generator for ``pool``."""
        return random.Random(f"{self.seed}:pool:{pool}")

    def validate_pools(self, pools) -> None:
        """Raise if ``instance_mtbf_s`` names a pool the cluster lacks."""
        unknown = sorted(set(self.instance_mtbf_s) - set(pools))
        if unknown:
            raise ValueError(f"FaultProfile names unknown pools: {unknown}")
