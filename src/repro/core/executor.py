"""Real executor: run a workflow DAG as actual JAX computation on local devices.

The simulator proves scheduling at cluster scale; this module proves the
*plumbing* end-to-end — every agent invocation is a real JAX program over
real arrays, using the model zoo's reduced configs on CPU:

  frame_extract   strided frame sampling (jnp slicing/pooling)
  speech_to_text  seamless-m4t (reduced) enc-dec generate over audio features
  object_detect   CLIP-style dual-encoder cosine scoring of frames vs labels
  summarize       zoo LM (reduced) prefill+decode over a context prompt
  embed           mean-pooled embedding-table vectors into an in-memory DB
  qa              nearest-vector retrieval + LM generate

Outputs flow along the DAG's dataflow edges, so a mis-wired dependency fails
loudly (missing input type), and the Murakkab/baseline paths can be compared
for *output equality* (same seeds -> same tokens), mirroring the paper's
"execution output and accuracy are the same in all comparisons".
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config
from ..models.model_zoo import build_model
from ..runtime.serve import ServeSession, ServeOptions
from .agents import AgentLibrary
from .dag import DAG
from .scheduler import ExecutionPlan


@dataclass
class Media:
    """Synthetic decoded video: frames + audio features per scene."""

    name: str
    frames: jax.Array          # (scenes, fps, 32, 32, 3) uint8-ish floats
    audio: jax.Array           # (scenes, T, d_audio) float32

    @classmethod
    def synthesize(cls, name: str, scenes: int = 4, fps: int = 10,
                   seed: int = 0) -> "Media":
        """Deterministic random media standing in for a decoded video."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        frames = jax.random.uniform(k1, (scenes, fps, 32, 32, 3))
        audio = jax.random.normal(k2, (scenes, 64, 80))
        return cls(name, frames, audio)


_LABELS = ["cat", "car", "tree", "person", "dog", "road", "sky", "wheel",
           "helmet", "grass", "sign", "flag", "track", "ball", "house",
           "water"]


class RealExecutor:
    """Executes DAG nodes with real reduced-config JAX models."""

    def __init__(self, library: AgentLibrary, seed: int = 0,
                 default_arch: str = "deepseek-7b"):
        self.library = library
        self.seed = seed
        self.default_arch = default_arch
        self._sessions: dict[str, ServeSession] = {}
        self._vector_db: list[tuple[np.ndarray, jax.Array]] = []

    # -- model sessions ----------------------------------------------------------
    def session(self, arch: str) -> ServeSession:
        """Lazily-built serving session for one reduced zoo config."""
        if arch not in self._sessions:
            cfg = get_config(arch, reduced=True)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(self.seed))
            self._sessions[arch] = ServeSession(model, params,
                                                opts=ServeOptions())
        return self._sessions[arch]

    # -- agent implementations -----------------------------------------------------
    def frame_extract(self, media: list[Media], args: dict) -> jax.Array:
        """Strided frame sampling over all scenes."""
        stride = max(int(args.get("sampling_rate", 15)) // 15, 1)
        out = jnp.concatenate([m.frames[:, ::stride] for m in media], 0)
        return out                                  # (scenes, fps', 32, 32, 3)

    def speech_to_text(self, media: list[Media], arch: str | None) \
            -> jax.Array:
        """Transcribe audio features with a (reduced) enc-dec or LM."""
        arch = arch or "seamless-m4t-large-v2"
        sess = self.session(arch)
        cfg = sess.model.cfg
        audio = jnp.concatenate([m.audio for m in media], 0)  # (S, T, 80)
        B, T, _ = audio.shape
        if cfg.family == "encdec":
            # project audio features to d_model "frames" (stub frontend)
            d = cfg.d_model
            reps = -(-d // audio.shape[-1])
            frames = jnp.tile(audio, (1, 1, reps))[..., :d].astype(jnp.bfloat16)
            bos = jnp.zeros((B, 1), jnp.int32)
            toks = sess.generate(bos, max_new_tokens=8,
                                 extras={"frames": frames})
        else:
            bos = (jnp.abs(audio[:, 0, :8]) * 100).astype(jnp.int32) % \
                sess.model.cfg.vocab_size
            toks = sess.generate(bos, max_new_tokens=8)
        return toks                                 # (scenes, 8) transcript ids

    def object_detect(self, frames: jax.Array, arch: str | None) -> jax.Array:
        """CLIP-style: random-projection image/text encoders, cosine top-1."""
        S, F = frames.shape[:2]
        key = jax.random.PRNGKey(self.seed + 1)
        k_img, k_txt = jax.random.split(key)
        d = 64
        img_proj = jax.random.normal(k_img, (32 * 32 * 3, d)) / 55.4
        txt_emb = jax.random.normal(k_txt, (len(_LABELS), d))
        img = frames.reshape(S, F, -1) @ img_proj                  # (S,F,d)
        img = img / jnp.linalg.norm(img, axis=-1, keepdims=True)
        txt = txt_emb / jnp.linalg.norm(txt_emb, axis=-1, keepdims=True)
        scores = jnp.einsum("sfd,ld->sfl", img, txt)
        return jnp.argmax(scores, -1)               # (scenes, frames) label ids

    def summarize(self, frames, objects, transcript, arch: str | None) \
            -> jax.Array:
        """LM generate over a deterministic per-scene context prompt."""
        arch = arch or self.default_arch
        sess = self.session(arch)
        V = sess.model.cfg.vocab_size
        S = objects.shape[0]
        # build a deterministic "prompt" per scene from the gathered context
        ctx = jnp.concatenate([
            objects[:, :8].astype(jnp.int32) % V,
            transcript[:, :8].astype(jnp.int32) % V,
            (jnp.mean(frames.reshape(S, -1), -1, keepdims=True) * 1000
             ).astype(jnp.int32) % V,
        ], axis=1)
        return sess.generate(ctx, max_new_tokens=8)  # (scenes, 8) summaries

    def embed(self, summaries: jax.Array, arch: str | None) -> jax.Array:
        """Mean-pooled embedding vectors, inserted into the in-memory DB."""
        arch = arch or self.default_arch
        sess = self.session(arch)
        emb = sess.params["embed"]                   # (V, d)
        vecs = jnp.take(emb, summaries % emb.shape[0], axis=0).mean(1)
        for i in range(vecs.shape[0]):
            self._vector_db.append((np.asarray(vecs[i], np.float32),
                                    summaries[i]))
        return vecs                                  # (scenes, d)

    def qa(self, vectors: jax.Array, question: str, arch: str | None) \
            -> jax.Array:
        """Nearest-vector retrieval + LM generate over the question."""
        arch = arch or self.default_arch
        sess = self.session(arch)
        V = sess.model.cfg.vocab_size
        q = jnp.asarray([ord(c) % V for c in question[:16]], jnp.int32)[None]
        if self._vector_db:
            qv = np.asarray(jnp.take(sess.params["embed"], q[0],
                                     axis=0).mean(0), np.float32)
            sims = [float(qv @ v) for v, _ in self._vector_db]
            best = self._vector_db[int(np.argmax(sims))][1][None]
            q = jnp.concatenate([q, best.astype(jnp.int32) % V], 1)
        return sess.generate(q, max_new_tokens=8)

    # -- DAG walk -----------------------------------------------------------------
    def run(self, dag: DAG, plan: ExecutionPlan | None, media: list[Media],
            question: str = "") -> dict:
        """Execute in topological order; returns {task_id: output} + timings."""
        outputs: dict[str, object] = {}
        by_type: dict[str, object] = {}
        timings: dict[str, float] = {}
        for tid in dag.topo_order:
            node = dag.nodes[tid]
            impl_name = plan[tid].impl if plan else None
            arch = (self.library.impls[impl_name].arch
                    if impl_name and impl_name in self.library.impls else None)
            t0 = time.perf_counter()
            if node.agent == "frame_extract":
                out = self.frame_extract(media, node.args)
            elif node.agent == "speech_to_text":
                out = self.speech_to_text(media, arch)
            elif node.agent == "object_detect":
                out = self.object_detect(by_type["frames"], arch)
            elif node.agent == "summarize":
                out = self.summarize(by_type["frames"], by_type["objects"],
                                     by_type["transcript"], arch)
            elif node.agent == "embed":
                out = self.embed(by_type["summary"], arch)
            elif node.agent == "qa":
                out = self.qa(by_type.get("vectors"), question or
                              node.args.get("question", ""), arch)
            else:
                raise ValueError(f"real executor: unknown agent {node.agent}")
            jax.block_until_ready(out)
            timings[tid] = time.perf_counter() - t0
            outputs[tid] = out
            by_type[self.library.interfaces[node.agent].produces] = out
        outputs["_timings"] = timings
        return outputs
