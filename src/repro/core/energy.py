"""Hardware SKU catalog + energy / $-cost accounting.

The paper's levers (Table 1) are grounded in hardware numbers. Two catalogs:

- ``PAPER_HW``  — the A100/EPYC cluster of the paper's evaluation (§4), used
  by the Fig-3 / Table-2 reproduction benchmarks. Power model follows the
  paper's simplification: *only GPU energy is measured* (CPU rated 16x lower).
- ``TPU_HW``    — the deployment target: TPU v5e/v5p/v4 pools + CPU hosts.
  The per-chip constants are the same ones EXPERIMENTS.md §Roofline uses
  (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI for v5e), so the
  scheduler's cost model and the roofline analysis share one source of truth.

Energy model (per device): ``P(t) = idle_w + util(t) * (active_w - idle_w)``.
Idle power is integrated over the full makespan for every device in a
*metered* pool (matching how the paper's 155 Wh baseline includes idle GPUs);
active increments accrue only while a task runs on the device.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class DeviceSpec:
    """One hardware SKU."""

    name: str
    kind: str                 # "gpu" | "cpu" | "tpu"
    peak_flops: float         # FLOP/s (bf16 for accelerators, fp32 for CPU)
    hbm_bw: float             # bytes/s
    hbm_bytes: float          # capacity
    link_bw: float            # bytes/s per interconnect link (ICI / NVLink)
    idle_w: float
    active_w: float
    usd_per_hour: float
    metered: bool = True      # counted in the energy report?
    generation: int = 0       # newer = larger (the GPU-generation lever)


# --- the paper's cluster (2x Standard_ND96amsr_A100_v4) ---------------------
A100 = DeviceSpec("a100-80g", "gpu", peak_flops=312e12, hbm_bw=2.039e12,
                  hbm_bytes=80e9, link_bw=300e9, idle_w=88.0, active_w=400.0,
                  usd_per_hour=3.40, generation=8)
H100 = DeviceSpec("h100-80g", "gpu", peak_flops=989e12, hbm_bw=3.35e12,
                  hbm_bytes=80e9, link_bw=450e9, idle_w=110.0, active_w=700.0,
                  usd_per_hour=6.98, generation=9)
EPYC_CORE = DeviceSpec("epyc-7v12-core", "cpu", peak_flops=70e9,
                       hbm_bw=3.4e9, hbm_bytes=4e9, link_bw=0.0,
                       # paper: GPU rated ~16x higher than the (whole) CPU;
                       # per-core share of a 240 W socket over 48 cores.
                       # $-rate: marginal cost of idle cores on the already-
                       # provisioned ND96amsr VM (paper Table 1: CPU = lower $)
                       idle_w=1.5, active_w=3.5, usd_per_hour=0.008,
                       metered=False, generation=7)

# --- TPU deployment target ---------------------------------------------------
TPU_V5E = DeviceSpec("tpu-v5e", "tpu", peak_flops=197e12, hbm_bw=819e9,
                     hbm_bytes=16e9, link_bw=50e9, idle_w=65.0,
                     active_w=220.0, usd_per_hour=1.20, generation=9)
TPU_V5P = DeviceSpec("tpu-v5p", "tpu", peak_flops=459e12, hbm_bw=2.765e12,
                     hbm_bytes=95e9, link_bw=100e9, idle_w=120.0,
                     active_w=450.0, usd_per_hour=4.20, generation=10)
TPU_V4 = DeviceSpec("tpu-v4", "tpu", peak_flops=275e12, hbm_bw=1.228e12,
                    hbm_bytes=32e9, link_bw=50e9, idle_w=90.0,
                    active_w=300.0, usd_per_hour=2.10, generation=8)
HOST_CORE = DeviceSpec("host-core", "cpu", peak_flops=80e9, hbm_bw=4e9,
                       hbm_bytes=4e9, link_bw=0.0, idle_w=1.5, active_w=3.5,
                       usd_per_hour=0.008, metered=False, generation=8)

CATALOG: dict[str, DeviceSpec] = {
    d.name: d for d in (A100, H100, EPYC_CORE, TPU_V5E, TPU_V5P, TPU_V4,
                        HOST_CORE)
}


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


@dataclass
class EnergyLedger:
    """Integrates energy/cost over a run; fed by the simulator/executor.

    ``charge_active`` accrues the *increment above idle* for device-seconds
    of real work; ``finalize(makespan, pool_sizes)`` adds the idle floor for
    every metered device over the whole run (paper Table-2 semantics).
    """

    active_joules: float = 0.0
    idle_joules: float = 0.0
    usd: float = 0.0
    per_pool_active: dict[str, float] = field(default_factory=dict)

    def charge_active(self, spec: DeviceSpec, device_seconds: float,
                      utilization: float = 1.0, pool: str = ""):
        """Accrue the above-idle energy (and $) for real device-seconds."""
        if spec.metered:
            j = device_seconds * utilization * (spec.active_w - spec.idle_w)
            self.active_joules += j
            if pool:
                self.per_pool_active[pool] = \
                    self.per_pool_active.get(pool, 0.0) + j
        self.usd += device_seconds / 3600.0 * spec.usd_per_hour

    def charge_idle(self, spec: DeviceSpec, n_devices: int, seconds: float):
        """Integrate the idle-power floor for ``n_devices`` over a period."""
        if spec.metered:
            self.idle_joules += n_devices * seconds * spec.idle_w

    @property
    def joules(self) -> float:
        """Total energy: active increments plus the idle floor."""
        return self.active_joules + self.idle_joules

    @property
    def wh(self) -> float:
        """Total energy in watt-hours (the paper's Table-2 unit)."""
        return self.joules / 3600.0


def roofline_latency(flops: float, bytes_moved: float, spec: DeviceSpec,
                     n_devices: int = 1, collective_bytes: float = 0.0,
                     efficiency: float = 0.6) -> float:
    """Three-term roofline time (the scheduler's latency model).

    Identical structure to EXPERIMENTS.md §Roofline:
        compute   = flops / (n * peak * eff)
        memory    = bytes / (n * hbm_bw)
        collective= coll_bytes / (n * link_bw)
    Latency = max of the three (bound by the dominant term).
    """
    n = max(n_devices, 1)
    t_c = flops / (n * spec.peak_flops * efficiency)
    t_m = bytes_moved / (n * spec.hbm_bw)
    t_x = (collective_bytes / (n * spec.link_bw)) if spec.link_bw else 0.0
    return max(t_c, t_m, t_x)


def batch_roofline_latency(work, spec: DeviceSpec, n_devices: int = 1,
                           batch: int = 1, efficiency: float = 0.6) -> float:
    """Per-item latency of one step over a batch of ``batch`` items.

    The batch-aware extension of :func:`roofline_latency` (DESIGN.md §7):
    the ``work``'s prefill/decode phase split decides which HBM traffic
    amortizes across the batch. Weights stream once per decode step (and
    once for prefill) *regardless* of batch size — ``work.shared_bytes`` —
    while per-item activation/KV traffic scales with ``batch``:

        compute(b) = b * flops / (n * peak * eff)
        memory(b)  = (shared_bytes + b * per_item_bytes) / (n * hbm_bw)
        coll(b)    = b * coll_bytes / (n * link_bw)
        per_item   = max(compute, memory, coll) / b

    Small ``b``: weights-streaming-bound, per-item latency falls ~1/b.
    Past the knee (:func:`batch_knee`): compute-bound, per-item flattens.
    At ``batch=1`` this is exactly the seed roofline (memory(1) =
    hbm_bytes / (n * hbm_bw)), so unbatched estimates are unchanged.
    """
    n = max(n_devices, 1)
    b = max(batch, 1)
    t_c = b * work.flops / (n * spec.peak_flops * efficiency)
    t_m = (work.shared_bytes + b * work.per_item_bytes) / (n * spec.hbm_bw)
    t_x = (b * work.coll_bytes / (n * spec.link_bw)) if spec.link_bw else 0.0
    return max(t_c, t_m, t_x) / b


def batch_knee(work, spec: DeviceSpec, n_devices: int = 1,
               efficiency: float = 0.6) -> float:
    """Batch size where the weights stream stops dominating compute.

    Solves ``compute(b) = memory(b)`` of :func:`batch_roofline_latency` for
    ``b``: below the knee a batched step is bound by the shared weights
    stream (batching is nearly free), above it by compute (batching only
    adds latency). ``inf`` when the work never becomes compute-bound
    (per-item memory traffic alone outweighs compute — batching always
    pays); 1.0 when it is compute-bound already at ``b=1``.
    """
    n = max(n_devices, 1)
    c = work.flops / (n * spec.peak_flops * efficiency)     # compute / item
    p = work.per_item_bytes / (n * spec.hbm_bw)             # memory / item
    s = work.shared_bytes / (n * spec.hbm_bw)               # shared stream
    if c <= p:
        return math.inf
    return max(s / (c - p), 1.0)


def _largest_divisor_in(items: int, lo: int, hi: int) -> int | None:
    """Largest divisor of ``items`` inside ``[lo, hi]``, or None."""
    best = None
    i = 1
    while i * i <= items:
        if items % i == 0:
            for d in (i, items // i):
                if lo <= d <= hi and (best is None or d > best):
                    best = d
        i += 1
    return best


def knee_batch_grid(work, spec: DeviceSpec, items: int, max_batch: int,
                    efficiency: float = 0.6) -> list[int]:
    """Candidate batch sizes for the joint (count x batch) lever search.

    The full batch range is too wide to scan per (impl, pool, count), but
    the roofline's shape pins where the optimum can sit (DESIGN.md §7.2):

    - ``1`` — the unbatched baseline;
    - ``min(max_batch, items)`` — the largest feasible batch, optimal
      whenever per-item latency keeps falling (below the knee) or the
      remainder lands at/past the knee;
    - ``floor/ceil`` of :func:`batch_knee` — the smallest batches that
      already run compute-bound (same per-item latency as larger ones,
      smaller co-residency);
    - the largest divisor of ``items`` in ``[knee, max]`` — a zero-remainder
      schedule whose every step is past the knee. When ``max_batch`` does
      not divide ``items`` and the remainder ``items % max_batch`` falls
      below the knee, that remainder step runs weights-streaming-bound and
      this divisor strictly beats the max batch.

    The knee is independent of the device count (compute, per-item and
    shared-stream terms all scale 1/n), so one grid serves every count in
    the joint search. Works without a phase split have no knee — the
    deprecated ``batch ** alpha`` power law is monotone, so its optimum is
    an endpoint and the grid is just ``{1, min(max_batch, items)}``.
    """
    items = max(int(items), 1)
    bmax = max(min(max_batch, items), 1)
    if bmax == 1:
        return [1]
    cands = {1, bmax}
    if work.has_phases:
        knee = batch_knee(work, spec, 1, efficiency)
        if math.isfinite(knee):
            lo = min(max(int(math.floor(knee)), 1), bmax)
            hi = min(max(int(math.ceil(knee)), 1), bmax)
            cands.update((lo, hi))
            d = _largest_divisor_in(items, hi, bmax)
            if d is not None:
                cands.add(d)
    return sorted(cands)
