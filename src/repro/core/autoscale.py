"""Pool autoscaling policy for the open-loop serving engine (DESIGN.md §8).

The paper's cluster-manager collaboration includes elastic pool sizing:
capacity should follow offered load, because every provisioned-but-idle
device pays the idle-power floor (``EnergyLedger.charge_idle``) for the
whole run. The :class:`Autoscaler` is a *policy* object — the simulator
consults it on periodic ``scale`` events and applies its decisions through
``ClusterManager.set_capacity``, which clamps at live allocations (pinned
demand) and logs the change on the capacity timeline the idle-energy
integral reads.

Policy math (per pool, at each tick):

    desired = ceil(demand / target_util)        # demand = held + queued
    desired = clamp(desired, min_devices, max_devices)
    desired = max(desired, used)                # never below pinned demand

- **Scale-up** is issued with ``scale_up_lag_s`` of provisioning delay
  (the engine applies it as a lagged event), and at most one scale-up is
  in flight per pool.
- **Scale-down** applies immediately but only after ``cooldown_s`` since
  the pool's last capacity change (hysteresis: a burst that just ended
  doesn't thrash capacity down before the next one).
- **Scale-to-zero** (``min_devices == 0``) is only legal for harvestable
  pools — reserved/priority pools must keep warm capacity; ``validate``
  rejects anything else.

Under fault injection (DESIGN.md §10) the same target-utilization law
doubles as crash backfill: an instance crash shrinks the pool via
``set_capacity``, demand per device rises, and the next tick scales the
pool back toward its policy envelope without any fault-specific wiring —
whichever of the autoscaler or the seeded repair event fires first
restores capacity (both are clamped to the pool limit).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .cluster import ClusterManager


@dataclass(frozen=True)
class PoolPolicy:
    """Autoscaling envelope + dynamics for one pool."""

    min_devices: int
    max_devices: int
    target_util: float = 0.75     # size so demand / capacity ≈ this
    scale_up_lag_s: float = 30.0  # provisioning delay for added capacity
    cooldown_s: float = 60.0      # min gap between a change and a shrink

    def __post_init__(self):
        if not 0 <= self.min_devices <= self.max_devices:
            raise ValueError(f"need 0 <= min <= max, got "
                             f"[{self.min_devices}, {self.max_devices}]")
        if not 0 < self.target_util <= 1.0:
            raise ValueError(f"target_util in (0, 1], got "
                             f"{self.target_util}")
        if self.scale_up_lag_s < 0 or self.cooldown_s < 0:
            raise ValueError("lag/cooldown must be >= 0")


@dataclass(frozen=True)
class ScaleAction:
    """One decided resize; ``lag_s > 0`` means apply after that delay."""

    pool: str
    capacity: int
    lag_s: float = 0.0


class Autoscaler:
    """Target-utilization pool sizing with lag + cooldown hysteresis."""

    def __init__(self, policies: dict[str, PoolPolicy],
                 interval_s: float = 15.0):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.policies = dict(policies)
        self.interval_s = interval_s
        self._last_change: dict[str, float] = {}
        self._pending_up: dict[str, float] = {}   # pool -> apply time

    def limits(self) -> dict[str, int]:
        """Per-pool max capacity (the engine's degrade-vs-wait boundary)."""
        return {pool: pol.max_devices for pool, pol in self.policies.items()}

    def validate(self, cluster: ClusterManager):
        """Reject policies that reference unknown pools or scale a
        non-harvestable pool to zero (reserved capacity must stay warm)."""
        for pool, pol in self.policies.items():
            p = cluster.pools.get(pool)
            if p is None:
                raise ValueError(f"autoscale policy for unknown pool "
                                 f"{pool!r}")
            if pol.min_devices == 0 and not p.harvestable:
                raise ValueError(
                    f"scale-to-zero on non-harvestable pool {pool!r}: "
                    f"only harvest capacity may drop its warm floor")

    def decide(self, cluster: ClusterManager, demand: dict[str, int],
               t: float) -> list[ScaleAction]:
        """Resize decisions for this tick; the caller applies/schedules."""
        actions: list[ScaleAction] = []
        for pool, pol in self.policies.items():
            cap = cluster.pools[pool].capacity
            used = cluster._used[pool]
            want = demand.get(pool, used)
            desired = math.ceil(want / pol.target_util) if want > 0 else 0
            desired = min(max(desired, pol.min_devices), pol.max_devices)
            desired = max(desired, used)      # never below pinned demand
            if pool in self._pending_up:
                if t < self._pending_up[pool]:
                    continue                  # a scale-up is in flight
                self._pending_up.pop(pool)
            if desired > cap:
                actions.append(ScaleAction(pool, desired,
                                           lag_s=pol.scale_up_lag_s))
                self._pending_up[pool] = t + pol.scale_up_lag_s
            elif desired < cap:
                last = self._last_change.get(pool, -math.inf)
                if t - last >= pol.cooldown_s:
                    actions.append(ScaleAction(pool, desired))
        return actions

    def apply(self, cluster: ClusterManager, action: ScaleAction,
              t: float) -> int:
        """Apply a decided resize; returns the capacity actually set
        (``set_capacity`` clamps at live allocations)."""
        applied = cluster.set_capacity(action.pool, action.capacity, t)
        self._last_change[action.pool] = t
        self._pending_up.pop(action.pool, None)
        return applied
