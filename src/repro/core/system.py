"""Murakkab: the integrated system facade (Fig. 2).

Wires the agent library, profile store, cluster manager, planner, scheduler
and execution engine together. One object owns both halves the paper says
must talk: the *workflow orchestrator* (planner + scheduler) and the
*cluster manager* — DAGs flow down, utilization stats flow up.

    system = Murakkab.paper_cluster()
    result = Job(description=..., inputs=videos,
                 constraints=MIN_COST).execute(system)
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field, replace

from .agents import AgentLibrary, default_library
from .cluster import ClusterManager, Instance, Pool
from .dag import DAG
from .orchestrator import RulePlanner
from .profiles import ProfileStore
from .scheduler import ExecutionPlan, Scheduler
from .arrivals import SERVING_PRESETS, ArrivalProcess
from .simulator import (OpenLoopReport, SimReport, Simulator, Submission,
                        render_trace)
from .spec import build_node, input_units
from .workflow import COMPONENT_ALIASES, ImperativeWorkflow, Job


@dataclass
class JobResult:
    """Everything one declarative job execution produced."""

    makespan_s: float
    energy_wh: float
    usd: float
    quality: float
    dag: DAG
    plan: ExecutionPlan
    toolcalls: dict[str, str]
    sim: SimReport
    log: list[str] = field(default_factory=list)

    def trace_str(self) -> str:
        """ASCII Fig-3-style execution trace of the run."""
        return render_trace(self.sim)


class Murakkab:
    """The integrated system: orchestrator + scheduler + cluster manager."""

    PLAN_CACHE_MAX = 256

    def __init__(self, cluster: ClusterManager,
                 library: AgentLibrary | None = None,
                 planner=None, router=None, telemetry=None):
        self.library = library or default_library()
        self.profiles = ProfileStore(self.library)
        self.cluster = cluster
        self.planner = planner or RulePlanner(self.library)
        self.scheduler = Scheduler(self.library, self.profiles, self.cluster)
        # learned routing + telemetry feedback loop (DESIGN.md §11):
        # ``router`` is a core.router.Router consulted at the scheduler's
        # level-1 implementation choice; ``telemetry`` a
        # core.telemetry.TelemetryStore every simulator run logs per-task
        # outcomes into. Both default to None — provably inert: plans and
        # traces stay byte-identical to a system without the subsystem.
        self.scheduler.router = router
        self.telemetry = telemetry
        # admission-time plan reuse (DESIGN.md §7): identical tenants
        # arriving into an unchanged cluster skip the greedy search
        self._plan_cache: OrderedDict[tuple, ExecutionPlan] = OrderedDict()
        self.plan_cache_enabled = True
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0

    # -- the routing/telemetry loop (DESIGN.md §11) -----------------------------
    @property
    def router(self):
        """The learned router the scheduler consults (None = static)."""
        return self.scheduler.router

    @router.setter
    def router(self, r):
        self.scheduler.router = r

    def _routed_interfaces(self) -> tuple:
        """Interfaces whose impl choice the attached router decides."""
        r = self.scheduler.router
        return r.interfaces if r is not None else ()

    # -- cluster factories -------------------------------------------------------
    @classmethod
    def paper_cluster(cls, library: AgentLibrary | None = None,
                      calibrated: bool = True, router=None,
                      telemetry=None) -> "Murakkab":
        """The paper's testbed: 2x ND96amsr = 16x A100 + 192 EPYC vCPUs."""
        cluster = ClusterManager([
            Pool("gpu", "a100-80g", capacity=16),
            Pool("cpu", "epyc-7v12-core", capacity=192),
        ])
        sys = cls(cluster, library, router=router, telemetry=telemetry)
        if calibrated:
            from ..configs.workflow_video import calibrate_paper_profiles
            calibrate_paper_profiles(sys.profiles)
        return sys

    @classmethod
    def tpu_cluster(cls, v5e: int = 256, v5p: int = 64, v4_harvest: int = 128,
                    host_cores: int = 512,
                    library: AgentLibrary | None = None, router=None,
                    telemetry=None) -> "Murakkab":
        """Deployment target: TPU pools + CPU hosts + harvestable v4 slices."""
        cluster = ClusterManager([
            Pool("v5e", "tpu-v5e", capacity=v5e),
            Pool("v5p", "tpu-v5p", capacity=v5p),
            Pool("v4_harvest", "tpu-v4", capacity=v4_harvest,
                 harvestable=True),
            Pool("cpu", "host-core", capacity=host_cores),
        ])
        return cls(cluster, library, router=router, telemetry=telemetry)

    def prewarm(self, impl: str, pool: str, n_devices: int, count: int = 1):
        """Provision warm instances (PTU-style always-on capacity)."""
        for _ in range(count):
            lease = self.cluster.alloc(pool, n_devices, t=0.0)
            if lease is None:
                raise RuntimeError(f"prewarm {impl}: {pool} pool full")
            self.cluster.add_instance(Instance(impl, pool, n_devices,
                                               lease=lease))

    # -- declarative path -----------------------------------------------------------
    def lower(self, job: Job) -> DAG:
        """Decompose a declarative job into the task-DAG IR."""
        return self.planner.lower(job)

    def plan(self, job: Job) -> tuple[DAG, ExecutionPlan]:
        """Lower a job and choose a configuration for every task."""
        dag = self.lower(job)
        plan = self.scheduler.plan(dag, job.constraint_spec,
                                   job.quality_floor,
                                   session=job.session)
        return dag, plan

    def execute(self, job: Job, arrival: float = 0.0) -> JobResult:
        """Plan and run one declarative job on the simulated cluster.

        The single-tenant entry point (paper Listing 2): lowers the job,
        runs the greedy lever search under its constraints and quality
        floors, executes the plan on the discrete-event engine and returns
        the full ``JobResult`` (makespan/energy/$, DAG, plan, toolcalls,
        trace). For multi-tenant workloads use ``execute_many``.
        """
        dag, plan = self.plan(job)
        return self._run({"job": (dag, plan, arrival)}, dag, plan)

    def execute_many(self, jobs: dict[str, tuple[Job, float]],
                     policy: str | None = "fcfs",
                     log: list | None = None,
                     resume: bool = True,
                     faults=None) -> SimReport:
        """Multi-tenant submission: {id: (job, arrival_s)}.

        Jobs enter an admission queue ordered by ``policy`` (core/admission:
        ``fcfs`` | ``strict-priority`` | ``weighted-fair``) and are *planned
        on admission* — the scheduler sees the cluster state at each job's
        arrival (warm instances, devices held by earlier tenants) instead of
        planning every job upfront against an empty cluster. Each job's
        ``tenant_class`` decides its queue rank and whether its allocations
        are preemptible (harvest class). ``resume=False`` disables work-item
        checkpoint/resume of preempted tasks (DESIGN.md §6.4) — every
        victim restarts from scratch, the pre-resume baseline. ``faults``
        takes a :class:`core.faults.FaultProfile` to run under seeded
        fault injection with retry/hedge recovery (DESIGN.md §10).

        Admission-time planning goes through a plan cache keyed by (DAG
        structural signature, constraint spec, quality floor, cluster-state
        digest): an identical tenant arriving into an unchanged cluster
        reuses the prior plan instead of re-running the greedy search.
        """
        subs = {}
        for wid, (job, arrival) in jobs.items():
            dag = self.lower(job)

            def _plan(dag=dag, job=job):
                return self.plan_admitted(dag, job)

            subs[wid] = Submission(dag=dag, plan=None, arrival=arrival,
                                   tenant=job.tenant_class, plan_fn=_plan)
        sim = Simulator(self.cluster, self.library, self.profiles,
                        resume=resume, faults=faults,
                        telemetry=self.telemetry,
                        routed_interfaces=self._routed_interfaces())
        return sim.run(subs, log=log, policy=policy)

    def open_loop(self, process: ArrivalProcess, horizon_s: float, *,
                  warmup_s: float = 0.0, presets: dict | None = None,
                  policy: str | None = "strict-priority", autoscaler=None,
                  log: list | None = None, collect_trace: bool = True,
                  resume: bool = True, fast_dispatch: bool = True,
                  plan_mode: str = "amortized", kv_cache: bool = True,
                  cache_affinity: bool = True,
                  faults=None) -> OpenLoopReport:
        """Serve an open-loop arrival stream (DESIGN.md §8).

        ``process`` is a ``core.arrivals`` generator (Poisson / MMPP /
        trace replay); each :class:`ArrivalEvent` is turned into a
        ``Submission`` via the scenario's :class:`ServingPreset` (job
        factory + per-class SLO). Scenario DAGs are lowered once and
        shared across arrivals — sound because the engine only mutates
        private plan copies and per-workflow state — so a 10k-arrival
        sweep pays one lowering, not 10k.

        ``plan_mode`` picks the planning amortization:

        - ``"amortized"`` (default): each scenario is planned once, on its
          first arrival, and later arrivals reuse a private copy of that
          plan. This is the serving posture — plans are compiled per
          workflow template, not per request — and what makes a
          10k-arrival sweep feasible (the admission-time plan cache is
          keyed by the cluster digest, which differs at almost every
          open-loop arrival, so per-request planning re-runs the search).
        - ``"admission"``: the closed-loop semantics — every arrival plans
          against the live cluster digest through ``plan_admitted``.

        ``autoscaler`` is a ``core.autoscale.Autoscaler``; steady-state
        metrics trim the first ``warmup_s`` of arrivals.

        Session-aware presets (``ServingPreset.session_aware``) lower one
        job template per *turn index* — conversation history grows the
        token footprint — and each submission carries the event's session
        id, which the engine uses for KV-affinity placement and hit-rate
        prefill pricing (DESIGN.md §9). ``kv_cache``/``cache_affinity``
        forward to the :class:`Simulator` switches, as does ``faults``
        (a :class:`core.faults.FaultProfile` for seeded fault injection
        with retry/hedge/degradation recovery, DESIGN.md §10).
        """
        if plan_mode not in ("amortized", "admission"):
            raise ValueError(f"plan_mode must be 'amortized' or "
                             f"'admission', got {plan_mode!r}")
        presets = presets if presets is not None else SERVING_PRESETS
        if not presets:
            raise RuntimeError(
                "no serving presets available — import repro.configs "
                "(workflow_video/rag/docingest) or pass presets=")
        lowered: dict[tuple, tuple[DAG, Job]] = {}
        plans: dict[tuple, ExecutionPlan] = {}

        def _stream():
            for i, ev in enumerate(process.events()):
                if ev.t > horizon_s:
                    break     # the engine stops pulling here anyway
                preset = presets[ev.scenario]
                # session-aware scenarios lower one template per turn
                # index (history grows the footprint); stateless ones
                # share a single template
                key = (ev.scenario,
                       ev.turn if preset.session_aware else 0)
                pair = lowered.get(key)
                if pair is None:
                    kw = ({"session": "", "turn": ev.turn}
                          if preset.session_aware else {})
                    job = (preset.make_job(preset.constraints, **kw)
                           if preset.constraints is not None
                           else preset.make_job(**kw))
                    pair = lowered[key] = (self.lower(job), job)
                dag, job = pair
                plan = plan_fn = None
                if plan_mode == "amortized":
                    tmpl = plans.get(key)
                    if tmpl is None:
                        tmpl = plans[key] = \
                            self.plan_admitted(dag, job)
                    # submissions share the template: the engine's only
                    # in-place plan mutation (capacity degrade) takes a
                    # copy-on-write private plan first
                    plan = tmpl
                    if faults is not None:
                        # degradation replans (retry pressure) re-plan
                        # this workflow against the live cluster; inert
                        # without faults, so the amortized fast path
                        # stays closure-free
                        def plan_fn(dag=dag, job=job):
                            return self.plan_admitted(dag, job)
                else:
                    pjob = (replace(job, session=ev.session)
                            if ev.session else job)

                    def plan_fn(dag=dag, job=pjob):
                        return self.plan_admitted(dag, job)

                yield f"w{i:06d}", Submission(
                    dag=dag, plan=plan, arrival=ev.t, tenant=ev.tenant,
                    plan_fn=plan_fn, slo_s=preset.slo_for(ev.tenant),
                    scenario=ev.scenario, session=ev.session)

        sim = Simulator(self.cluster, self.library, self.profiles,
                        resume=resume, fast_dispatch=fast_dispatch,
                        kv_cache=kv_cache, cache_affinity=cache_affinity,
                        faults=faults, telemetry=self.telemetry,
                        routed_interfaces=self._routed_interfaces())
        return sim.run_open_loop(_stream(), horizon_s, warmup_s=warmup_s,
                                 policy=policy, autoscaler=autoscaler,
                                 log=log, collect_trace=collect_trace)

    def plan_admitted(self, dag: DAG, job: Job) -> ExecutionPlan:
        """Plan one admitted workflow against live cluster state, reusing a
        cached plan when an identical (workflow, constraints, cluster-state)
        triple was already planned. Returns a private copy — the simulator
        may degrade configs in place when capacity shrank since planning."""
        if not self.plan_cache_enabled:
            return self.scheduler.plan(dag, job.constraint_spec,
                                       job.quality_floor,
                                       session=job.session)
        floor = job.quality_floor
        key = (dag.signature(), job.constraint_spec,
               tuple(sorted(floor.items())) if isinstance(floor, dict)
               else floor,
               self.cluster.digest(), self.profiles.version,
               # unlike pruning (plan-preserving), the search mode changes
               # chosen plans — toggling it must not serve cross-mode plans
               self.scheduler.joint_batch,
               # session affinity prices plans per session (warm-prefix
               # discounts differ even at equal cluster digests)
               job.session,
               # a learned router changes level-1 impl choices: any change
               # to what it would answer (weights version, epsilon, seed)
               # must invalidate cached plans; None when routing is off
               self.scheduler.router.fingerprint()
               if self.scheduler.router is not None else None)
        cached = self._plan_cache.get(key)
        if cached is not None:
            self._plan_cache.move_to_end(key)
            self.plan_cache_hits += 1
            return ExecutionPlan(dict(cached.configs))
        self.plan_cache_misses += 1
        plan = self.scheduler.plan(dag, job.constraint_spec,
                                   job.quality_floor,
                                   session=job.session)
        self._plan_cache[key] = ExecutionPlan(dict(plan.configs))
        if len(self._plan_cache) > self.PLAN_CACHE_MAX:
            self._plan_cache.popitem(last=False)
        return plan

    # -- imperative (baseline) path ----------------------------------------------------
    def execute_imperative(self, wf: ImperativeWorkflow,
                           inputs=()) -> JobResult:
        """Run a Listing-1 pinned workflow (the evaluation baseline)."""
        dag, plan = self.lower_imperative(wf, inputs)
        return self._run({"baseline": (dag, plan, 0.0)}, dag, plan)

    def lower_imperative(self, wf: ImperativeWorkflow, inputs=()) \
            -> tuple[DAG, ExecutionPlan]:
        """Listing-1 semantics: pinned impls/resources, sequential chain.

        Work-item cardinality and token footprints come from the component's
        interface (its declared ``CardinalityModel``/``TokenModel``) applied
        to the inputs' merged unit counts — no scenario knowledge here.
        """
        units = input_units(inputs)
        nodes, plan = [], ExecutionPlan()
        prev = None
        for i, comp in enumerate(wf.components()):
            alias = COMPONENT_ALIASES.get(comp.name.lower())
            if alias is None:
                raise KeyError(f"unknown component {comp.name!r}; aliases: "
                               f"{sorted(COMPONENT_ALIASES)}")
            iface_name, impl_name = alias
            iface = self.library.interfaces[iface_name]
            tid = f"c{i}_{iface_name}"
            node = build_node(tid, f"{comp.name} ({comp.kind})", iface,
                              (prev,) if prev else (), dict(comp.params),
                              units, chunkable=False)
            nodes.append(node)
            pool, n = self._resources_to_pool(comp.resources)
            cfg = self.scheduler.pin(node, impl_name, pool, n)
            # provisioned capacity (PTUs / pinned GPUs) is always-on => warm
            plan.configs[tid] = cfg.with_(warm=True)
            prev = tid
        return DAG(nodes), plan

    def _resources_to_pool(self, resources: dict) -> tuple[str, int]:
        for key, n in resources.items():
            k = key.lower()
            kind = {"gpus": "gpu", "ptus": "gpu", "cpus": "cpu",
                    "tpus": "tpu"}.get(k)
            if kind is None:
                continue
            if int(n) <= 0:
                raise ValueError(
                    f"non-positive device count {key}={n!r}; a pinned "
                    f"component must request >= 1 device")
            pools = self.cluster.pools_of_kind(kind)
            if not pools:
                raise ValueError(f"no pool of kind {kind!r} in cluster")
            # pinned (always-on) components need non-preemptible capacity:
            # a harvestable pool can be reclaimed under a component that
            # assumes its devices never go away
            pinned = [p for p in pools if not p.harvestable]
            if not pinned:
                raise ValueError(
                    f"only harvestable (preemptible) {kind!r} capacity in "
                    f"this cluster ({[p.name for p in pools]}); a pinned "
                    f"imperative component needs an always-on pool")
            return pinned[0].name, int(n)
        raise ValueError(f"unintelligible resources {resources!r}")

    # -- shared run ------------------------------------------------------------------
    def _run(self, wfs, dag: DAG, plan: ExecutionPlan) -> JobResult:
        log: list[str] = []
        sim = Simulator(self.cluster, self.library, self.profiles,
                        telemetry=self.telemetry,
                        routed_interfaces=self._routed_interfaces())
        report = sim.run(wfs, log=log)
        toolcalls = (self.planner.toolcalls(dag)
                     if hasattr(self.planner, "toolcalls") else {})
        return JobResult(
            makespan_s=report.makespan_s,
            energy_wh=report.energy_wh,
            usd=report.usd,
            quality=plan.total_quality(dag),
            dag=dag, plan=plan, toolcalls=toolcalls, sim=report, log=log)
