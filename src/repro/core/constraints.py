"""Constraint DSL: composable scheduling objectives (DESIGN.md §3).

The seed exposed one ``Constraint`` enum compared lexicographically. This
module generalizes it into composable objects the scheduler consumes:

- ``MinCost() / MinEnergy() / MinLatency() / MaxQuality()`` — atomic
  objectives over a candidate ``TaskConfig`` (lower value = better).
- ``Deadline(s=30)`` / ``Budget(usd=..., wh=...)`` — feasibility terms whose
  value is the *overrun* (0 when satisfied), so placing one ahead of an
  objective means "among configurations meeting it, optimize the rest".
  ``Scheduler.plan`` divides workflow-level deadlines/budgets across the
  DAG's tasks before per-task search: deadlines by critical-path-weighted
  latency share, budgets by cost share (DESIGN.md §6.1); ``per_task`` keeps
  the legacy even split.
- ``Weighted(terms)`` — a weighted blend of objectives into one scalar
  (weights carry the unit conversion, e.g. $/J).
- ``Lexicographic(a, b, ...)`` — explicit ordering; a bare sequence means
  the same thing.

Everything the seed accepted still works: ``MIN_COST``, the ``Constraint``
enum, and tuples of enum members normalize through ``as_spec``. All but the
last objective in an ordering compare in 5%-wide log bands so a secondary
objective breaks near-ties of the primary one (paper §3.3c).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass


class Constraint(enum.Enum):
    """Seed-compatible shorthand for the atomic objectives."""

    MIN_COST = "min_cost"
    MIN_ENERGY = "min_energy"
    MIN_LATENCY = "min_latency"
    MAX_QUALITY = "max_quality"


MIN_COST = Constraint.MIN_COST
MIN_ENERGY = Constraint.MIN_ENERGY
MIN_LATENCY = Constraint.MIN_LATENCY
MAX_QUALITY = Constraint.MAX_QUALITY


class Objective:
    """One scalar scheduling objective; lower ``value`` is better."""

    def value(self, cfg) -> float:
        """Objective value of a candidate ``TaskConfig`` (lower = better)."""
        raise NotImplementedError

    def per_task(self, n_tasks: int) -> "Objective":
        """Workflow-level terms override this to split across tasks."""
        return self

    def scaled(self, lat_frac: float, cost_frac: float) -> "Objective":
        """Workflow-level terms override this to take a weighted share:
        ``lat_frac`` of a deadline, ``cost_frac`` of a budget."""
        return self

    @property
    def is_workflow_term(self) -> bool:
        """True for terms stated at workflow scope (deadlines, budgets) that
        must be divided across tasks before per-task search."""
        return False


@dataclass(frozen=True)
class MinCost(Objective):
    """Minimize estimated dollar spend."""

    def value(self, cfg) -> float:
        """The config's estimated $ cost."""
        return cfg.est_usd


@dataclass(frozen=True)
class MinEnergy(Objective):
    """Minimize estimated above-idle energy."""

    def value(self, cfg) -> float:
        """The config's estimated energy in joules."""
        return cfg.est_energy_j


@dataclass(frozen=True)
class MinLatency(Objective):
    """Minimize estimated task latency."""

    def value(self, cfg) -> float:
        """The config's estimated latency in seconds."""
        return cfg.est_latency_s


@dataclass(frozen=True)
class MaxQuality(Objective):
    """Maximize result quality (negated: lower value = better)."""

    def value(self, cfg) -> float:
        """Negated quality, so minimization maximizes quality."""
        return -cfg.quality


@dataclass(frozen=True)
class Deadline(Objective):
    """End-to-end latency target in seconds; value = overrun."""

    s: float

    def __post_init__(self):
        if self.s <= 0:
            raise ValueError(f"Deadline needs a positive target, got {self.s}")

    def value(self, cfg) -> float:
        """Seconds of overrun beyond the target (0 when met)."""
        return max(0.0, cfg.est_latency_s - self.s)

    def per_task(self, n_tasks: int) -> "Deadline":
        """Legacy even split of the deadline across tasks."""
        return Deadline(s=self.s / max(n_tasks, 1))

    def scaled(self, lat_frac: float, cost_frac: float) -> "Deadline":
        """One task's critical-path-weighted share of the deadline."""
        return Deadline(s=self.s * lat_frac)

    @property
    def is_workflow_term(self) -> bool:
        """Deadlines are stated at workflow scope."""
        return True


@dataclass(frozen=True)
class Budget(Objective):
    """Spend caps; value = summed normalized overrun fraction (0 if met)."""

    usd: float | None = None
    wh: float | None = None

    def __post_init__(self):
        if self.usd is None and self.wh is None:
            raise ValueError("Budget needs at least one of usd= / wh=")
        for name, cap in (("usd", self.usd), ("wh", self.wh)):
            if cap is not None and cap <= 0:
                raise ValueError(
                    f"Budget needs a positive {name} cap, got {cap}")

    def value(self, cfg) -> float:
        """Summed normalized overrun fraction across the caps (0 if met)."""
        over = 0.0
        if self.usd is not None:
            over += max(0.0, cfg.est_usd - self.usd) / self.usd
        if self.wh is not None:
            cap_j = self.wh * 3600.0
            over += max(0.0, cfg.est_energy_j - cap_j) / cap_j
        return over

    def per_task(self, n_tasks: int) -> "Budget":
        """Legacy even split of the caps across tasks."""
        n = max(n_tasks, 1)
        return Budget(usd=None if self.usd is None else self.usd / n,
                      wh=None if self.wh is None else self.wh / n)

    def scaled(self, lat_frac: float, cost_frac: float) -> "Budget":
        """One task's cost-weighted share of the caps."""
        return Budget(usd=None if self.usd is None else self.usd * cost_frac,
                      wh=None if self.wh is None else self.wh * cost_frac)

    @property
    def is_workflow_term(self) -> bool:
        """Budgets are stated at workflow scope."""
        return True


@dataclass(frozen=True)
class Weighted(Objective):
    """Blend: value = sum of weight * objective value."""

    terms: tuple[tuple[Objective, float], ...]

    def value(self, cfg) -> float:
        """The weighted sum over the blended objectives."""
        return sum(w * o.value(cfg) for o, w in self.terms)

    def per_task(self, n_tasks: int) -> "Weighted":
        """Split any workflow-scoped terms evenly across tasks."""
        return Weighted(tuple((o.per_task(n_tasks), w)
                              for o, w in self.terms))

    def scaled(self, lat_frac: float, cost_frac: float) -> "Weighted":
        """Scale any workflow-scoped terms by their per-task shares."""
        return Weighted(tuple((o.scaled(lat_frac, cost_frac), w)
                              for o, w in self.terms))

    @property
    def is_workflow_term(self) -> bool:
        """True when any blended term is workflow-scoped."""
        return any(o.is_workflow_term for o, _ in self.terms)

    @classmethod
    def of(cls, cost: float = 0.0, energy: float = 0.0, latency: float = 0.0,
           quality: float = 0.0) -> "Weighted":
        """Shorthand: blend the four atomic objectives by weight."""
        terms = [(MinCost(), cost), (MinEnergy(), energy),
                 (MinLatency(), latency), (MaxQuality(), quality)]
        return cls(tuple((o, w) for o, w in terms if w))


_ENUM_MAP = {
    Constraint.MIN_COST: MinCost(),
    Constraint.MIN_ENERGY: MinEnergy(),
    Constraint.MIN_LATENCY: MinLatency(),
    Constraint.MAX_QUALITY: MaxQuality(),
}

# atomic objective -> enum member, for seed-compatible accessors
_OBJECTIVE_ENUM = {v: k for k, v in _ENUM_MAP.items()}


def as_enum(obj: "Objective"):
    """The ``Constraint`` member for an atomic objective, else the objective
    itself (composite DSL terms have no enum spelling)."""
    return _OBJECTIVE_ENUM.get(obj, obj)


def _as_objective(x) -> Objective:
    if isinstance(x, Objective):
        return x
    if isinstance(x, Constraint):
        return _ENUM_MAP[x]
    raise TypeError(f"not a scheduling objective: {x!r}")


@dataclass(frozen=True)
class ConstraintSpec:
    """A fully-normalized lexicographic ordering of objectives."""

    objectives: tuple[Objective, ...]

    def __post_init__(self):
        if not self.objectives:
            raise ValueError("a ConstraintSpec needs >= 1 objective")

    @staticmethod
    def _band(v: float) -> tuple[int, float]:
        """5% multiplicative log band, monotone over all of R.

        Sign-classed so that any negative value (quality-style objectives)
        orders below zero, and zero (e.g. a met deadline/budget: overrun 0)
        orders below every positive overrun — a naive ``log(v)`` band would
        rank a sub-unit overrun (negative log) *better* than feasibility.
        """
        if v > 0:
            return (1, round(math.log(max(v, 1e-12), 1.05)))
        if v < 0:
            return (-1, -round(math.log(max(-v, 1e-12), 1.05)))
        return (0, 0.0)

    def key(self, cfg) -> tuple:
        """Comparison key: all but the last objective banded (5% log bands),
        then universal tie-breaks on latency and $."""
        key: list = []
        for i, obj in enumerate(self.objectives):
            v = obj.value(cfg)
            key.append(self._band(v) if i < len(self.objectives) - 1 else v)
        key += [cfg.est_latency_s, cfg.est_usd]
        return tuple(key)

    @property
    def seeks_quality(self) -> bool:
        """True when the primary objective maximizes quality (the scheduler
        unlocks quality-only levers: top-2 impls, execution paths)."""
        return isinstance(self.objectives[0], MaxQuality)

    def per_task(self, n_tasks: int) -> "ConstraintSpec":
        """Split workflow-level deadline/budget terms evenly across tasks."""
        return ConstraintSpec(tuple(o.per_task(n_tasks)
                                    for o in self.objectives))

    def for_share(self, lat_frac: float, cost_frac: float) \
            -> "ConstraintSpec":
        """One task's weighted share of the workflow-level terms: deadlines
        scale by ``lat_frac``, budgets by ``cost_frac`` (Scheduler computes
        the fractions from a pilot plan and the DAG's critical path)."""
        return ConstraintSpec(tuple(o.scaled(lat_frac, cost_frac)
                                    for o in self.objectives))

    @property
    def has_workflow_terms(self) -> bool:
        """True when any objective is a workflow-scoped deadline/budget."""
        return any(o.is_workflow_term for o in self.objectives)


def Lexicographic(*objectives) -> ConstraintSpec:
    """Explicit ordering of objectives; earlier terms dominate."""
    return ConstraintSpec(tuple(_as_objective(o) for o in objectives))


def as_spec(constraints) -> ConstraintSpec:
    """Normalize every accepted constraint form into a ``ConstraintSpec``.

    Accepts: a ``ConstraintSpec``; a ``Constraint`` enum member; an
    ``Objective``; or a sequence mixing the latter two.
    """
    if isinstance(constraints, ConstraintSpec):
        return constraints
    if isinstance(constraints, (Constraint, Objective)):
        return ConstraintSpec((_as_objective(constraints),))
    try:
        objs = tuple(_as_objective(c) for c in constraints)
    except TypeError:
        raise TypeError(
            f"cannot interpret constraints {constraints!r}; expected a "
            f"Constraint, an Objective, a sequence of them, or a "
            f"ConstraintSpec") from None
    return ConstraintSpec(objs)
