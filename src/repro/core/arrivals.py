"""Open-loop arrival processes for the serving engine (DESIGN.md §8).

The closed-loop benches replay a fixed tenant mix once; real compound-AI
serving is open-loop — requests keep arriving whether or not the cluster
has caught up, so queueing, SLO attainment and autoscaling behavior only
show up under a generated arrival stream. Three seeded processes:

- :class:`PoissonArrivals` — memoryless arrivals at a constant offered
  rate; the steady-state baseline every queueing result assumes.
- :class:`MMPPArrivals` — a 2-state Markov-modulated Poisson process
  (on/off bursts): exponential dwell times alternate between a burst rate
  and an idle/background rate. The standard bursty-traffic model; drives
  the autoscaler's scale-up-lag and cooldown behavior.
- :class:`TraceArrivals` — replay of a recorded schedule, round-tripping a
  JSONL file (one ``{"t": ..., "scenario": ..., "tenant": ...}`` object
  per line), so production traces can be fed straight into the engine.
- :class:`SessionArrivals` — multi-turn chat/agent sessions (DESIGN.md
  §9): Poisson session starts, geometric turn counts, exponential think
  time between turns. Each event carries the session id and turn index,
  so the engine can route turns to KV-cache-resident instances.

Every process yields :class:`ArrivalEvent` rows in non-decreasing time
order and is fully determined by its seed — two iterations of the same
process produce identical streams (a hypothesis property in
``tests/test_arrivals.py``). Scenario and tenant class are sampled per
arrival from weight maps, so one stream carries a heterogeneous mix.
"""
from __future__ import annotations

import heapq
import itertools
import json
import random
from dataclasses import dataclass, field
from typing import Callable, Iterator

from .admission import TENANT_CLASSES

# tenant-class mix used when a process is built without explicit shares:
# a small latency-sensitive slice, a standard majority, and a best-effort
# harvest tail (the mix the multitenant bench's scenarios assume)
DEFAULT_TENANT_SHARES = {"priority": 0.2, "standard": 0.5, "harvest": 0.3}


@dataclass(frozen=True)
class ArrivalEvent:
    """One workflow arrival: when, which scenario, which tenant class.

    ``session``/``turn`` identify multi-turn serving sessions (empty /
    0 for the stateless processes — the wire format omits them then, so
    pre-session traces round-trip unchanged).
    """

    t: float
    scenario: str
    tenant: str = "standard"
    session: str = ""
    turn: int = 0


def _normalize(weights: dict[str, float], what: str) -> list[tuple[str, float]]:
    """Cumulative distribution rows [(key, cum_prob)] from a weight map."""
    if not weights:
        raise ValueError(f"empty {what} mix")
    total = sum(weights.values())
    if total <= 0:
        raise ValueError(f"{what} weights must sum > 0: {weights}")
    rows, acc = [], 0.0
    for key in sorted(weights):
        acc += weights[key] / total
        rows.append((key, acc))
    rows[-1] = (rows[-1][0], 1.0)     # guard float drift at the top bin
    return rows


def _pick(rows: list[tuple[str, float]], u: float) -> str:
    for key, cum in rows:
        if u <= cum:
            return key
    return rows[-1][0]


class ArrivalProcess:
    """Base: a seeded, replayable stream of :class:`ArrivalEvent`."""

    def events(self) -> Iterator[ArrivalEvent]:
        """Yield arrivals in non-decreasing time order (may be infinite)."""
        raise NotImplementedError

    # -- shared mix sampling -------------------------------------------------
    def _init_mix(self, mix: dict[str, float],
                  tenant_shares: dict[str, float] | None):
        shares = dict(tenant_shares or DEFAULT_TENANT_SHARES)
        for tenant in shares:
            if tenant not in TENANT_CLASSES:
                raise ValueError(f"unknown tenant class {tenant!r}; "
                                 f"one of {TENANT_CLASSES}")
        self._mix = _normalize(mix, "scenario")
        self._shares = _normalize(shares, "tenant")

    def _sample(self, rng: random.Random, t: float) -> ArrivalEvent:
        scenario = _pick(self._mix, rng.random())
        tenant = _pick(self._shares, rng.random())
        return ArrivalEvent(t, scenario, tenant)


class PoissonArrivals(ArrivalProcess):
    """Constant-rate memoryless arrivals (exponential inter-arrival gaps)."""

    def __init__(self, rate_per_s: float, mix: dict[str, float],
                 tenant_shares: dict[str, float] | None = None,
                 seed: int = 0):
        if rate_per_s <= 0:
            raise ValueError(f"rate_per_s must be > 0, got {rate_per_s}")
        self.rate_per_s = rate_per_s
        self.seed = seed
        self._init_mix(mix, tenant_shares)

    def events(self) -> Iterator[ArrivalEvent]:
        """Infinite exponential-gap stream at ``rate_per_s``."""
        rng = random.Random(self.seed)
        t = 0.0
        while True:
            t += rng.expovariate(self.rate_per_s)
            yield self._sample(rng, t)


class MMPPArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process (on/off bursts).

    Dwell times in each state are exponential (``mean_on_s`` /
    ``mean_off_s``); arrivals within a state are Poisson at ``rate_on`` or
    ``rate_off``. ``rate_off=0`` models true idle gaps. The long-run
    offered rate is ``(rate_on * mean_on + rate_off * mean_off) /
    (mean_on + mean_off)`` — :meth:`mean_rate`.
    """

    def __init__(self, rate_on: float, rate_off: float, mean_on_s: float,
                 mean_off_s: float, mix: dict[str, float],
                 tenant_shares: dict[str, float] | None = None,
                 seed: int = 0):
        if rate_on <= 0:
            raise ValueError(f"rate_on must be > 0, got {rate_on}")
        if rate_off < 0:
            raise ValueError(f"rate_off must be >= 0, got {rate_off}")
        if mean_on_s <= 0 or mean_off_s <= 0:
            raise ValueError("state dwell means must be > 0")
        self.rate_on = rate_on
        self.rate_off = rate_off
        self.mean_on_s = mean_on_s
        self.mean_off_s = mean_off_s
        self.seed = seed
        self._init_mix(mix, tenant_shares)

    def mean_rate(self) -> float:
        """Long-run offered arrivals/s across on and off states."""
        return (self.rate_on * self.mean_on_s +
                self.rate_off * self.mean_off_s) / \
            (self.mean_on_s + self.mean_off_s)

    def events(self) -> Iterator[ArrivalEvent]:
        """Infinite on/off-modulated stream (starts in the burst state)."""
        rng = random.Random(self.seed)
        t = 0.0
        on = True                     # start in the burst state
        state_end = rng.expovariate(1.0 / self.mean_on_s)
        while True:
            rate = self.rate_on if on else self.rate_off
            gap = rng.expovariate(rate) if rate > 0 else float("inf")
            if t + gap > state_end:
                # no arrival before the state flips: jump to the boundary
                # (the exponential's memorylessness makes re-drawing the
                # gap in the next state statistically exact)
                t = state_end
                on = not on
                mean = self.mean_on_s if on else self.mean_off_s
                state_end = t + rng.expovariate(1.0 / mean)
                continue
            t += gap
            yield self._sample(rng, t)


class TraceArrivals(ArrivalProcess):
    """Replay of a recorded arrival schedule (JSONL round-trippable)."""

    def __init__(self, events: "list[ArrivalEvent]"):
        prev = 0.0
        for e in events:
            if e.t < prev:
                raise ValueError(f"trace not time-ordered at t={e.t} "
                                 f"(previous {prev})")
            prev = e.t
            if e.tenant not in TENANT_CLASSES:
                raise ValueError(f"unknown tenant class {e.tenant!r}")
        self._events = list(events)

    def events(self) -> Iterator[ArrivalEvent]:
        """The recorded schedule, verbatim."""
        return iter(self._events)

    def __len__(self) -> int:
        return len(self._events)

    # -- JSONL round trip ----------------------------------------------------
    def to_jsonl(self) -> str:
        """One JSON object per line: {"t", "scenario", "tenant"} plus
        {"session", "turn"} for session-carrying events only (the
        sessionless wire format is byte-stable across this addition)."""
        rows = []
        for e in self._events:
            row: dict = {"t": e.t, "scenario": e.scenario,
                         "tenant": e.tenant}
            if e.session:
                row["session"] = e.session
                row["turn"] = e.turn
            rows.append(json.dumps(row, sort_keys=True))
        return "\n".join(rows)

    @classmethod
    def from_jsonl(cls, text: str) -> "TraceArrivals":
        """Parse :meth:`to_jsonl` output (blank lines ignored)."""
        events = []
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            events.append(ArrivalEvent(float(row["t"]), row["scenario"],
                                       row.get("tenant", "standard"),
                                       row.get("session", ""),
                                       int(row.get("turn", 0))))
        return cls(events)

    @classmethod
    def record(cls, process: ArrivalProcess, horizon_s: float,
               max_events: int = 1_000_000) -> "TraceArrivals":
        """Materialize another process's stream up to ``horizon_s``."""
        events = []
        for e in process.events():
            if e.t > horizon_s or len(events) >= max_events:
                break
            events.append(e)
        return cls(events)


class SessionArrivals(ArrivalProcess):
    """Multi-turn serving sessions (chat/agent loops, DESIGN.md §9).

    Sessions start as a Poisson process at ``session_rate_per_s``. Each
    session samples its tenant class once, then emits turns: after turn
    ``k`` the session continues with probability ``1 - 1/mean_turns``
    (geometric turn counts with the given mean, hard-capped at
    ``max_turns``), and the next turn arrives after an exponential think
    gap of mean ``think_time_s``. Turns of concurrent sessions interleave
    in time order via a heap merge; a single seeded RNG drives every draw,
    so the stream replays exactly.
    """

    def __init__(self, session_rate_per_s: float, scenario: str = "chat",
                 mean_turns: float = 6.0, think_time_s: float = 45.0,
                 max_turns: int = 32,
                 tenant_shares: dict[str, float] | None = None,
                 seed: int = 0):
        if session_rate_per_s <= 0:
            raise ValueError(f"session_rate_per_s must be > 0, "
                             f"got {session_rate_per_s}")
        if mean_turns < 1:
            raise ValueError(f"mean_turns must be >= 1, got {mean_turns}")
        if think_time_s <= 0:
            raise ValueError(f"think_time_s must be > 0, "
                             f"got {think_time_s}")
        if max_turns < 1:
            raise ValueError(f"max_turns must be >= 1, got {max_turns}")
        self.session_rate_per_s = session_rate_per_s
        self.scenario = scenario
        self.mean_turns = mean_turns
        self.think_time_s = think_time_s
        self.max_turns = max_turns
        self.seed = seed
        self._init_mix({scenario: 1.0}, tenant_shares)

    def mean_rate(self) -> float:
        """Long-run offered turns/s (sessions/s x mean turns, pre-cap)."""
        return self.session_rate_per_s * self.mean_turns

    def events(self) -> Iterator[ArrivalEvent]:
        """Infinite time-ordered turn stream across concurrent sessions."""
        rng = random.Random(self.seed)
        cont = 1.0 - 1.0 / max(self.mean_turns, 1.0)
        seq = itertools.count()       # FIFO tiebreak for same-t events
        sessions = 0
        # heap rows: (t, seq, session_id | None, turn, tenant);
        # session_id None marks a pending session *start*
        heap: list = [(rng.expovariate(self.session_rate_per_s),
                       next(seq), None, 0, "")]
        while heap:
            t, _, sid, turn, tenant = heapq.heappop(heap)
            if sid is None:
                # a session starts now: name it, sample its tenant once,
                # and queue the start of the next session
                sid = f"s{sessions:06d}"
                sessions += 1
                tenant = _pick(self._shares, rng.random())
                heapq.heappush(
                    heap, (t + rng.expovariate(self.session_rate_per_s),
                           next(seq), None, 0, ""))
            if turn + 1 < self.max_turns and rng.random() < cont:
                gap = rng.expovariate(1.0 / self.think_time_s)
                heapq.heappush(heap, (t + gap, next(seq), sid,
                                      turn + 1, tenant))
            yield ArrivalEvent(t, self.scenario, tenant, sid, turn)


# ---------------------------------------------------------------------------
# Serving presets: scenario name -> job factory + SLO policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServingPreset:
    """How one scenario appears in an open-loop mix.

    ``make_job`` is the scenario's declarative job factory (the workflow
    configs register theirs at import — core stays config-agnostic);
    ``weight`` is its share of the default arrival mix; ``base_slo_s`` is
    the standard-class span SLO, scaled per tenant class by
    ``slo_class_mult`` (priority tighter, harvest looser).
    """

    scenario: str
    make_job: Callable
    weight: float = 1.0
    base_slo_s: float | None = None
    slo_class_mult: dict = field(default_factory=lambda: {
        "priority": 0.5, "standard": 1.0, "harvest": 4.0})
    constraints: tuple | None = None     # forwarded to make_job
    # session-aware factories take (session=..., turn=...) kwargs and
    # build turn-indexed jobs (token footprint grows with history); the
    # open-loop driver keys its lowering cache per turn for these
    session_aware: bool = False

    def slo_for(self, tenant: str) -> float | None:
        """The span SLO for one tenant class (None = best-effort)."""
        if self.base_slo_s is None:
            return None
        return self.base_slo_s * self.slo_class_mult.get(tenant, 1.0)


# scenario -> preset; the three workflow config modules register theirs at
# import time (``repro.configs``), keeping core free of config imports
SERVING_PRESETS: dict[str, ServingPreset] = {}


def register_preset(preset: ServingPreset) -> ServingPreset:
    """Register (or replace) a scenario's serving preset."""
    SERVING_PRESETS[preset.scenario] = preset
    return preset


def default_mix() -> dict[str, float]:
    """Scenario weight map over every registered preset."""
    if not SERVING_PRESETS:
        raise RuntimeError(
            "no serving presets registered — import repro.configs "
            "(workflow_video/rag/docingest) before building a mix")
    return {name: p.weight for name, p in SERVING_PRESETS.items()}
