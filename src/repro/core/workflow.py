"""Workflow programming models: declarative (Murakkab) and imperative (baseline).

Paper Listing 2 (declarative)::

    result = Job(description="List objects shown/mentioned in the videos",
                 inputs=videos, tasks=[t1, t2, t3],
                 constraints=MIN_COST).execute(system)

Paper Listing 1 (imperative, today's systems)::

    frame_ext = Tool(name="OpenCV", params={"sampling_rate": 15},
                     resources={"CPUs": 1})
    stt       = MLModel(name="Whisper", resources={"GPUs": 1})
    ...
    result = Workflow(frame_ext >> stt >> obj_det >> summarize)\
                 .execute(system, inputs=videos)

The imperative path pins model/hardware per component and runs sequentially —
it exists so the baseline of the paper's evaluation is a first-class citizen
(the system prompt requires implementing the baseline too).

Inputs are ``InputSet`` instances (DESIGN.md §2): each carries a dataflow
``artifact`` type and a ``units()`` breakdown that interface-declared
cardinality models consume. ``VideoInput``, ``DocumentInput`` and
``QueryInput`` below are peers — the core special-cases none of them.
Constraints accept the seed enum *or* the composable DSL from
``core.constraints`` (``Deadline``, ``Budget``, ``Weighted``, orderings).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

from .constraints import (MAX_QUALITY, MIN_COST, MIN_ENERGY,  # noqa: F401
                          MIN_LATENCY, Constraint, ConstraintSpec, as_enum,
                          as_spec)


@dataclass(frozen=True)
class VideoInput:
    """Synthetic stand-in for an input video file."""

    name: str
    duration_s: float = 480.0
    scenes: int = 4                  # OmAgent-style scene segmentation
    frames_per_scene: int = 10

    artifact = "video"

    def units(self) -> dict[str, int]:
        """Unit breakdown driving interface cardinality models."""
        return {"videos": 1, "scenes": self.scenes,
                "frames": self.scenes * self.frames_per_scene}


@dataclass(frozen=True)
class DocumentInput:
    """An input document to parse, digest and index."""

    name: str
    pages: int = 12
    chunks_per_page: int = 3

    artifact = "document"

    def units(self) -> dict[str, int]:
        """Unit breakdown driving interface cardinality models."""
        return {"documents": 1, "pages": self.pages,
                "chunks": self.pages * self.chunks_per_page}


@dataclass(frozen=True)
class QueryInput:
    """A retrieval query over an indexed corpus."""

    text: str
    top_k: int = 5                   # passages handed to synthesis
    candidates: int = 20             # retrieval pool size to rerank

    artifact = "query"

    def units(self) -> dict[str, int]:
        """Unit breakdown driving interface cardinality models."""
        return {"queries": 1, "passages": self.candidates}


@dataclass(frozen=True)
class Job:
    """Declarative job spec (paper Listing 2)."""

    description: str
    inputs: Sequence[Any] = ()
    tasks: Sequence[str] = ()        # optional NL sub-task hints
    constraints: Any = Constraint.MIN_COST
    # min acceptable impl quality: one float, or per-interface dict
    quality_floor: float | dict = 0.85
    # multi-tenant class: "priority" | "standard" | "harvest"
    # (core/admission.py). Harvest-class allocations are preemptible; a
    # preempted task's completed batch steps are checkpointed and the
    # requeue resumes from the residual work-items (DESIGN.md §6.4), so
    # harvest jobs lose at most one in-flight step per preemption.
    tenant_class: str = "standard"
    # serving-session identity (multi-turn chat/agent loops): tasks of
    # jobs sharing a session share prompt prefixes, so the planner and
    # engine use it for KV-affinity placement and hit-rate-dependent
    # prefill pricing (DESIGN.md §9). Empty = stateless (the default).
    session: str = ""

    def __post_init__(self):
        from .admission import validate_tenant
        validate_tenant(self.tenant_class)

    @property
    def constraint_spec(self) -> ConstraintSpec:
        """The job's constraints normalized into a ``ConstraintSpec``."""
        return as_spec(self.constraints)

    @property
    def constraint_order(self) -> tuple:
        """Seed-compatible accessor: atomic objectives come back as the
        ``Constraint`` enum members the seed returned (so identity and
        membership checks keep working); composite DSL terms pass through."""
        return tuple(as_enum(o) for o in self.constraint_spec.objectives)

    def execute(self, system, **kw):
        """Lower -> schedule -> run on the given Murakkab system."""
        return system.execute(self, **kw)


# ---------------------------------------------------------------------------
# Imperative API (Listing 1) — the baseline programming model
# ---------------------------------------------------------------------------


@dataclass
class Component:
    """A pinned model/tool with explicit resources (today's style)."""

    name: str
    kind: str                        # tool | mlmodel | llm
    params: dict = field(default_factory=dict)
    resources: dict = field(default_factory=dict)   # {"GPUs": 1} / {"CPUs": 2}
    key: str = ""                    # provider credential (unused, fidelity)
    system_prompt: str = ""
    user_prompt: str = ""
    _next: "Component | None" = None

    def __rshift__(self, other: "Component") -> "Component":
        """``a >> b`` chains dataflow (stands in for the paper's ``->``)."""
        tail = self
        while tail._next is not None:
            tail = tail._next
        tail._next = other
        return self

    def chain(self) -> list["Component"]:
        """The linked components in dataflow order."""
        out, cur = [], self
        while cur is not None:
            out.append(cur)
            cur = cur._next
        return out


def Tool(name: str, **kw) -> Component:
    """A pinned non-model tool component (Listing 1)."""
    return Component(name=name, kind="tool", **kw)


def MLModel(name: str, **kw) -> Component:
    """A pinned (non-LLM) model component (Listing 1)."""
    return Component(name=name, kind="mlmodel", **kw)


def LLM(name: str, **kw) -> Component:
    """A pinned LLM component with prompts (Listing 1)."""
    return Component(name=name, kind="llm", **kw)


# component name -> agent (interface, impl) in the default library
COMPONENT_ALIASES: dict[str, tuple[str, str]] = {
    "opencv": ("frame_extract", "opencv"),
    "whisper": ("speech_to_text", "whisper-large"),
    "clip": ("object_detect", "clip"),
    "llama": ("summarize", "nvlm-72b"),     # paper eval runs NVLM here
    "nvlm": ("summarize", "nvlm-72b"),
    "nvlm-embed": ("embed", "nvlm-embed"),
    "bm25": ("retrieve", "bm25-keyword"),
    "faiss": ("retrieve", "dense-retrieval"),
    "pypdf": ("parse_doc", "pypdf-parse"),
}


@dataclass
class ImperativeWorkflow:
    """Fixed execution: pinned impls/resources, sequential flow."""

    flow: Component

    def components(self) -> list[Component]:
        """The pinned components in execution order."""
        return self.flow.chain()

    def execute(self, system, inputs: Sequence[Any] = (), **kw):
        """Run the pinned sequential flow on the given system."""
        return system.execute_imperative(self, inputs=inputs, **kw)


def Workflow(flow: Component) -> ImperativeWorkflow:
    """Wrap a ``>>``-chained component flow (paper Listing 1)."""
    return ImperativeWorkflow(flow)
