"""Per-task telemetry: the route -> log -> evaluate -> update feedback loop.

DESIGN.md §11. The simulator logs one :class:`TaskRecord` per completed task
attempt — the query features the router saw, the implementation that ran,
and the latency/energy/$/quality outcome — into an append-only
:class:`TelemetryStore`. The offline evaluator (``core/router.py``) replays
the store between runs to update routing weights and to calibrate measured
quality back into the :class:`~repro.core.profiles.ProfileStore`; nothing
learns *inside* a simulation step, so traces stay seeded-replayable.

Attained quality defaults to the planned (declared) quality; a
``quality_model`` callable — ``(features, impl_name, declared) -> float`` —
stands in for a ground-truth grader (an LLM judge, labeled evals) in
benchmarks and tests. Everything here is a pure function of its inputs:
the same run produces byte-identical records.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Callable

# -- query featurization ------------------------------------------------------

#: ~200 highest-frequency English words; tokens outside this set count as
#: *rare* (entity names, tickers, jargon) — the signal that lexical (BM25)
#: retrieval tends to score exactly (beyond-vector-search's observation).
_COMMON_WORDS = frozenset("""
the be to of and a in that have i it for not on with he as you do at this
but his by from they we say her she or an will my one all would there their
what so up out if about who get which go me when make can like time no just
him know take people into year your good some could them see other than then
now look only come its over think also back after use two how our work first
well way even new want because any these give day most us is are was were
been has had did does having may might must shall should state question
summarize summary describe during between under against within without
where why whose whom while which report filing fiscal revenue risk results
company year years quarter annual disclose trends segment acquisitions
litigation supply chain closed reserved what's
""".split())


@dataclass(frozen=True)
class QueryFeatures:
    """Deterministic features of one routed query/task input.

    The router's decision basis and the telemetry record's context — both
    sides compute them through :func:`featurize`, so the offline evaluator
    replays exactly what the router saw.
    """

    length: int            # characters in the text
    n_tokens: int          # whitespace tokens
    digit_density: float   # fraction of characters that are digits
    id_density: float      # fraction of tokens carrying digits/ID shapes
    rarity: float          # fraction of tokens outside the common-word set

    def bucket(self) -> str:
        """Coarse feature bucket the bandit keys its weights on.

        Two axes: *lookup-shaped* (digit/ID-dense — document ids, fiscal
        years, tickers — where exact lexical match wins) vs *semantic*
        (clean prose needing embedding recall), crossed with short vs long.
        """
        lookup = self.id_density >= 0.2 or self.digit_density >= 0.08
        size = "short" if self.n_tokens <= 10 else "long"
        return f"{'lookup' if lookup else 'semantic'}:{size}"


def featurize(text: str) -> QueryFeatures:
    """Featurize one query string (pure, deterministic).

    ``id_density`` counts tokens that look like identifiers: containing a
    digit, or ALL-CAPS acronyms of length >= 2 ("10-K", "FY2024", "SEC").
    ``rarity`` is corpus-frequency-model rarity against the built-in
    common-word table — a stand-in for token IDF that needs no corpus.
    """
    text = text or ""
    toks = text.split()
    n = len(toks)
    digits = sum(c.isdigit() for c in text)
    ids = sum(1 for t in toks
              if any(c.isdigit() for c in t)
              or (len(t) >= 2 and t.isupper()))
    rare = sum(1 for t in toks
               if t.strip(".,?!:;()'\"").lower() not in _COMMON_WORDS)
    return QueryFeatures(
        length=len(text), n_tokens=n,
        digit_density=digits / max(len(text), 1),
        id_density=ids / max(n, 1),
        rarity=rare / max(n, 1))


#: toolcall-arg keys scanned, in order, for the routable text of a task
_TEXT_ARGS = ("query", "question", "message", "text")


def featurize_node(node) -> QueryFeatures:
    """Features for a task node: its text-bearing toolcall arg, else the
    NL description. One shared entry point for the router's decision and
    the telemetry log, so replayed records match routed features exactly."""
    for key in _TEXT_ARGS:
        v = node.args.get(key)
        if isinstance(v, str) and v:
            return featurize(v)
    return featurize(node.description)


# -- the telemetry record + store ---------------------------------------------


@dataclass(frozen=True)
class TaskRecord:
    """One completed task attempt: decision context + measured outcome."""

    t: float               # simulation completion time
    workflow: str
    task: str
    interface: str         # agent interface the task bound to
    impl: str              # implementation that actually ran (the "arm")
    pool: str
    features: QueryFeatures
    latency_s: float       # measured wall time of the run
    energy_j: float        # marginal (above idle) energy of the run
    usd: float
    quality: float         # attained quality (model-graded or declared)
    routed: bool = False   # True when a learned router chose ``impl``

    def to_json(self) -> dict:
        """Round-trippable plain-dict form (JSONL row)."""
        return asdict(self)

    @staticmethod
    def from_json(row: dict) -> "TaskRecord":
        """Inverse of :meth:`to_json` (exact round-trip)."""
        row = dict(row)
        row["features"] = QueryFeatures(**row["features"])
        return TaskRecord(**row)


QualityModel = Callable[[QueryFeatures, str, float], float]


class TelemetryStore:
    """Append-only per-task outcome log feeding the offline evaluator.

    ``quality_model`` — ``(features, impl, declared_quality) -> float`` —
    grades attained quality; ``None`` records the planned quality (every
    run then trivially attains its estimate). The store never influences
    the run that fills it: the simulator writes records after each task's
    accounting settles, so ``telemetry=None`` and an attached store
    produce byte-identical traces (the inertness tests pin this).
    """

    def __init__(self, quality_model: QualityModel | None = None):
        self.records: list[TaskRecord] = []
        self.quality_model = quality_model

    def __len__(self) -> int:
        return len(self.records)

    # -- writing --------------------------------------------------------------
    def observe(self, *, t: float, workflow: str, task: str, node,
                interface: str, impl: str, pool: str, latency_s: float,
                energy_j: float, usd: float, declared_quality: float,
                routed: bool = False) -> TaskRecord:
        """Grade and append one completed task attempt."""
        feats = featurize_node(node)
        q = (self.quality_model(feats, impl, declared_quality)
             if self.quality_model is not None else declared_quality)
        rec = TaskRecord(t=t, workflow=workflow, task=task,
                         interface=interface, impl=impl, pool=pool,
                         features=feats, latency_s=latency_s,
                         energy_j=energy_j, usd=usd, quality=q,
                         routed=routed)
        self.records.append(rec)
        return rec

    def log(self, rec: TaskRecord):
        """Append a pre-built record (trace replay, tests)."""
        self.records.append(rec)

    # -- reading --------------------------------------------------------------
    def by_interface(self, interface: str) -> list[TaskRecord]:
        """Records of one agent interface, in completion order."""
        return [r for r in self.records if r.interface == interface]

    def attainment(self, interface: str, target: float) -> float:
        """Fraction of the interface's records attaining ``target`` quality
        (1.0 on an empty slice — no evidence of a miss)."""
        rows = self.by_interface(interface)
        if not rows:
            return 1.0
        return sum(r.quality >= target for r in rows) / len(rows)

    def mean_quality(self, min_count: int = 1) -> dict[str, float]:
        """Measured mean attained quality per implementation.

        Only impls with at least ``min_count`` records appear — the
        calibration path refuses to overwrite a declared quality on one
        noisy sample. Pure function of the log.
        """
        acc: dict[str, list[float]] = {}
        for r in self.records:
            acc.setdefault(r.impl, []).append(r.quality)
        return {impl: math.fsum(qs) / len(qs)
                for impl, qs in sorted(acc.items())
                if len(qs) >= min_count}

    # -- persistence ----------------------------------------------------------
    def to_jsonl(self) -> str:
        """Serialize every record, one JSON object per line."""
        return "\n".join(json.dumps(r.to_json(), sort_keys=True)
                         for r in self.records) + ("\n" if self.records
                                                   else "")

    @classmethod
    def from_jsonl(cls, text: str,
                   quality_model: QualityModel | None = None) \
            -> "TelemetryStore":
        """Exact inverse of :meth:`to_jsonl`."""
        store = cls(quality_model=quality_model)
        for line in text.splitlines():
            line = line.strip()
            if line:
                store.records.append(TaskRecord.from_json(json.loads(line)))
        return store
