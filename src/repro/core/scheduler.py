"""Adaptive scheduling: the Table-1 levers + greedy hierarchical search.

For every task the scheduler chooses a configuration over the paper's levers:

  ========================  =======================================
  Paper lever (Table 1)     ``TaskConfig`` field
  ========================  =======================================
  GPU generation            ``pool`` (device SKU of the pool)
  CPU vs GPU                ``pool`` (kind)
  Task parallelism          ``n_instances`` (fan-out), ``batch``
  Execution paths           ``paths`` (top-k parallel reasoning)
  Model/tool                ``impl``
  ========================  =======================================

The search space explodes combinatorially (paper §3.3c), so selection is a
greedy *hierarchy of optimization functions*: (1) implementation by quality
gate + constraint preference, (2) hardware/device-count by the constraint
objective, (3) parallelism given real-time free resources from the cluster
manager. Constraints are the composable DSL of ``core.constraints`` —
lexicographic orderings in 5%-tolerance bands (a secondary objective breaks
near-ties of the primary one), weighted blends, deadlines and budget caps;
the seed ``Constraint`` enum still normalizes through ``as_spec``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

from .agents import AgentImpl, AgentLibrary
from .cluster import ClusterManager
from .constraints import Constraint, ConstraintSpec, Objective, as_spec
from .dag import DAG, TaskNode
from .energy import CATALOG, knee_batch_grid
from .profiles import CostQuery, ProfileStore


@dataclass(frozen=True)
class TaskConfig:
    """One fully-resolved execution configuration for a task."""

    impl: str
    pool: str
    n_devices: int                # per instance
    n_instances: int = 1          # fan-out across instances
    batch: int = 1                # items co-scheduled per step
    paths: int = 1                # parallel execution paths (CoT top-k)
    # estimates (filled by the scheduler; simulator recomputes actuals)
    est_latency_s: float = 0.0
    est_energy_j: float = 0.0
    est_usd: float = 0.0
    est_power_w: float = 0.0      # marginal draw while running
    quality: float = 1.0
    warm: bool = False            # a warm instance was available

    def with_(self, **kw) -> "TaskConfig":
        """Functional update (the dataclass is frozen)."""
        return replace(self, **kw)


@dataclass
class ExecutionPlan:
    """Task id -> chosen ``TaskConfig`` for one lowered workflow DAG."""

    configs: dict[str, TaskConfig] = field(default_factory=dict)

    def __getitem__(self, tid: str) -> TaskConfig:
        return self.configs[tid]

    def total_quality(self, dag: DAG) -> float:
        """End-to-end quality = product over stages (cascading effects)."""
        q = 1.0
        for tid in dag.topo_order:
            q *= self.configs[tid].quality
        return q

    def report(self, dag: DAG) -> dict:
        """Plan-level estimates: critical path, energy, $ and quality."""
        lat = {tid: c.est_latency_s for tid, c in self.configs.items()}
        cp, path = dag.critical_path(lat)
        return {
            "critical_path_s": cp,
            "critical_path": path,
            "est_energy_j": sum(c.est_energy_j
                                for c in self.configs.values()),
            "est_usd": sum(c.est_usd for c in self.configs.values()),
            "quality": self.total_quality(dag),
        }


def _pow2_range(lo: int, hi: int) -> list[int]:
    out, n = [], 1
    while n <= hi:
        if n >= lo:
            out.append(n)
        n *= 2
    return out or [lo]


class Scheduler:
    """The greedy hierarchical lever search over execution profiles."""

    def __init__(self, library: AgentLibrary, profiles: ProfileStore,
                 cluster: ClusterManager):
        self.library = library
        self.profiles = profiles
        self.cluster = cluster
        self.evals = 0          # estimate() calls (greedy-search footprint)
        self.prune = True       # dominated-config pruning in plan_task
        self.pruned = 0         # candidate configs skipped by pruning
        # joint (count x batch) level-2 search (DESIGN.md §7.2); False
        # restores the legacy sequential order (count at batch=1, then one
        # batch candidate) — kept for benchmarks/planner_bench.py
        self.joint_batch = True
        # learned routing (DESIGN.md §11): a core.router.Router consulted
        # at level 1 for its covered interfaces; None keeps the static
        # quality-gate + constraint-preference choice byte-identical
        self.router = None
        # level-3 expansion of per-(impl, pool) group bests behind a
        # fan-out-aware pruning bound (DESIGN.md §11.4); False keeps the
        # two-seed expansion (joint + batch=1 winners) — the default, so
        # chosen plans stay byte-identical to the two-seed search
        self.group_expand = False
        self._works: dict[tuple[str, int, int], object] = {}

    # -- estimation ------------------------------------------------------------
    def _work_of(self, impl: AgentImpl, node: TaskNode):
        """Memoized ``impl.work_fn`` — one Work per (impl, token footprint)."""
        key = (impl.name, node.tokens_in, node.tokens_out)
        work = self._works.get(key)
        if work is None:
            work = self._works[key] = impl.work_fn(node.tokens_in,
                                                   node.tokens_out)
        return work

    def estimate(self, node: TaskNode, impl: AgentImpl, pool: str,
                 n_devices: int, n_instances: int = 1, batch: int = 1,
                 paths: int = 1, warm: bool = False,
                 items_done: int = 0, cache_frac: float = 0.0) -> TaskConfig:
        """Cost out one candidate configuration for ``node``.

        Latency comes from the batched execution schedule
        (``ProfileStore.schedule_latency``: full steps plus a remainder
        step charged at its own size, DESIGN.md §7.2) — the same call the
        simulator's ``_duration`` makes, so estimates and actuals agree by
        construction. ``items_done`` prices a *residual* attempt of a
        preempted-and-checkpointed task (DESIGN.md §6.4): only the
        remaining ``work_items - items_done`` items are scheduled, again
        exactly mirroring ``_duration``, so parity also holds for resumed
        tasks. ``cache_frac`` is the resident-prefix hit fraction the
        placement would enjoy (DESIGN.md §9) — it discounts the prefill
        phase through the shared ``CostQuery``, the same one pricing site
        the simulator charges. Energy/$ accrue over compute
        device-seconds; weight-loading is an idle-power period covered by
        the pool floor.
        """
        self.evals += 1
        spec = CATALOG[self.cluster.pools[pool].device]
        work = self._work_of(impl, node)
        if spec.kind == "cpu":
            batch = 1     # batching is an accelerator lever (weights reuse)
        remaining = max(node.work_items - items_done, 0)
        items_per_inst = math.ceil(remaining / n_instances)
        compute = self.profiles.schedule_latency(CostQuery(
            impl=impl, spec=spec, n_devices=n_devices, work=work,
            batch=batch, items=items_per_inst, cache_hit_frac=cache_frac))
        lat = compute if warm else compute + impl.load_time_s
        pf = self.profiles.power_frac(impl, spec, n_devices)
        # active energy/$ accrue over compute time; weight-loading is an
        # idle-power period (covered by the pool idle floor).
        dev_s = compute * n_devices * n_instances * paths
        energy = dev_s * pf * (spec.active_w - spec.idle_w)
        usd = dev_s / 3600.0 * spec.usd_per_hour
        power = n_devices * n_instances * paths * pf * \
            (spec.active_w - spec.idle_w)
        # quality reads the profile store's quality column (measured pins
        # override the declared ladder, DESIGN.md §11); with no pins this
        # is exactly impl.quality
        q = 1.0 - (1.0 - self.profiles.quality(impl.name)) ** paths
        return TaskConfig(impl=impl.name, pool=pool, n_devices=n_devices,
                          n_instances=n_instances, batch=batch, paths=paths,
                          est_latency_s=lat, est_energy_j=energy,
                          est_usd=usd, est_power_w=power, quality=q,
                          warm=warm)

    # -- constraint comparison ---------------------------------------------------
    @staticmethod
    def _objective(cfg: TaskConfig, c: "Constraint | Objective") -> float:
        """Value of one objective (enum shorthand or DSL object)."""
        return as_spec(c).objectives[0].value(cfg)

    @staticmethod
    def _key(cfg: TaskConfig, order) -> tuple:
        """Comparison key under any accepted constraint form."""
        return as_spec(order).key(cfg)

    def _batch_grid(self, impl: AgentImpl, spec, work,
                    items: int) -> list[int]:
        """Batch candidates for the joint (count x batch) search.

        Measured (pinned) rows select among their calibrated batch points —
        the paper's semantics, mirroring ``pinned_counts`` — plus the
        largest feasible batch; analytic rows get the knee-derived grid of
        ``energy.knee_batch_grid`` (1, the knee, a zero-remainder divisor
        of the item count at/past the knee, and ``min(max_batch, items)``).
        """
        if impl.max_batch <= 1 or spec.kind == "cpu" or items <= 1:
            return [1]
        bmax = min(impl.max_batch, items)
        pinned_bs = self.profiles.pinned_batches(impl.name, spec.name)
        if pinned_bs:
            return sorted({b for b in pinned_bs if 1 <= b <= bmax}
                          | {1, bmax})
        return knee_batch_grid(work, spec, items, impl.max_batch,
                               impl.mxu_efficiency)

    def _dominated(self, node: TaskNode, impl: AgentImpl, pool: str,
                   counts: list[int], batches: list[int], warm: bool,
                   incumbent: TaskConfig, order: "ConstraintSpec",
                   cache_frac: float = 0.0, hi_k: int = 1) -> bool:
        """Dominated-config pruning: can *any* (device count x batch) in
        this (impl, pool) group beat the incumbent under ``order``?

        Builds one optimistic pseudo-config whose latency/$/energy/quality
        are simultaneous lower bounds over every level-2 candidate in the
        group. On the analytic roofline, per-item latency is non-increasing
        in both device count (``overhead + K/n``) and batch size (the
        weights stream amortizes), and the remainder schedule satisfies
        ``schedule(n, b) >= items * latency(n, b)``, so the latency bound
        is the grid minimum at ``max(counts)``; per-item device-seconds
        ``latency * n`` are non-decreasing in count (roofline terms x n are
        constant, the overhead share grows), so the $/energy bound is the
        grid minimum at ``min(counts)``. Pinned
        (impl, device) pairs scale off the nearest calibration anchor,
        which is *not* monotone in ``n``, so those groups take the exact
        minimum over the (count x batch) grid (cheap: memoized, short
        lists). Every objective in the DSL is monotone in those four
        quantities and the lexicographic key is monotone componentwise, so
        if even the bound cannot beat the incumbent's key, no real
        candidate can — the whole candidate loop is skipped without
        changing the chosen plan.

        ``hi_k > 1`` makes the bound *fan-out-aware* (the group-best
        level-3 expansion, DESIGN.md §11.4): expanded candidates split
        items across up to ``hi_k`` instances, so the compute part of the
        latency bound divides by ``hi_k`` (load time does not split — the
        greedy coupling the two-seed expansion was built around), while
        the $/energy bound already holds under fan-out (``k * ceil(items
        / k) >= items`` device-seconds, and extra execution paths only
        add). Quality-seeking orders additionally get the max-paths
        quality upper bound ``1 - (1-q)**4``, since expansion may boost
        quality via parallel paths.
        """
        spec = CATALOG[self.cluster.pools[pool].device]
        work = self._work_of(impl, node)
        items = node.work_items

        def per_item(n: int, b: int) -> float:
            # the group's estimates price at cache_frac, so the bound must
            # discount identically to stay a bound *and* stay tight
            return self.profiles.step_latency(CostQuery(
                impl=impl, spec=spec, n_devices=n, work=work, batch=b,
                cache_hit_frac=cache_frac)) / max(b, 1)

        if self.profiles.pinned_counts(impl.name, spec.name):
            per = [min(per_item(n, b) for b in batches) for n in counts]
            lat_lb = items * min(per)
            dev_s_lb = items * min(p * n for p, n in zip(per, counts))
        else:
            # min over the (small) batch grid instead of assuming
            # monotonicity in b: covers the deprecated alpha fallback even
            # for alpha > 1, where items * latency(b) under-cuts only at
            # b = 1 (which the grid always contains)
            lat_lb = items * min(per_item(counts[-1], b) for b in batches)
            dev_s_lb = items * counts[0] * min(per_item(counts[0], b)
                                               for b in batches)
        if hi_k > 1:
            lat_lb /= hi_k
        if not warm:
            lat_lb += impl.load_time_s
        pf_lb = min(self.profiles.power_frac(impl, spec, n) for n in counts)
        q_lb = self.profiles.quality(impl.name)
        if hi_k > 1 and order.seeks_quality:
            q_lb = 1.0 - (1.0 - q_lb) ** 4     # max execution paths
        lb = TaskConfig(
            impl=impl.name, pool=pool, n_devices=counts[0],
            est_latency_s=lat_lb,
            est_energy_j=dev_s_lb * pf_lb * (spec.active_w - spec.idle_w),
            est_usd=dev_s_lb / 3600.0 * spec.usd_per_hour,
            quality=q_lb, warm=warm)
        return order.key(lb) >= order.key(incumbent)

    # -- the greedy hierarchical search -------------------------------------------
    def plan_task(self, node: TaskNode, order,
                  quality_floor: float | dict, *,
                  session: str = "") -> TaskConfig:
        """Choose one ``TaskConfig`` for ``node`` under ``order``.

        The greedy hierarchy (paper §3.3c): (1) implementation by quality
        gate + constraint preference; (2) a *joint* search over device
        count x batch size per candidate (impl, pool) — the batch grid is
        knee-derived (``energy.knee_batch_grid``) or, for measured rows,
        the calibrated batch points, so the count choice sees each pool at
        its best batch rather than locking the count in at batch=1
        (DESIGN.md §7.2; ``joint_batch=False`` restores the sequential
        legacy order); (3) remaining parallelism levers — instance fan-out
        and execution paths — against free resources right now. The
        fan-out loop re-derives the batch grid per candidate ``k``: with
        ``k`` instances the per-instance item count (and its remainder
        step) changes, so the level-2 winner's batch size is no longer
        knee/divisor-aligned.

        Level 3 expands *two* seeds when the joint search is on: the joint
        winner and the batch=1 winner (the sequential hierarchy's level-2
        choice). Batched and unbatched configs respond differently to
        fan-out — splitting items across instances shrinks compute but not
        load time, so a cheap low-load implementation that loses the
        batched level-2 comparison can still win after fan-out. Expanding
        both seeds makes the joint search's candidate set a strict
        superset of the sequential one, so the chosen config is never
        worse under the constraint order. ``group_expand`` widens level 3
        further: *every* per-(impl, pool) group best becomes an expansion
        seed, with the fan-out-aware pruning bound (``_dominated`` with
        ``hi_k``) skipping groups that provably cannot win — plan-equal to
        exhaustively expanding all groups (DESIGN.md §11.4).

        ``session`` (keyword-only) is the serving session the task belongs
        to: (impl, pool) groups holding a resident KV prefix for it are
        priced at their hit fraction (DESIGN.md §9), making a warm cache a
        co-placement reason exactly like warm shells. Empty session (every
        cache-less workload) prices everything at hit 0 — byte-identical
        to the affinity-blind search.
        """
        order = as_spec(order)
        impls = self.library.impls_for(node.agent)
        if not impls:
            raise ValueError(f"no implementation for agent {node.agent!r}")
        floor = (quality_floor.get(node.agent, 0.0)
                 if isinstance(quality_floor, dict) else quality_floor)

        # Level 1 — implementation: quality gate, then constraint preference.
        # The gate reads the profile store's quality column (measured pins
        # from the telemetry loop override the declared ladder, §11); with
        # no pins q_of(i) == i.quality exactly.
        q_of = self.profiles.quality
        ok = [i for i in impls if q_of(i.name) >= floor] or \
            [max(impls, key=lambda i: q_of(i.name))]
        # learned routing (DESIGN.md §11): for covered interfaces the
        # router picks the arm among the floor-passing candidates — the
        # floor stays a hard gate, the router only chooses within it. A
        # None answer (untrained bucket, no exploration) falls through to
        # the static constraint-preference choice below.
        if self.router is not None and self.router.covers(node.agent):
            pick = self.router.route(node, [i.name for i in ok])
            if pick is not None:
                ok = [i for i in ok if i.name == pick]
        if order.seeks_quality:
            cand_impls = sorted(ok, key=lambda i: -q_of(i.name))[:2]
        else:
            cand_impls = ok  # defer to the objective over hw configs

        stats = self.cluster.stats()
        # warm-instance lookup, hoisted out of the candidate loop: one
        # O(instances) scan per plan_task instead of one per (impl, pool)
        warm_set = {(inst.impl, inst.pool)
                    for inst in self.cluster.instances}
        # resident-prefix hit fraction per (impl, pool): the session's best
        # cached instance in the group, clipped to the task's prefix span
        hit_frac: dict[tuple[str, str], float] = {}
        if session and node.prefix_tokens > 0 and node.tokens_in > 0:
            for inst in self.cluster.cached_instances(session):
                tok = min(inst.cache[session].tokens, node.prefix_tokens)
                if tok <= 0:
                    continue
                key = (inst.impl, inst.pool)
                frac = tok / node.tokens_in
                if frac > hit_frac.get(key, 0.0):
                    hit_frac[key] = frac

        # Level 2 — hardware + device count (x batch, when joint) per
        # candidate implementation. With ``group_expand`` the joint search
        # also collects the best config *per (impl, pool) group* — every
        # group becomes a level-3 expansion seed (DESIGN.md §11.4), so
        # pruning must use the fan-out-aware bound: a group may only be
        # skipped when no member can win even after fan-out/paths.
        groups: dict[tuple[str, str], tuple] = {}

        def search(cands, joint: bool,
                   collect: bool = False) -> TaskConfig | None:
            """Best (impl, pool, count[, batch]) config under ``order``."""
            best: TaskConfig | None = None
            for impl in cands:
                for pool_name, st in stats.items():
                    if st["kind"] not in impl.hw_kinds:
                        continue
                    cap = self.cluster.pools[pool_name].capacity
                    lo = impl.min_devices.get(st["kind"], 1)
                    hi = min(impl.max_devices.get(st["kind"], cap), cap)
                    if lo > hi:
                        continue
                    warm = (impl.name, pool_name) in warm_set
                    device = self.cluster.pools[pool_name].device
                    counts = [n for n in self.profiles.pinned_counts(
                                  impl.name, device) if lo <= n <= hi] \
                        or _pow2_range(lo, hi)
                    if joint:
                        batches = self._batch_grid(impl, CATALOG[device],
                                                   self._work_of(impl, node),
                                                   node.work_items)
                    else:
                        batches = [1]
                    cf = hit_frac.get((impl.name, pool_name), 0.0)
                    hi_k = 1
                    if collect and node.chunkable:
                        # max fan-out any member could reach: smallest
                        # device count in the group leaves the most free
                        # instance slots
                        hi_k = min(max(st["free"] // counts[0], 1),
                                   node.work_items)
                    if best is not None and self.prune and self._dominated(
                            node, impl, pool_name, counts, batches, warm,
                            best, order, cf, hi_k=hi_k):
                        self.pruned += len(counts) * len(batches)
                        continue
                    if self.profiles.cache_enabled and \
                            len(counts) * len(batches) > 1:
                        # grid prewarm: one vectorized kernel call
                        # (ProfileStore.schedule_latency_batch) prices every
                        # (count, batch) candidate's memo misses at once;
                        # the estimate loop below then runs on memo hits
                        _work = self._work_of(impl, node)
                        _spec = CATALOG[device]
                        self.profiles.schedule_latency_batch([
                            CostQuery(impl=impl, spec=_spec, n_devices=n,
                                      work=_work, batch=b,
                                      items=node.work_items,
                                      cache_hit_frac=cf)
                            for n in counts for b in batches])
                    gbest: TaskConfig | None = None
                    for n in counts:
                        for b in batches:
                            cfg = self.estimate(node, impl, pool_name, n,
                                                batch=b, warm=warm,
                                                cache_frac=cf)
                            if best is None or self._key(cfg, order) < \
                                    self._key(best, order):
                                best = cfg
                            if collect and (gbest is None or
                                            self._key(cfg, order) <
                                            self._key(gbest, order)):
                                gbest = cfg
                    if collect and gbest is not None:
                        groups[(impl.name, pool_name)] = \
                            (gbest, counts, batches, warm, cf)
            return best

        # Level 3 — remaining parallelism levers, given free resources.
        def expand(best: TaskConfig, legacy_batch: bool) -> TaskConfig:
            """Grow a level-2 seed through the level-3 parallelism levers."""
            impl = self.library.impls[best.impl]
            st = stats[best.pool]
            cf = hit_frac.get((best.impl, best.pool), 0.0)
            free_inst = max(st["free"] // best.n_devices, 1)
            if legacy_batch and impl.max_batch > 1:
                # sequential lever order: one batch candidate, tried only
                # after the count is locked in at batch=1
                b = min(impl.max_batch, node.work_items)
                cand = self.estimate(node, impl, best.pool, best.n_devices,
                                     best.n_instances, b, warm=best.warm,
                                     cache_frac=cf)
                if self._key(cand, order) < self._key(best, order):
                    best = cand
            # fan-out candidates are capped by what fits concurrently right
            # now; guard the cap explicitly — _pow2_range(2, 1) would fall
            # back to [2], offering a two-instance config the cluster
            # cannot place (the simulator would degrade it to one instance,
            # breaking estimate/actual parity)
            hi_k = min(free_inst, node.work_items)
            if node.chunkable and hi_k >= 2:
                spec = CATALOG[self.cluster.pools[best.pool].device]
                work = self._work_of(impl, node)
                for k in _pow2_range(2, hi_k):
                    if legacy_batch:
                        batches = [best.batch]
                    else:
                        # re-derive the batch grid per fan-out candidate:
                        # with k instances the per-instance item count (and
                        # its remainder step) changes, so the level-2
                        # winner's batch size is no longer knee/divisor-
                        # aligned (DESIGN.md §7.2); keeping best.batch in
                        # the grid preserves the old candidate set
                        per_inst = math.ceil(node.work_items / k)
                        batches = sorted(set(
                            self._batch_grid(impl, spec, work, per_inst))
                            | {min(best.batch, max(per_inst, 1))})
                    for b in batches:
                        cand = self.estimate(node, impl, best.pool,
                                             best.n_devices, k, b,
                                             warm=best.warm, cache_frac=cf)
                        if self._key(cand, order) < self._key(best, order):
                            best = cand
            # Execution paths: only when quality leads, on harvestable slack.
            if order.seeks_quality:
                harvest = st["harvestable"] // max(
                    best.n_devices * best.n_instances, 1)
                for p in (2, 4):
                    if p - 1 > harvest:
                        break
                    cand = self.estimate(node, impl, best.pool,
                                         best.n_devices, best.n_instances,
                                         best.batch, paths=p, warm=best.warm,
                                         cache_frac=cf)
                    if self._key(cand, order) < self._key(best, order):
                        best = cand
            return best

        collect = self.group_expand and self.joint_batch
        best = search(cand_impls, self.joint_batch, collect=collect)
        if best is None:   # quality-gated impls don't fit this cluster
            groups.clear()
            cand_impls = sorted(impls, key=lambda i: -q_of(i.name))
            best = search(cand_impls, self.joint_batch, collect=collect)
        if best is None:
            raise ValueError(
                f"no (pool x devices) fits agent {node.agent!r}; "
                f"pools: {list(stats)}")

        final = expand(best, legacy_batch=not self.joint_batch)
        expanded = {(best.impl, best.pool)}
        if self.joint_batch:
            # second seed: the sequential hierarchy's batch=1 level-2
            # winner, expanded through the legacy lever order — keeps the
            # joint candidate set a superset of the sequential one
            seed = search(cand_impls, joint=False)
            if seed is not None and seed != best:
                expanded.add((seed.impl, seed.pool))
                alt = expand(seed, legacy_batch=True)
                if self._key(alt, order) < self._key(final, order):
                    final = alt
        if collect:
            # Level-3 expansion of every remaining (impl, pool) group best
            # (DESIGN.md §11.4). A group whose fan-out-aware lower bound
            # cannot beat the incumbent is skipped — sound because the
            # bound covers everything ``expand`` can build from the seed
            # (fan-out up to the free-slot cap, any batch, paths <= 4) and
            # ``final`` only ever improves under ``order``.
            for gkey in sorted(groups):
                if gkey in expanded:
                    continue
                gcfg, counts, batches, warm, cf = groups[gkey]
                impl = self.library.impls[gcfg.impl]
                hi_k = 1
                if node.chunkable:
                    hi_k = min(max(stats[gcfg.pool]["free"]
                                   // gcfg.n_devices, 1), node.work_items)
                if self.prune and self._dominated(
                        node, impl, gcfg.pool, counts, batches, warm,
                        final, order, cf, hi_k=hi_k):
                    self.pruned += 1
                    continue
                alt = expand(gcfg, legacy_batch=False)
                if self._key(alt, order) < self._key(final, order):
                    final = alt
        return final

    def split_shares(self, dag: DAG, order,
                     quality_floor: float | dict = 0.85, *,
                     session: str = "") \
            -> dict[str, tuple[float, float]]:
        """Per-task ``(lat_frac, cost_frac)`` shares of workflow-level terms.

        A pilot plan under the legacy even split supplies per-task latency
        and cost estimates. The deadline share of task ``t`` is
        ``lat(t) / L(t)`` with ``L(t)`` the longest path *through* ``t``:
        tasks on ``dag.critical_path`` receive slack proportional to their
        latency share of the path (their shares sum to exactly 1 along it,
        handing the whole deadline to the path that needs it), and for every
        root-to-leaf path the shares sum to <= 1, so per-task feasibility
        implies workflow feasibility. The budget share is ``t``'s pilot cost
        share of the whole DAG — spend is additive across tasks, so shares
        sum to 1.
        """
        spec = as_spec(order)
        pilot_spec = spec.per_task(len(dag))
        pilot = {tid: self.plan_task(dag.nodes[tid], pilot_spec,
                                     quality_floor, session=session)
                 for tid in dag.topo_order}
        eps = 1e-12
        lat = {tid: max(cfg.est_latency_s, eps)
               for tid, cfg in pilot.items()}
        # longest path through t = forward finish + backward tail - own
        fwd: dict[str, float] = {}
        for tid in dag.topo_order:
            fwd[tid] = lat[tid] + max((fwd[d]
                                       for d in dag.nodes[tid].deps),
                                      default=0.0)
        bwd: dict[str, float] = {}
        for tid in reversed(dag.topo_order):
            bwd[tid] = lat[tid] + max((bwd[s]
                                       for s in dag.successors(tid)),
                                      default=0.0)
        cost = {tid: cfg.est_usd for tid, cfg in pilot.items()}
        total_cost = sum(cost.values())
        if total_cost <= 0:   # free tools everywhere: fall back to energy
            cost = {tid: cfg.est_energy_j for tid, cfg in pilot.items()}
            total_cost = sum(cost.values())
        shares = {}
        for tid in dag.topo_order:
            through = fwd[tid] + bwd[tid] - lat[tid]
            lat_frac = min(lat[tid] / max(through, eps), 1.0)
            cost_frac = (cost[tid] / total_cost if total_cost > 0
                         else 1.0 / len(dag))
            shares[tid] = (lat_frac, cost_frac)
        return shares

    def plan(self, dag: DAG, order,
             quality_floor: float | dict = 0.85, *,
             session: str = "") -> ExecutionPlan:
        """Choose a ``TaskConfig`` for every task of ``dag``.

        ``order`` is any accepted constraint form (seed enum member,
        sequence, DSL objective, ``ConstraintSpec``); ``quality_floor`` is
        a scalar or per-interface dict gating level-1 implementation
        choice. Workflow-level deadline/budget terms are first split
        across tasks by the critical-path-weighted shares of
        ``split_shares`` (DESIGN.md §6.1); plain objectives plan each task
        directly. ``session`` threads the job's serving session into
        :meth:`plan_task` for KV-affinity pricing (DESIGN.md §9).
        """
        spec = as_spec(order)
        plan = ExecutionPlan()
        if spec.has_workflow_terms:
            # critical-path-weighted split of deadline/budget terms: tasks
            # on the critical path get slack proportional to their pilot
            # latency/cost share, admitting tighter SLOs than the even split
            shares = self.split_shares(dag, spec, quality_floor,
                                       session=session)
            for tid in dag.topo_order:
                plan.configs[tid] = self.plan_task(
                    dag.nodes[tid], spec.for_share(*shares[tid]),
                    quality_floor, session=session)
            return plan
        for tid in dag.topo_order:
            plan.configs[tid] = self.plan_task(dag.nodes[tid], spec,
                                               quality_floor,
                                               session=session)
        return plan

    # -- pinned plans (imperative baseline) -----------------------------------------
    def pin(self, node: TaskNode, impl_name: str, pool: str,
            n_devices: int) -> TaskConfig:
        """Fixed configuration: no levers (paper Listing-1 semantics)."""
        impl = self.library.impls[impl_name]
        return self.estimate(node, impl, pool, n_devices, n_instances=1,
                             batch=1, paths=1, warm=False)

    def search_space_size(self, node: TaskNode) -> int:
        """|configs| the full cross-product would visit (overheads bench)."""
        total = 0
        stats = self.cluster.stats()
        for impl in self.library.impls_for(node.agent):
            for pool_name, st in stats.items():
                if st["kind"] not in impl.hw_kinds:
                    continue
                cap = self.cluster.pools[pool_name].capacity
                lo = impl.min_devices.get(st["kind"], 1)
                hi = min(impl.max_devices.get(st["kind"], cap), cap)
                if lo > hi:
                    continue
                nd = len(_pow2_range(lo, hi))
                ni = len(_pow2_range(1, max(node.work_items, 1)))
                nb = len(_pow2_range(1, max(impl.max_batch, 1)))
                total += nd * ni * nb * 3   # 3 = paths in {1,2,4}
        return total
