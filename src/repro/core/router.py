"""Learned routing: featurized, seeded bandit over implementation arms.

DESIGN.md §11. The :class:`Router` replaces the static keyword-vs-vector
retrieval lever (``configs/workflow_rag.py``) with a *learned* one: the
scheduler's level-1 implementation choice consults the router for covered
interfaces, and the router picks an arm from the quality-floor-passing
candidates by seeded epsilon-greedy over per-(interface, feature-bucket)
reward weights. Routing is a pure function of ``(seed, weights, task)`` —
no state mutates during planning or simulation — so identical (seed,
telemetry log) pairs yield byte-identical routing decisions and traces
stay replayable.

Learning happens *between* runs: the :class:`OfflineEvaluator` replays a
:class:`~repro.core.telemetry.TelemetryStore` and returns a new router
whose weights are the per-bucket mean rewards (quality attainment minus a
cost penalty) — a pure function of the log. The same evaluator calibrates
measured per-impl quality back into the
:class:`~repro.core.profiles.ProfileStore` quality column, closing the
loop for quality-aware *model selection* under a ``quality_floor``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from types import MappingProxyType
from typing import Mapping

from .telemetry import TelemetryStore, featurize_node

#: weight table: (interface, feature bucket) -> {arm -> mean reward}
Weights = dict[tuple[str, str], dict[str, float]]


@dataclass(frozen=True)
class Router:
    """Seeded epsilon-greedy policy over implementation arms.

    Frozen: updates build a *new* router (``with_weights``), bumping
    ``version`` so plan caches keyed on :meth:`fingerprint` invalidate.
    ``route`` draws its exploration coin from ``random.Random(str)`` keyed
    by ``(seed, task id, feature bucket)`` — independent of dispatch or
    planning order, stable across processes (SHA-512 string seeding).
    """

    interfaces: tuple[str, ...] = ("retrieve",)
    epsilon: float = 0.1
    seed: int = 0
    weights: Mapping[tuple[str, str], Mapping[str, float]] = \
        field(default_factory=dict)
    version: int = 0

    def __post_init__(self):
        if not 0.0 <= self.epsilon <= 1.0:
            raise ValueError(f"epsilon must be in [0, 1], "
                             f"got {self.epsilon}")
        # freeze the nested weight table so a shared router can't drift
        frozen = MappingProxyType({
            k: MappingProxyType(dict(v)) for k, v in dict(
                self.weights).items()})
        object.__setattr__(self, "weights", frozen)

    # -- identity ------------------------------------------------------------
    def covers(self, interface: str) -> bool:
        """True when this router decides the given interface's impl."""
        return interface in self.interfaces

    def fingerprint(self) -> tuple:
        """Hashable identity for plan-cache keys: any change to what the
        router would answer changes the fingerprint."""
        return (self.interfaces, self.epsilon, self.seed, self.version)

    # -- the decision --------------------------------------------------------
    def route(self, node, arms: list[str]) -> str | None:
        """Pick one arm for ``node`` from the floor-passing ``arms``.

        Exploit: the arm with the highest learned weight in the task's
        feature bucket (ties break lexicographically — deterministic).
        Explore: with probability ``epsilon`` (seeded coin keyed by task
        identity + bucket), a uniform seeded pick over ``arms``. Returns
        ``None`` when the bucket has no weights and no exploration fires —
        the scheduler then falls through to its constraint-preference
        choice, so an untrained router degrades to the static lever.
        """
        if not arms:
            return None
        bucket = featurize_node(node).bucket()
        rng = random.Random(f"{self.seed}:route:{node.id}:{bucket}")
        u = rng.random()
        if u < self.epsilon:
            return sorted(arms)[int(rng.random() * len(arms)) % len(arms)]
        table = self.weights.get((node.agent, bucket))
        if not table:
            return None
        known = [a for a in arms if a in table]
        if not known:
            return None
        return max(sorted(known), key=lambda a: table[a])

    # -- functional updates ---------------------------------------------------
    def with_weights(self, weights: Weights,
                     epsilon: float | None = None) -> "Router":
        """A new router carrying ``weights`` (and optionally a new
        exploration rate), with ``version`` bumped past this one's."""
        return Router(interfaces=self.interfaces,
                      epsilon=self.epsilon if epsilon is None else epsilon,
                      seed=self.seed, weights=weights,
                      version=self.version + 1)

    def weight_churn(self, other: "Router") -> int:
        """Number of (interface, bucket, arm) weights that differ between
        two routers — the neutral telemetry metric the bench reports."""
        mine = {(k, a): v for k, tbl in self.weights.items()
                for a, v in tbl.items()}
        theirs = {(k, a): v for k, tbl in other.weights.items()
                  for a, v in tbl.items()}
        keys = set(mine) | set(theirs)
        return sum(1 for k in keys if mine.get(k) != theirs.get(k))


class OfflineEvaluator:
    """Replays a telemetry log into routing weights and quality pins.

    The bandit update rule (DESIGN.md §11): per (interface, feature
    bucket, arm), weight = mean over the log's records of

        reward = min(quality / quality_target, 1)
                 - cost_weight * cost / mean_cost(interface)

    Quality saturates at the target — exceeding the bar buys nothing, so
    the cost term decides among arms that attain it, which is exactly the
    quality-floor semantics the planner enforces. Costs normalize by the
    interface's mean over the same log (self-scaling, no tuning constant
    carries units). Both passes are pure functions of the record list:
    the same log always produces the same weights.

    **Drift.** A plain lifetime mean never forgets: once an arm has
    accumulated enough history, a regression in its *current* behavior
    (quality drop after a model swap, a pool migration doubling cost) is
    averaged away by the stale majority, and the router keeps routing to
    it. ``half_life_s`` fixes this by exponentially decaying each record's
    weight with its age — ``0.5 ** (age / half_life_s)`` against the
    newest record in the log (sim-time, so replays stay deterministic) —
    in *both* passes: the cost normalizer and the reward means.
    ``window_s`` is the hard variant: records older than the window are
    dropped outright. Both default off, reproducing the lifetime mean
    exactly.
    """

    def __init__(self, quality_target: float = 0.85,
                 cost_weight: float = 0.2, cost_key: str = "energy_j",
                 half_life_s: float | None = None,
                 window_s: float | None = None):
        if not 0.0 < quality_target <= 1.0:
            raise ValueError("quality_target must be in (0, 1]")
        if cost_weight < 0.0:
            raise ValueError("cost_weight must be >= 0")
        if cost_key not in ("energy_j", "usd", "latency_s"):
            raise ValueError(f"unknown cost_key {cost_key!r}")
        if half_life_s is not None and half_life_s <= 0.0:
            raise ValueError("half_life_s must be > 0")
        if window_s is not None and window_s <= 0.0:
            raise ValueError("window_s must be > 0")
        self.quality_target = quality_target
        self.cost_weight = cost_weight
        self.cost_key = cost_key
        self.half_life_s = half_life_s
        self.window_s = window_s

    def _weights_of(self, records) -> "list[tuple]":
        """(record, age-weight) pairs under the decay/window policy.

        Ages are measured against the newest record's sim-time — a pure
        function of the log, unlike wall clocks — so the same store
        always yields the same weights.
        """
        if not records:
            return []
        now = max(r.t for r in records)
        rows = []
        for r in records:
            age = now - r.t
            if self.window_s is not None and age > self.window_s:
                continue
            w = 0.5 ** (age / self.half_life_s) \
                if self.half_life_s is not None else 1.0
            rows.append((r, w))
        return rows

    # -- the update rule ------------------------------------------------------
    def rewards(self, store: TelemetryStore) -> Weights:
        """Per-(interface, bucket, arm) mean rewards from the log
        (age-weighted means under ``half_life_s``/``window_s``)."""
        rows = self._weights_of(store.records)
        cost_of = {r: getattr(r, self.cost_key) for r, _ in rows}
        scale: dict[str, tuple[float, float]] = {}
        for r, w in rows:
            tot, n = scale.get(r.interface, (0.0, 0.0))
            scale[r.interface] = (tot + w * cost_of[r], n + w)
        mean_cost = {i: (tot / n if n and tot > 0 else 1.0)
                     for i, (tot, n) in scale.items()}
        acc: dict[tuple[str, str], dict[str, tuple[float, float]]] = {}
        for r, w in rows:
            reward = (min(r.quality / self.quality_target, 1.0)
                      - self.cost_weight * cost_of[r]
                      / mean_cost[r.interface])
            tbl = acc.setdefault((r.interface, r.features.bucket()), {})
            tot, n = tbl.get(r.impl, (0.0, 0.0))
            tbl[r.impl] = (tot + w * reward, n + w)
        return {key: {arm: tot / n for arm, (tot, n) in sorted(tbl.items())}
                for key, tbl in sorted(acc.items())}

    def update(self, router: Router, store: TelemetryStore,
               epsilon: float | None = None) -> Router:
        """A new router whose weights replay the log (pure function)."""
        return router.with_weights(self.rewards(store), epsilon=epsilon)

    # -- quality calibration (the model-selection half of the loop) -----------
    def calibrate_profiles(self, store: TelemetryStore, profiles,
                           min_count: int = 3) -> dict[str, float]:
        """Pin measured mean quality per impl into the profile store.

        Gives the planner's quality column (``ProfileStore.quality``) the
        telemetry-measured values, so ``quality_floor`` gating and the
        level-1 implementation choice run on *observed* quality instead of
        the declared ladder — an impl whose measured quality clears a
        floor its declared score missed becomes selectable (and vice
        versa). Returns the pins applied.
        """
        pins = store.mean_quality(min_count=min_count)
        for impl, q in pins.items():
            profiles.pin_quality(impl, q)
        return pins
