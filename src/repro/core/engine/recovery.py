"""Recovery layer: fault injection, retry/backoff, hedges, crash repair.

All handlers here are inert when ``Simulator.faults`` is ``None`` — no
events of these kinds are ever pushed then, so a fault-free run's heap,
float-op order and counters stay byte-identical to an engine without the
subsystem (DESIGN.md §10; the golden tests pin it). Fault draws are pure
functions of (seed, workflow, task, attempt), so the fast and reference
dispatch paths see identical fault streams regardless of dispatch order.
"""
from __future__ import annotations

import bisect
import heapq

from ..cluster import Instance, Lease
from ..scheduler import ExecutionPlan
from .events import TraceEntry, _Running, _WfState


class RecoveryMixin:
    """Crash/fail/retry/hedge event handlers mixed into ``Engine``."""

    def seed_faults(self):
        """Arm the per-pool crash processes (called once, at run start)."""
        fp = self.faults
        fp.validate_pools(self.cluster.pools)
        # crash-shrunk pools must make over-sized plans *wait* for repair,
        # not permanently degrade them: remember the nominal capacities as
        # the no-autoscaler pool limit (Simulator._pool_limit)
        self.sim._nominal_caps = {name: p.capacity
                                  for name, p in self.cluster.pools.items()}
        for pool in sorted(fp.instance_mtbf_s):
            rng = self._pool_rng[pool] = fp.pool_stream(pool)
            gap = rng.expovariate(1.0 / fp.instance_mtbf_s[pool])
            heapq.heappush(self.events,
                           (gap, next(self.ctr), "crash", pool))

    def on_fault_event(self, kind: str, payload) -> None:
        """Dispatch one fault-machinery heap event."""
        if kind == "crash":
            self.on_crash(payload)
        elif kind == "repair":
            self.on_repair(payload)
        elif kind == "tfail":
            wid, tid, attempt = payload
            self.fail_task(wid, tid, attempt, "fault")
        elif kind == "retry":
            self.on_retry(payload)
        elif kind == "hedge":
            self.on_hedge(payload)
        elif kind == "hfinish":
            self.on_hfinish(payload)
        else:
            raise RuntimeError(f"unknown event kind {kind!r}")

    def fail_task(self, wid: str, tid: str, t_attempt: int, reason: str,
                  crashed: Instance | None = None):
        """A running task just failed (transient fault or instance crash).

        Like ``cancel_task``, but: surviving shells go *idle* instead of
        being evicted (the software failed, not the hardware), the failure
        counts against the workflow's retry budget, and the task re-queues
        only after a seeded exponential backoff (the retry event) — or the
        workflow dead-letters once the budget is exhausted. Chunkable tasks
        checkpoint their completed steps through the same ``_refund``
        inversion preemption uses, so a retry resumes from ``items_done``.
        """
        st = self.wfs[wid]
        if st.attempt.get(tid, 0) != t_attempt:
            return                      # stale: that execution already ended
        rec = self.running.pop((wid, tid), None)
        if rec is None:
            return
        t = self.t
        if self.hedges:
            self._kill_hedge(wid, tid)  # a hedge dies with its primary
        st.started.discard(tid)
        st.attempt[tid] = t_attempt + 1
        for lease in rec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in rec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst is crashed or inst not in self.cluster.instances:
                continue
            inst.busy_until = t         # surviving shells idle immediately
        if rec.insts:
            # availability moved (shells idled / died): wake blocked keys
            self.cluster.free_epoch[rec.cfg.pool] += 1
            self.cluster.epoch_total += 1
        self._refund(rec, st, tid, t)
        self.faults_injected += 1
        if reason == "fault":
            self.task_faults += 1
        if self.collect_trace:
            self.trace.append(TraceEntry(
                wid, tid, rec.cfg.impl, rec.cfg.pool, rec.ndev, rec.start,
                t, note=("crashed" if reason == "crash" else "failed")))
        if st.dead:
            return      # already dead-lettered: this run just settled
        fails = st.fails.get(tid, 0) + 1
        st.fails[tid] = fails
        if fails >= self.retry.attempts_for(st.tenant):
            if self.log is not None:
                self.log.append(f"[{t:8.1f}s] {reason} {wid}:{tid} "
                                f"(attempt {fails}); retries exhausted")
            self._dead_letter(wid, st)
            return
        delay = self.retry.backoff_s(
            fails, self.faults.retry_jitter(wid, tid, fails))
        heapq.heappush(self.events,
                       (t + delay, next(self.ctr), "retry",
                        (wid, tid, fails)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] {reason} {wid}:{tid} "
                            f"(attempt {fails}); retry in {delay:.1f}s")

    def _dead_letter(self, wid: str, st: _WfState):
        """Abandon a workflow whose task exhausted its retry budget."""
        self.dead_letters += 1
        st.dead = True
        if st.ready and not self.pol.dynamic:
            j = bisect.bisect_left(self.active_ready, (st.sort_key, wid))
            if j < len(self.active_ready) and \
                    self.active_ready[j][1] == wid:
                del self.active_ready[j]
        st.ready.clear()
        self._deactivate(wid, st)
        # its unfinished tasks are no longer upcoming demand
        self.cluster.abandon_workflow(wid)
        self.incomplete -= 1
        if self.log is not None:
            self.log.append(f"[{self.t:8.1f}s] dead-letter {wid} "
                            f"({st.tenant})")

    def on_crash(self, pool: str):
        """Exponential-MTBF instance crash on ``pool``.

        The victim dies through ``evict_instance`` — its lease is released
        and its KV/prefix entries die with the shell — and the crashed
        device group leaves the pool's capacity until a seeded repair
        restores it (the autoscaler may backfill sooner). The draws happen
        unconditionally so the crash clock is a pure function of the seed,
        whatever the cluster looks like when it fires.
        """
        fp = self.faults
        rng = self._pool_rng[pool]
        u_victim = rng.random()
        gap = rng.expovariate(1.0 / fp.instance_mtbf_s[pool])
        repair = rng.expovariate(1.0 / fp.repair_s)
        if self.incomplete <= 0:
            return      # run drained: stop the crash process
        t = self.t
        live = list(self.cluster.pool_instances(pool))
        if live:
            victim = live[min(int(u_victim * len(live)), len(live) - 1)]
            self.instance_crashes += 1
            lease = victim.lease
            owner = (self.lease_owner.pop(lease.id, None)
                     if lease is not None else None)
            n = victim.n_devices
            self.cluster.evict_instance(victim, t)
            cap = self.cluster.pools[pool].capacity
            self.cluster.set_capacity(pool, cap - n, t)
            heapq.heappush(self.events,
                           (t + repair, next(self.ctr), "repair",
                            (pool, n)))
            if self.log is not None:
                self.log.append(f"[{t:8.1f}s] crash {victim.impl} "
                                f"({n}x{pool}); repair in {repair:.0f}s")
            if owner is None:
                self.faults_injected += 1   # idle shell (KV died with it)
            elif len(owner) == 3:
                self.faults_injected += 1
                self._kill_hedge(owner[1], owner[2])
            else:
                wid, tid = owner
                self.fail_task(wid, tid,
                               self.wfs[wid].attempt.get(tid, 0),
                               "crash", crashed=victim)
        if self.incomplete > 0:
            heapq.heappush(self.events,
                           (t + gap, next(self.ctr), "crash", pool))

    def on_repair(self, payload):
        """Restore a crashed device group's capacity (clamped to the pool
        limit, so an autoscaler keeps authority over the final size)."""
        pool, n = payload
        cap = self.cluster.pools[pool].capacity
        new_cap = min(cap + n, self.sim._pool_limit(pool))
        if new_cap > cap:
            self.cluster.set_capacity(pool, new_cap, self.t)
            if self.log is not None:
                self.log.append(f"[{self.t:8.1f}s] repair +{n}x{pool}")

    def on_retry(self, payload):
        """Backoff elapsed: requeue the failed task (maybe replanned)."""
        wid, tid, fails = payload
        st = self.wfs.get(wid)
        if st is None or st.dead or st.fails.get(tid, 0) != fails:
            return
        if tid in st.done or tid in st.started:
            return
        self.fault_retries += 1
        rp = self.retry
        if rp.replan_after > 0 and fails >= rp.replan_after \
                and st.plan_fn is not None:
            # graceful degradation: under retry pressure, replan the
            # workflow's remaining tasks against the *live* (possibly
            # capacity-degraded) cluster — the planner picks a cheaper
            # impl/config within the quality floor if the original no
            # longer fits well
            self._degrade_replan(wid, st)
        self._push_ready(wid, st, tid)
        if self.log is not None:
            self.log.append(f"[{self.t:8.1f}s] retry {wid}:{tid} "
                            f"(failure {fails})")

    def _degrade_replan(self, wid: str, st: _WfState):
        """Re-plan remaining tasks on the degraded cluster (copy-on-write)."""
        try:
            fresh = st.plan_fn()
        except Exception:
            return                      # planning may fail mid-degradation
        cfgs = dict(st.plan.configs)
        changed = False
        for tid, cfg in fresh.configs.items():
            if tid in st.done or tid in st.started:
                continue                # only not-yet-run tasks may move
            if cfgs.get(tid) != cfg:
                cfgs[tid] = cfg
                changed = True
        if changed:
            st.plan = ExecutionPlan(cfgs)
            self.degrade_replans += 1
            if self.log is not None:
                self.log.append(f"[{self.t:8.1f}s] degrade-replan {wid}")

    def on_hedge(self, payload):
        """Straggler-detection event: the task has now run for
        ``hedge_threshold x`` its estimate — launch a duplicate if it is
        still running and resources fit."""
        wid, tid, attempt = payload
        st = self.wfs.get(wid)
        if st is None or st.dead or st.attempt.get(tid, 0) != attempt:
            return
        rec = self.running.get((wid, tid))
        if rec is None or (wid, tid) in self.hedges:
            return
        self._start_hedge(wid, tid, attempt, st, rec)

    def _start_hedge(self, wid: str, tid: str, attempt: int,
                     st: _WfState, rec: _Running):
        """Duplicate a straggling run on other shells (first finish wins).

        Hedges are opportunistic: they use genuinely free capacity only —
        no eviction, no preemption — and are themselves preemptible and
        crash-prone, but never straggle or fault (one level of recursion
        is enough). The duplicate prices the same residual the primary
        did (``items_done0``), sessionless (its shells hold no prefix).
        """
        t = self.t
        cluster = self.cluster
        cfg = rec.cfg
        node = st.dag.nodes[tid]
        impl = self.impls[cfg.impl]
        spec = self.specs[cfg.pool]
        harvest = st.tenant == "harvest"
        leases: list[Lease] = []
        insts: list[Instance] = []
        new_inst = 0
        if self.is_model[cfg.impl]:
            for i in cluster.warm_instances(cfg.impl, cfg.pool,
                                            cfg.n_devices):
                if len(insts) >= rec.n_inst:
                    break
                if i.busy_until <= t and i not in rec.insts:
                    insts.append(i)
            provisioned = []
            while len(insts) < rec.n_inst:
                lease = cluster.alloc(cfg.pool, cfg.n_devices, t,
                                      harvest=harvest)
                if lease is None:
                    break
                inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                                warm_since=t, lease=lease,
                                cache_cap_bytes=self.sim._cache_cap(cfg))
                cluster.add_instance(inst)
                insts.append(inst)
                provisioned.append(inst)
                new_inst += 1
            if len(insts) < rec.n_inst:
                for inst in provisioned:    # couldn't fit: roll back
                    cluster.evict_instance(inst, t)
                return
        else:
            lease = cluster.alloc(cfg.pool, cfg.n_devices * rec.n_inst, t,
                                  harvest=harvest)
            if lease is None:
                return
            leases.append(lease)
        n_inst = rec.n_inst
        dur, compute, per_inst = self.sim._duration(
            node, cfg, n_inst, new_inst, rec.items_done0, 0.0)
        pmult = cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
        dur *= pmult
        end = t + dur
        compute_begin = end - compute * pmult
        for inst in insts:
            inst.busy_until = end
        ndev = cfg.n_devices * n_inst
        dev_s = compute * ndev * cfg.paths
        pf = self.profiles.power_frac(impl, spec, cfg.n_devices)
        self.ledger.charge_active(spec, dev_s, utilization=pf,
                                  pool=cfg.pool)
        self.busy[cfg.pool] = self.busy.get(cfg.pool, 0.0) + dev_s
        self.served.charge(st.tenant, dev_s)
        howner = ("h", wid, tid)
        for lease in leases:
            self.lease_owner[lease.id] = howner
        for inst in insts:
            if inst.lease is not None:
                self.lease_owner[inst.lease.id] = howner
        self.hedges[(wid, tid)] = _Running(
            cfg, leases, insts, t, end, compute_begin, ndev, dev_s, pf,
            note="hedge+" + ("cold" if new_inst else "warm"),
            n_inst=n_inst, batch=(1 if spec.kind == "cpu" else cfg.batch),
            items_done0=rec.items_done0, items_per_inst=per_inst,
            resumable=node.chunkable)
        self.hedges_launched += 1
        heapq.heappush(self.events, (end, next(self.ctr), "hfinish",
                                     (wid, tid, attempt)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] hedge {wid}:{tid} on "
                            f"{ndev}x{cfg.pool} (primary "
                            f"{rec.slow:.1f}x slow)")

    def _kill_hedge(self, wid: str, tid: str):
        """Cancel an in-flight hedge; its executed work is discarded."""
        hrec = self.hedges.pop((wid, tid), None)
        if hrec is None:
            return
        t = self.t
        for lease in hrec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in hrec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst in self.cluster.instances:
                inst.busy_until = t
        if hrec.insts:
            self.cluster.free_epoch[hrec.cfg.pool] += 1
            self.cluster.epoch_total += 1
        # salvage=False: the loser's completed steps don't checkpoint (the
        # winner runs the full residual itself — crediting both would
        # double-count items), so executed = wasted, unexecuted = refunded
        self._refund(hrec, self.wfs[wid], tid, t, salvage=False)
        if self.collect_trace:
            self.trace.append(TraceEntry(
                wid, tid, hrec.cfg.impl, hrec.cfg.pool, hrec.ndev,
                hrec.start, t, note="hedge_lost"))

    def on_hfinish(self, payload):
        """A hedge finished first: cancel the straggling primary and
        complete the task through the duplicate's run."""
        wid, tid, attempt = payload
        hrec = self.hedges.get((wid, tid))
        st = self.wfs.get(wid)
        if hrec is None or st is None or \
                st.attempt.get(tid, 0) != attempt:
            return
        del self.hedges[(wid, tid)]
        t = self.t
        prec = self.running.pop((wid, tid), None)
        if prec is not None:
            # invalidate the primary's in-flight finish event
            st.attempt[tid] = attempt + 1
            for lease in prec.leases:
                self.lease_owner.pop(lease.id, None)
                if self.cluster.lease_active(lease):
                    self.cluster.release(lease, t)
            for inst in prec.insts:
                if inst.lease is not None:
                    self.lease_owner.pop(inst.lease.id, None)
                if inst in self.cluster.instances:
                    inst.busy_until = t
            if prec.insts:
                self.cluster.free_epoch[prec.cfg.pool] += 1
                self.cluster.epoch_total += 1
            self._refund(prec, st, tid, t, salvage=False)
            if self.collect_trace:
                self.trace.append(TraceEntry(
                    wid, tid, prec.cfg.impl, prec.cfg.pool, prec.ndev,
                    prec.start, t, note="hedge_beat_primary"))
        self.hedges_won += 1
        self._complete(wid, tid, st, hrec)
