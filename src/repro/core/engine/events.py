"""Event layer: heap, clock, and the same-timestamp drain loops.

The engine is a single event heap of ``(t, counter, kind, payload)``
tuples. Both run modes drain every event sharing a timestamp before
dispatching once for that timestamp:

- ``loop_closed`` (``Simulator.run``): all submissions are queued up
  front, so each drain pops the full same-``t`` batch before handling it.
  Simultaneous arrivals are all admitted (and planned) before any of them
  starts work, so admission-policy order holds for same-time tenants and
  identical tenants admitted into the same cluster state share one plan
  via the plan cache.
- ``loop_open`` (``Simulator.run_open_loop``): arrivals are pulled lazily
  (one look-ahead submission in the heap at a time) and handlers may chain
  new same-``t`` events (zero-lag scale applies, same-``t`` arrivals), so
  the drain re-checks the heap head after each handler. Same-``t`` events
  pop in push-counter order, so handling them as they pop matches handling
  them as a batch.

Finish coalescing (DESIGN.md §12): a contiguous same-``t`` run of
``finish`` events is handed to ``on_finish_batch`` as one group, which
amortizes the per-finish epoch bumps (one per touched pool) and the
rebalance scan (one per group) across same-step completions. Only
*contiguous* finish runs coalesce: any interleaved non-finish event
(arrival, scale, fault) flushes the group first, so a same-``t`` arrival
that re-raises demand an earlier finish just zeroed still observes exactly
the cluster state the uncoalesced engine would have shown it. Finish
handlers push no events, so the run collected from the heap head is
exactly the run the uncoalesced loop would have popped one-by-one.

The state records (``TraceEntry``, ``Submission``, ``_WfState``,
``_Running``) live here: they are what events carry and what the drain
mutates.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

from ..admission import Admission
from ..cluster import Instance, Lease
from ..dag import DAG
from ..scheduler import ExecutionPlan, TaskConfig


@dataclass(frozen=True)
class TraceEntry:
    """One task execution interval in the Fig-3-style trace."""

    workflow: str
    task: str
    impl: str
    pool: str
    devices: int              # total devices (n_devices * n_instances)
    start: float
    end: float
    note: str = ""


@dataclass(slots=True)
class Submission:
    """One tenant's workflow submission to the multi-tenant engine.

    ``plan`` may be ``None`` with a ``plan_fn`` instead: the engine calls it
    when the workflow is admitted (its arrival event fires), so scheduling
    sees the live cluster state. ``slo_s``/``scenario`` feed the open-loop
    SLO-attainment metrics and are ignored by the closed-loop ``run``.
    """

    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None
    slo_s: float | None = None
    scenario: str = ""
    session: str = ""            # serving-session identity (KV affinity)


@dataclass(slots=True)
class _WfState:
    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None
    done: set[str] = field(default_factory=set)
    started: set[str] = field(default_factory=set)
    finish: float = 0.0
    attempt: dict[str, int] = field(default_factory=dict)
    # work-items checkpointed per task: survived preemption, never re-run
    items_done: dict[str, int] = field(default_factory=dict)
    slo_s: float | None = None
    scenario: str = ""
    session: str = ""
    # indexed ready set: (topo_rank, task_id), kept sorted by insort
    ready: list = field(default_factory=list)
    adm: Admission | None = None
    sort_key: tuple | None = None     # static-policy dispatch key
    # fault machinery (inert when faults=None)
    dead: bool = False                # dead-lettered: retries exhausted
    fails: dict[str, int] = field(default_factory=dict)   # fault count/task


@dataclass(slots=True)
class _Running:
    """Book-keeping for an in-flight task (needed to preempt it)."""

    cfg: TaskConfig
    leases: list[Lease]
    insts: list[Instance]
    start: float
    end: float
    compute_begin: float      # start + weights-load wall time
    ndev: int
    dev_s: float
    pf: float
    note: str
    n_inst: int               # instances actually acquired (may be < plan)
    batch: int                # effective batch (CPU pools force 1)
    items_done0: int          # items already checkpointed before this run
    items_per_inst: int       # the split _duration charged (refund inverts it)
    resumable: bool           # chunkable: completed steps survive preempt
    session: str = ""         # serving session the run belongs to
    cache_frac: float = 0.0   # prefix-cache hit fraction priced into dur
    slow: float = 1.0         # straggler multiplier on the compute window


class EventLoopMixin:
    """The two drain loops over the engine's event heap.

    Mixed into ``Engine`` alongside the dispatch/ledger/recovery layers;
    relies on their handlers (``admit``/``on_finish``/``on_finish_batch``/
    ``on_fault_event``/``dispatch``).
    """

    def loop_closed(self):
        """Drain the heap for ``Simulator.run`` (all arrivals pre-queued)."""
        events = self.events
        heappop = heapq.heappop
        while events:
            t, _, kind, payload = heappop(events)
            self.t = t
            batch = [(kind, payload)]
            while events and events[0][0] == t:
                e = heappop(events)
                batch.append((e[2], e[3]))
            self.n_events += len(batch)
            fin = None
            for kind, payload in batch:
                if kind == "finish":
                    if fin is None:
                        fin = [payload]
                    else:
                        fin.append(payload)
                    continue
                if fin is not None:
                    self.on_finish_batch(fin)
                    fin = None
                if kind == "arrive":
                    self.admit(payload)
                else:
                    self.on_fault_event(kind, payload)
            if fin is not None:
                self.on_finish_batch(fin)
            self.dispatch()

    def loop_open(self, pull, autoscaler, scale_actions: list):
        """Drain the heap for ``Simulator.run_open_loop``.

        ``pull`` admits the next submission into the heap (one look-ahead);
        ``autoscaler`` is consulted on periodic ``scale`` events (``None``
        disables them — no such events are ever pushed then);
        ``scale_actions`` collects applied ``(t, pool, capacity)`` resizes.
        """
        events = self.events
        heappop = heapq.heappop
        heappush = heapq.heappush
        cluster = self.cluster
        wfs = self.wfs
        on_finish = self.on_finish
        on_finish_batch = self.on_finish_batch
        admit = self.admit
        dispatch = self.dispatch
        register_workflow = cluster.register_workflow
        while events:
            t, _, kind, payload = heappop(events)
            self.t = t
            n = 1
            while True:
                if kind == "finish":
                    if events and events[0][0] == t \
                            and events[0][2] == "finish":
                        # contiguous same-t finish run: coalesce. Finish
                        # handlers push nothing, so the run is stable.
                        fin = [payload]
                        while events and events[0][0] == t \
                                and events[0][2] == "finish":
                            fin.append(heappop(events)[3])
                        n += len(fin) - 1
                        on_finish_batch(fin)
                    else:
                        on_finish(payload)
                elif kind == "arrive":
                    admit(payload)
                    # keep exactly one future arrival in the heap
                    register_workflow(payload, wfs[payload].dag)
                    pull()
                elif kind == "scale":
                    for act in autoscaler.decide(
                            cluster, self.demand_by_pool(), t):
                        if act.lag_s > 0:
                            heappush(events,
                                     (t + act.lag_s, next(self.ctr),
                                      "scale_apply", act))
                        else:
                            autoscaler.apply(cluster, act, t)
                            scale_actions.append(
                                (t, act.pool, act.capacity))
                    if events or self.running or \
                            any(st.ready for st in wfs.values()):
                        heappush(events,
                                 (t + autoscaler.interval_s,
                                  next(self.ctr), "scale", None))
                elif kind == "scale_apply":
                    autoscaler.apply(cluster, payload, t)
                    scale_actions.append((t, payload.pool, payload.capacity))
                else:
                    self.on_fault_event(kind, payload)
                if events and events[0][0] == t:
                    t, _, kind, payload = heappop(events)
                    n += 1
                else:
                    break
            self.n_events += n
            dispatch()
