"""Dispatch layer: ready-set index, blocked-group memo, epoch logic.

The fast path (DESIGN.md §8) keeps an *indexed ready-set* per workflow —
roots enter at admission, successors enter when their last dependency
finishes, preemption victims re-enter on cancel — so each pass touches
only genuinely ready tasks instead of rescanning every workflow's whole
DAG. Tasks that failed to start are skipped while their pool's
availability epoch is unchanged (``ClusterManager.free_epoch``): a failed
``try_start`` depends only on (impl, pool, n_devices, n_instances, tenant)
and pool state, so identical-key retries under unchanged state fail
identically and may be elided without changing the schedule. The seed's
full rescan survives as ``fast_dispatch=False`` — the reference the
equivalence tests compare byte-identical traces against.

Finish coalescing (DESIGN.md §12): ``on_finish_batch`` settles a
contiguous same-``t`` group of finish events with per-task work (lease
settlement, trace, telemetry, successor indexing, demand decrement) in pop
order, but defers the per-pool availability-epoch bump and the rebalance
scan to the end of the group. Both deferrals are schedule-invariant:
epochs are only *equality*-compared by the dispatch memo, which runs after
the drain, so one bump per touched pool wakes exactly the keys k bumps
would have woken; and rebalance at group end sees the union of every
zero-demand interface the per-finish calls would have seen, evicting the
same instance set (eviction only ever removes idle, cache-less shells of
zero-demand interfaces, and nothing inside the group re-raises demand —
arrivals flush the group first).
"""
from __future__ import annotations

import bisect
import heapq

from operator import attrgetter

from ..admission import Admission
from ..cluster import Instance, Lease
from ..scheduler import ExecutionPlan
from .events import Submission, TraceEntry, _Running, _WfState

_WARM_SINCE = attrgetter("warm_since")
# shared empty containers: try_start stores one of these on the branch
# that never fills it (model runs hold no tool leases and vice versa) —
# every consumer only ever iterates them
_EMPTY: tuple = ()


class DispatchMixin:
    """Admission, candidate ordering, task start and finish settlement."""

    # -- submissions / admission ----------------------------------------------
    def add_submission(self, wid: str, sub: Submission):
        """Queue a workflow's arrival event."""
        self.wfs[wid] = _WfState(sub.dag, sub.plan, sub.arrival, sub.tenant,
                                 sub.plan_fn, slo_s=sub.slo_s,
                                 scenario=sub.scenario, session=sub.session)
        self.incomplete += 1
        heapq.heappush(self.events,
                       (sub.arrival, next(self.ctr), "arrive", wid))

    def admit(self, wid: str):
        """Arrive event: resolve the plan and index the workflow's roots."""
        st = self.wfs[wid]
        if st.plan is None:
            if st.plan_fn is None:
                raise ValueError(f"workflow {wid!r} submitted without a "
                                 f"plan or plan_fn")
            # admission-time planning: the scheduler sees the live cluster
            # (warm instances, free devices)
            st.plan = st.plan_fn()
        st.adm = Admission(wid, st.tenant, st.arrival)
        dag = st.dag
        roots = self._roots.get(id(dag))
        if roots is None:
            # open-loop submissions share one DAG per scenario: compute
            # the root (topo_rank, tid) pairs once per distinct DAG
            roots = self._roots[id(dag)] = [
                (dag.topo_index(tid), tid) for tid in dag.topo_order
                if not dag.nodes[tid].deps]
        st.ready.extend(roots)
        if self.pol.dynamic:
            self.active_dyn.append(wid)
        else:
            st.sort_key = self.pol.key(st.adm, self.served.served)
            bisect.insort(self.active, (st.sort_key, wid))
            if st.ready:
                bisect.insort(self.active_ready, (st.sort_key, wid))

    def _deactivate(self, wid: str, st: _WfState):
        if self.pol.dynamic:
            self.active_dyn.remove(wid)
        else:
            i = bisect.bisect_left(self.active, (st.sort_key, wid))
            del self.active[i]

    def _push_ready(self, wid: str, st: _WfState, tid: str):
        if not st.ready and not self.pol.dynamic:
            bisect.insort(self.active_ready, (st.sort_key, wid))
        bisect.insort(st.ready, (st.dag.topo_index(tid), tid))

    # -- dispatch candidates --------------------------------------------------
    def _ready_scan(self) -> list[tuple[str, str]]:
        """The seed's full rescan: every workflow, every task, every pass.

        Kept verbatim as the ``fast_dispatch=False`` reference path; the
        equivalence tests assert the indexed ready-set produces
        byte-identical traces against this.
        """
        out = []
        t = self.t
        admitted = [Admission(wid, st.tenant, st.arrival)
                    for wid, st in self.wfs.items()
                    if t >= st.arrival and st.plan is not None]
        for adm in sorted(admitted,
                          key=lambda a: self.pol.key(a, self.served.served)):
            st = self.wfs[adm.workflow]
            for tid in st.dag.topo_order:
                if tid in st.done or tid in st.started:
                    continue
                if all(d in st.done for d in st.dag.nodes[tid].deps):
                    out.append((adm.workflow, tid))
        return out

    def _candidates(self) -> list[tuple[str, str]]:
        """Ready (workflow, task) pairs in admission-policy order, from the
        incremental index: O(active + ready) instead of O(total tasks)."""
        out = []
        wfs = self.wfs
        if self.pol.dynamic:
            served = self.served.served
            # filtering to ready-nonempty before the sort commutes with it
            order = sorted((w for w in self.active_dyn if wfs[w].ready),
                           key=lambda w: self.pol.key(wfs[w].adm, served))
            for wid in order:
                out += [(wid, tid) for _, tid in wfs[wid].ready]
            return out
        for _, wid in self.active_ready:
            out += [(wid, tid) for _, tid in wfs[wid].ready]
        return out

    def dispatch(self):
        """Start whatever is ready and fits, repeating while progress."""
        if not self.sim.fast_dispatch:
            progress = True
            while progress:
                progress = False
                for wid, tid in self._ready_scan():
                    self.n_attempts += 1
                    if self.try_start(wid, tid):
                        progress = True
            return
        dynamic = self.pol.dynamic
        if not dynamic and not self.active_ready:
            return      # nothing ready anywhere: the common post-event case
        cluster = self.cluster
        epochs = cluster.free_epoch
        wfs = self.wfs
        blocked = self.blocked
        blocked_get = blocked.get
        try_start = self.try_start
        attempts = 0
        progress = True
        while progress:
            progress = False
            epoch_snap = cluster.epoch_total
            if dynamic:
                cands = self._candidates()
            else:
                # inlined static-policy _candidates (hot: once per event)
                cands = []
                for _, w in self.active_ready:
                    cands += [(w, tid) for _, tid in wfs[w].ready]
            for wid, tid in cands:
                st = wfs[wid]
                if tid in st.started or tid in st.done:
                    continue
                cfg = st.plan.configs[tid]
                key = (cfg.impl, cfg.pool, cfg.n_devices, cfg.n_instances,
                       st.tenant)
                # a failed start depends only on this key and pool state;
                # while the pool epoch hasn't moved since the last failure,
                # a retry fails identically — skip it (DESIGN.md §8)
                if blocked_get(key) == epochs[cfg.pool]:
                    continue
                attempts += 1
                if try_start(wid, tid):
                    progress = True
                else:
                    # record *post*-attempt epoch: a failing attempt may
                    # itself evict idle instances (bumping the epoch), and
                    # those evictions don't make this key startable
                    cfg2 = st.plan.configs[tid]   # degrade may have moved it
                    key2 = (cfg2.impl, cfg2.pool, cfg2.n_devices,
                            cfg2.n_instances, st.tenant)
                    blocked[key2] = epochs[cfg2.pool]
            # a re-scan pass can only start something if availability
            # moved during this pass (preemption, eviction, release,
            # harvest supply): every survivor is memoized at the current
            # epoch, and new ready entries only appear via cancel_task,
            # which releases (bumping the epoch). No movement ⟹ the next
            # pass is provably a no-op — skip it.
            if progress and cluster.epoch_total == epoch_snap:
                break
        self.n_attempts += attempts
        return

    def demand_by_pool(self) -> dict[str, int]:
        """Devices wanted right now per pool: held + queued (ready) work."""
        demand = dict(self.cluster._used)
        for st in self.wfs.values():
            if st.plan is None:
                continue
            for _, tid in st.ready:
                cfg = st.plan.configs[tid]
                demand[cfg.pool] = demand.get(cfg.pool, 0) + \
                    cfg.n_devices * cfg.n_instances
        return demand

    # -- preemption -----------------------------------------------------------
    def cancel_task(self, vwid: str, vtid: str):
        """Preemption: roll a task back to pending, checkpoint the work
        already finished (chunkable tasks), refund the unearned energy/$
        and release whatever it still holds."""
        t = self.t
        rec = self.running.pop((vwid, vtid), None)
        if rec is None:
            return
        if self.hedges:
            # a hedge dies with its primary: any rollback of the primary
            # also cancels the in-flight duplicate (its work is discarded)
            self._kill_hedge(vwid, vtid)
        vst = self.wfs[vwid]
        vst.started.discard(vtid)
        self._push_ready(vwid, vst, vtid)
        vst.attempt[vtid] = vst.attempt.get(vtid, 0) + 1
        for lease in rec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in rec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst in self.cluster.instances:
                self.cluster.evict_instance(inst, t)
        self._refund(rec, vst, vtid, t)
        self.requeues += 1
        if self.collect_trace:
            self.trace.append(TraceEntry(vwid, vtid, rec.cfg.impl,
                                         rec.cfg.pool, rec.ndev, rec.start,
                                         t, note="preempted"))
        if self.log is not None:
            kept = vst.items_done.get(vtid, 0)
            self.log.append(f"[{t:8.1f}s] preempt {vwid}:{vtid} "
                            f"({rec.ndev}x{rec.cfg.pool}); requeued"
                            + (f" ({kept} items checkpointed)" if kept
                               else ""))

    def try_preempt(self, pool: str, n_needed: int) -> bool:
        """Reclaim harvest-class leases for a priority tenant."""
        t = self.t
        deficit = n_needed - self.cluster.free(pool)
        if deficit <= 0 or self.cluster.harvest_devices(pool) < deficit:
            return False
        victims = self.cluster.preempt_harvest(pool, deficit, t)
        for lease in victims:
            # idle warm instance on a preempted lease: drop the shell
            # through the manager's eviction path so its bookkeeping
            # (instance list + lease table) stays consistent; the lease
            # itself was already released by preempt_harvest, which
            # evict_instance tolerates
            for inst in [i for i in self.cluster.instances
                         if i.lease is not None
                         and i.lease.id == lease.id]:
                self.cluster.evict_instance(inst, t)
            owner = self.lease_owner.pop(lease.id, None)
            if owner is not None:
                if len(owner) == 3:
                    # ("h", wid, tid): a hedge duplicate lost its devices —
                    # cancel just the hedge; its primary keeps running
                    self._kill_hedge(owner[1], owner[2])
                else:
                    self.cancel_task(*owner)
        return bool(victims)

    # -- task start -----------------------------------------------------------
    def _alloc_or_evict(self, cluster, cfg, n: int, t: float,
                        harvest: bool):
        """Allocate ``n`` devices, evicting idle other-impl warm instances
        (LRU by warm_since) until the allocation fits or nothing is left."""
        pool = cfg.pool
        lease = cluster.alloc(pool, n, t, harvest=harvest)
        if lease is None:
            impl = cfg.impl
            idle = [i for i in cluster.pool_instances(pool)
                    if i.busy_until <= t and i.impl != impl]
            idle.sort(key=_WARM_SINCE)
            for victim in idle:
                cluster.evict_instance(victim, t)
                lease = cluster.alloc(pool, n, t, harvest=harvest)
                if lease is not None:
                    break
        return lease

    def _acquire(self, cluster, cfg, t: float, harvest: bool,
                 insts: list, session: str = "") -> int:
        """Fill ``insts`` up to ``cfg.n_instances`` — reusing idle warm
        instances first (first-fit in index order), then provisioning new
        ones; returns how many were newly provisioned.

        A non-empty ``session`` reorders the warm-reuse scan by resident
        prefix tokens for that session, descending (stable, so instances
        with no cache entry keep index order): session affinity prefers the
        shell whose KV cache already holds the conversation prefix
        (DESIGN.md §9). With ``session == ""`` the scan is byte-identical
        to the affinity-less engine.
        """
        new_inst = 0
        target = cfg.n_instances
        need = target - len(insts)
        warm = cluster.warm_instances(cfg.impl, cfg.pool, cfg.n_devices)
        if session:
            warm = sorted(
                warm, key=lambda i: -i.cache[session].tokens
                if session in i.cache else 0)
        if need > 0:
            if insts:
                for i in warm:
                    if i.busy_until <= t and i not in insts:
                        insts.append(i)
                        need -= 1
                        if need <= 0:
                            break
            else:
                # fresh fill: ``warm`` has no duplicates, so everything
                # appended here came from this scan — no containment check
                append = insts.append
                for i in warm:
                    if i.busy_until <= t:
                        append(i)
                        need -= 1
                        if need <= 0:
                            break
        while len(insts) < target:
            lease = self._alloc_or_evict(cluster, cfg, cfg.n_devices, t,
                                         harvest)
            if lease is None:
                break
            inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                            warm_since=t, lease=lease,
                            cache_cap_bytes=self.sim._cache_cap(cfg))
            cluster.add_instance(inst)
            insts.append(inst)
            new_inst += 1
        return new_inst

    def try_start(self, wid: str, tid: str) -> bool:
        """Start a ready task if its resources fit right now."""
        t = self.t
        sim = self.sim
        st = self.wfs[wid]
        cluster = self.cluster
        node = st.dag.nodes[tid]
        cfg = st.plan.configs[tid]
        impl = self.impls[cfg.impl]
        spec = self.specs[cfg.pool]
        harvest = st.tenant == "harvest"
        priority = st.tenant == "priority"
        new_inst = 0
        # degrade configs planned for a larger cluster (elasticity)
        cap = cluster.pools[cfg.pool].capacity
        if cfg.n_devices > cap:
            if cap < sim._pool_limit(cfg.pool):
                # the pool is autoscaled below its limit right now: wait
                # for the scale-up instead of permanently degrading the
                # plan to the shrunken size
                return False
            lo = impl.min_devices.get(spec.kind, 1)
            n = 1
            while n * 2 <= cap:
                n *= 2
            if n < lo:
                raise RuntimeError(
                    f"{cfg.impl} needs >= {lo} {spec.kind} devices; "
                    f"pool {cfg.pool} has {cap}")
            cfg = cfg.with_(n_devices=n, n_instances=1)
            # copy-on-write: amortized open-loop submissions share one
            # template plan per scenario; take a private copy before the
            # only in-place plan mutation the engine ever performs
            st.plan = ExecutionPlan(dict(st.plan.configs))
            st.plan.configs[tid] = cfg

        # KV/prefix cache (DESIGN.md §9): a task is cache-eligible when the
        # engine models caches, the workflow carries a session and the node
        # has a session-shared prefix on a KV-tracking impl. The affinity
        # lever (cache_affinity) only reorders warm-shell reuse — pricing
        # below uses whatever cache the acquired shells actually hold.
        session = (st.session if self.kv_cache and st.session
                   and node.prefix_tokens > 0
                   and impl.kv_bytes_per_token > 0 else "")
        cfg_impl = cfg.impl
        cfg_pool = cfg.pool
        cfg_ndev = cfg.n_devices
        if self.is_model[cfg_impl]:
            leases: "list[Lease] | tuple" = _EMPTY
            insts: "list[Instance] | tuple" = []
            affinity = session if self.cache_affinity else ""
            new_inst = self._acquire(cluster, cfg, t, harvest, insts,
                                     affinity)
            if not insts and priority and \
                    self.try_preempt(cfg_pool, cfg_ndev):
                new_inst += self._acquire(cluster, cfg, t, harvest, insts,
                                          affinity)
            if not insts:
                return False
            # keep each lease's preemptibility in sync with the tenant now
            # running on it (Simulator._relabel_lease, inlined: mismatches
            # are common enough under a mixed tenant stream to be hot)
            for inst in insts:
                lease = inst.lease
                if lease is not None and lease.harvest != harvest:
                    if lease.id not in cluster._leases:
                        inst.lease = None
                    else:
                        lease.harvest = harvest
                        if harvest:
                            # new preemptible supply: epoch must move
                            cluster.free_epoch[lease.pool] += 1
                            cluster.epoch_total += 1
            n_inst = len(insts)
        else:
            insts = _EMPTY
            total = cfg_ndev * cfg.n_instances
            lease = cluster.alloc(cfg_pool, total, t, harvest=harvest)
            n_inst = cfg.n_instances
            if lease is None:
                lease = self._alloc_or_evict(cluster, cfg, cfg_ndev,
                                             t, harvest)
                n_inst = 1
                if lease is None and priority and \
                        self.try_preempt(cfg_pool, cfg_ndev):
                    lease = self._alloc_or_evict(cluster, cfg,
                                                 cfg_ndev, t, harvest)
                if lease is None:
                    return False
            leases = [lease]

        items_done = st.items_done.get(tid, 0) if self.resume else 0
        cache_frac = 0.0
        if session and insts:
            self.cache_lookups += 1
            # every acquired shell must hold the prefix for the discount
            # to apply to the whole (identically-priced) instance group;
            # in practice chat turns run on one instance
            tok = min((inst.cache[session].tokens if session in inst.cache
                       else 0) for inst in insts)
            hit_tokens = min(tok, node.prefix_tokens)
            if hit_tokens > 0 and node.tokens_in > 0:
                cache_frac = hit_tokens / node.tokens_in
                self.cache_hits += 1
                remaining = max(node.work_items - items_done, 0)
                self.prefill_tokens_saved += hit_tokens * remaining
                for inst in insts:
                    cluster.cache_touch(inst, session, t)
        dur, compute, per_inst = sim._duration(node, cfg, n_inst,
                                               new_inst, items_done,
                                               cache_frac)
        pmult = cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
        dur *= pmult
        # seeded fault draws (DESIGN.md §10): a pure function of
        # (seed, wid, tid, attempt), so replay and the fast/reference
        # dispatch paths see identical fault streams regardless of
        # dispatch order. All three draws always happen (stream stability).
        attempt = st.attempt.get(tid, 0)
        slow, fail_frac = 1.0, 0.0
        fp = self.faults
        if fp is not None:
            u_fail, u_frac, u_strag = fp.task_draws(wid, tid, attempt)
            if u_fail < fp.task_fail_p:
                # transient failure somewhere inside the compute window
                fail_frac = 0.05 + 0.9 * u_frac
            elif u_strag < fp.straggler_p:
                slow = fp.straggler_mult
                self.faults_injected += 1
        base_dur = dur          # the CostQuery estimate (hedge trigger)
        if slow != 1.0:
            extra = compute * (slow - 1.0)
            compute = compute * slow
            dur = dur + extra * pmult
        end = t + dur
        # the tail of the run is compute; any lead-in is weights load
        compute_begin = end - compute * pmult
        for inst in insts:
            inst.busy_until = end
        ndev = cfg_ndev * n_inst
        dev_s = compute * ndev * cfg.paths
        pfkey = (cfg_impl, cfg_pool, cfg_ndev)
        pf = self._pf_memo.get(pfkey)
        if pf is None:
            pf = self._pf_memo[pfkey] = \
                self.profiles.power_frac(impl, spec, cfg_ndev)
        self.ledger.charge_active(spec, dev_s, pf, cfg_pool)
        busy = self.busy
        busy[cfg_pool] = busy.get(cfg_pool, 0.0) + dev_s
        # ServedCounter.charge, inlined (same float op)
        srv = self.served.served
        tenant = st.tenant
        srv[tenant] = srv.get(tenant, 0.0) + dev_s
        st.started.add(tid)
        ready = st.ready
        i = bisect.bisect_left(ready, (st.dag.topo_index(tid), tid))
        if i < len(ready) and ready[i][1] == tid:
            del ready[i]
            if not ready and not self.pol.dynamic:
                active_ready = self.active_ready
                j = bisect.bisect_left(active_ready, (st.sort_key, wid))
                if j < len(active_ready) and active_ready[j][1] == wid:
                    del active_ready[j]
        if self.collect_trace or self.log is not None:
            # compose the note: restart kind + warmth, so preemption
            # analysis sees a requeue that also paid a cold weights load
            # ("requeue+cold") rather than losing the restart cost. An
            # untraced, unlogged run (the benchmark posture) skips the
            # string work — nothing downstream ever reads the note then.
            restart = ("resume" if attempt and items_done else
                       "requeue" if attempt else "")
            warmth = "cold" if new_inst else ("warm" if insts else "")
            if cache_frac > 0.0:
                # surface the prefix hit in the trace ("warm+kv")
                warmth = warmth + "+kv" if warmth else "kv"
            note = (restart + "+" + warmth if restart and warmth
                    else restart or warmth)
            if slow != 1.0:
                note = note + "+slow" if note else "slow"
        else:
            restart = note = ""
        lease_owner = self.lease_owner
        owner = (wid, tid)
        for lease in leases:
            lease_owner[lease.id] = owner
        for inst in insts:
            lease = inst.lease
            if lease is not None:
                lease_owner[lease.id] = owner
        # _Running's positional field order; kwargs cost real time here
        self.running[owner] = _Running(
            cfg, leases, insts, t, end, compute_begin, ndev, dev_s, pf,
            note, n_inst, (1 if spec.kind == "cpu" else cfg.batch),
            items_done, per_inst, node.chunkable, session, cache_frac,
            slow)
        if fail_frac:
            # this attempt dies mid-compute instead of finishing
            fail_t = compute_begin + (end - compute_begin) * fail_frac
            heapq.heappush(self.events, (fail_t, next(self.ctr), "tfail",
                                         (wid, tid, attempt)))
        else:
            heapq.heappush(self.events, (end, next(self.ctr), "finish",
                                         (wid, tid, attempt)))
            if fp is not None and fp.hedge and slow >= fp.hedge_threshold:
                # straggler detected against the CostQuery estimate: at
                # threshold x the estimated duration the task is still
                # running — launch a duplicate then (first finish wins)
                heapq.heappush(
                    self.events,
                    (t + base_dur * fp.hedge_threshold, next(self.ctr),
                     "hedge", (wid, tid, attempt)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] start {wid}:{tid} on "
                            f"{ndev}x{cfg.pool} ({cfg.impl})"
                            + (f" [{restart}]" if restart else ""))
        return True

    # -- finish ---------------------------------------------------------------
    def on_finish(self, payload) -> bool:
        """Finish event; returns True when the whole workflow completed."""
        wid, tid, attempt = payload
        st = self.wfs[wid]
        if st.attempt.get(tid, 0) != attempt:
            return False    # stale: this execution was preempted
        rec = self.running.pop((wid, tid))
        if self.hedges:
            # the primary beat its duplicate: cancel the hedge, discard
            # and waste whatever it had executed (first finish wins)
            self._kill_hedge(wid, tid)
        return self._complete(wid, tid, st, rec)

    def on_finish_batch(self, payloads: list):
        """Settle a contiguous same-``t`` run of finish events as a group.

        Per-task settlement runs in pop order (identical to the uncoalesced
        loop); the per-pool epoch bump and the rebalance scan are deferred
        to the end of the group via ``_pend_pools`` (see the module
        docstring for the schedule-invariance argument).
        """
        if len(payloads) == 1:
            self.on_finish(payloads[0])
            return
        pend = self._pend_pools = {}
        for payload in payloads:
            self.on_finish(payload)
        self._pend_pools = None
        cluster = self.cluster
        if pend:
            epochs = cluster.free_epoch
            for pool in pend:
                epochs[pool] += 1
            cluster.epoch_total += len(pend)
        if cluster.demand_zeroed:
            cluster.demand_zeroed = False
            log = self.log
            for action in cluster.rebalance(self.sim.library, self.t):
                if log is not None:
                    log.append(f"[{self.t:8.1f}s] rebalance: {action}")

    def _complete(self, wid: str, tid: str, st: _WfState,
                  rec: _Running) -> bool:
        """Book a finished run (shared by primary finishes and hedge wins).

        For a dead-lettered workflow the run still settles its resources
        and trace, but spawns no successors and can never count as a
        workflow completion.
        """
        t = self.t
        cluster = self.cluster
        done = st.done
        done.add(tid)
        if t > st.finish:
            st.finish = t
        cluster.complete_task(wid, tid)
        if rec.slow != 1.0:
            # a straggler that ran to completion burned ``slow``x the
            # compute the work required: the excess is overhead of the
            # fault, booked as waste — the same currency a hedge-beaten
            # primary's discarded run is booked in, so the fault bench
            # compares hedging against let-it-drag honestly
            self.wasted_dev_s += rec.dev_s * (rec.slow - 1.0) / rec.slow
        cfg = rec.cfg
        model = self.is_model[cfg.impl]
        lease_owner = self.lease_owner
        for lease in rec.leases:
            # model instances keep their devices (stay warm); tools
            # release. Instance devices are reclaimed by rebalance.
            lease_owner.pop(lease.id, None)
            if not model:
                cluster.release(lease, t)
        for inst in rec.insts:
            lease = inst.lease
            if lease is not None:
                lease_owner.pop(lease.id, None)
        # session finished a turn on these shells: the full prompt+reply KV
        # is now resident, serving the *next* turn's prefix (DESIGN.md §9).
        # Insertion is gated like the pricing above, so cache-less runs
        # never touch the ledger (byte-identity with the pre-cache engine).
        if rec.session:
            node = st.dag.nodes[tid]
            impl = self.impls[cfg.impl]
            tokens = node.tokens_in + node.tokens_out
            nbytes = impl.kv_bytes_per_token * tokens
            for inst in rec.insts:
                cluster.cache_insert(inst, rec.session, tokens, nbytes, t)
        # the task's instances just went idle: blocked tasks keyed on this
        # pool may now reuse (or evict) them, so the availability epoch
        # must move even though no lease was released (model path). Inside
        # a coalesced finish group the bump is deferred — one per touched
        # pool at group end (the memo only equality-compares epochs, and
        # dispatch runs after the drain).
        pend = self._pend_pools
        if pend is None:
            cluster.free_epoch[cfg.pool] += 1
            cluster.epoch_total += 1
        else:
            pend[cfg.pool] = True
        if self.collect_trace:
            self.trace.append(TraceEntry(wid, tid, rec.cfg.impl,
                                         rec.cfg.pool, rec.ndev,
                                         rec.start, t, note=rec.note))
        tele = self.tele
        if tele is not None:
            # one record per completed attempt, priced exactly as the
            # ledger charged it (marginal energy over idle; $ over the full
            # device-seconds). Pure observation — nothing above read it.
            node = st.dag.nodes[tid]
            spec = self.specs[cfg.pool]
            energy = (rec.dev_s * rec.pf * (spec.active_w - spec.idle_w)
                      if spec.metered else 0.0)
            tele.observe(
                t=t, workflow=wid, task=tid, node=node,
                interface=node.agent, impl=cfg.impl, pool=cfg.pool,
                latency_s=t - rec.start, energy_j=energy,
                usd=rec.dev_s / 3600.0 * spec.usd_per_hour,
                declared_quality=cfg.quality,
                routed=node.agent in self.sim.routed_interfaces)
        # index newly-ready successors (their last dependency just
        # finished); a dead workflow spawns nothing
        nodes = st.dag.nodes
        if not st.dead:
            started = st.started
            for succ in st.dag.succ(tid):
                if succ in done or succ in started:
                    continue
                for d in nodes[succ].deps:
                    if d not in done:
                        break
                else:
                    self._push_ready(wid, st, succ)
        finished = not st.dead and len(done) == len(nodes)
        if finished:
            self._deactivate(wid, st)
            self.incomplete -= 1
        # workflow-aware reclamation once demand disappears. Gated on the
        # demand-hit-zero flag: rebalance can only newly reclaim at the
        # instant some interface's pending count reaches 0 (an interface
        # with zero demand has no running tasks either, so its instances
        # were all idle — and evicted — the moment it zeroed), which makes
        # skipping the other calls a pure no-op elision. Deferred to group
        # end inside a coalesced finish batch.
        if pend is None and cluster.demand_zeroed:
            cluster.demand_zeroed = False
            for action in cluster.rebalance(self.sim.library, t):
                if self.log is not None:
                    self.log.append(f"[{t:8.1f}s] rebalance: {action}")
        return finished
