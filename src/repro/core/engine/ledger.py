"""Ledger layer: energy/$/served charging, refunds, and run reports.

Charging happens at task start (``DispatchMixin.try_start`` bills active
energy/$ for the compute device-seconds up front); this layer owns the
inverse operations — the step-granular ``_refund`` that preemption, fault
failure and hedge cancellation share — plus the idle-floor integration
over each pool's capacity timeline at ``finalize`` and the report
assembly (``SimReport`` / ``OpenLoopReport``).

The refund contract (DESIGN.md §6.4): a chunkable victim's completed
batch steps survive — ``ProfileStore.completed_items`` inverts the exact
schedule ``_duration`` charged, including its prefix-cache discount — so
a resumed task's total charge across attempts is exactly
``schedule_latency(total items)``. Non-chunkable victims refund the
unexecuted remainder of the compute window; executed-then-discarded
device-seconds accrue in ``wasted_dev_s`` either way.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..profiles import CostQuery
from .events import TraceEntry, _Running, _WfState


@dataclass
class SimReport:
    """Aggregate outcome of one simulated run (energy, trace, spans)."""

    makespan_s: float
    energy_wh: float
    active_wh: float
    idle_wh: float
    usd: float
    trace: list[TraceEntry]
    per_workflow: dict[str, dict]
    pool_busy_device_s: dict[str, float]
    preemptions: int = 0
    requeues: int = 0            # task re-executions caused by preemption
    resumed_items: int = 0       # work-items salvaged by checkpoint/resume
    wasted_dev_s: float = 0.0    # executed-then-discarded device-seconds
    # KV/prefix-cache residency (DESIGN.md §9): lookups = session tasks
    # that could have hit, hits = tasks that started with a warm prefix
    cache_lookups: int = 0
    cache_hits: int = 0
    cache_hit_rate: float = 0.0
    prefill_tokens_saved: float = 0.0   # un-recomputed prefill tokens
    # fault injection + recovery (DESIGN.md §10); all zero when faults=None
    faults_injected: int = 0     # crashes + transient fails + stragglers
    instance_crashes: int = 0    # crash events that killed a live instance
    task_faults: int = 0         # transient mid-compute task failures
    fault_retries: int = 0       # task re-executions after a fault backoff
    hedges_launched: int = 0     # straggler duplicates started
    hedges_won: int = 0          # duplicates that beat their primary
    dead_letters: int = 0        # workflows abandoned (retries exhausted)
    degrade_replans: int = 0     # replans onto the degraded live cluster

    def workflow_span(self, wf: str) -> float:
        """Arrival-to-finish seconds for one workflow (tenant latency)."""
        return self.per_workflow[wf]["finish"] - self.per_workflow[wf]["start"]


@dataclass
class OpenLoopReport(SimReport):
    """SimReport + steady-state serving metrics from ``run_open_loop``."""

    horizon_s: float = 0.0       # arrival window length
    warmup_s: float = 0.0        # arrivals before this are trimmed
    offered_rps: float = 0.0     # arrivals / horizon
    arrivals: int = 0            # workflows admitted
    completed: int = 0           # workflows finished
    measured: int = 0            # completions past warmup (metric base)
    goodput_rps: float = 0.0     # SLO-met completions / measured seconds
    per_class: dict = field(default_factory=dict)
    n_events: int = 0            # heap events processed
    n_attempts: int = 0          # dispatch attempts (try_start calls)
    wall_s: float = 0.0
    events_per_s: float = 0.0    # (n_events + n_attempts) / wall_s
    scale_actions: list = field(default_factory=list)


class LedgerMixin:
    """Refunds, idle-floor finalization and report assembly."""

    def _refund(self, rec: _Running, vst: _WfState, vtid: str, t: float,
                salvage: bool = True):
        """Roll back an interrupted run's energy/$ charge, step-granularly.

        Shared by preemption (``cancel_task``), fault failures
        (``fail_task``) and hedge cancellation (``_kill_hedge``, with
        ``salvage=False`` — a losing duplicate's completed steps are
        discarded, never checkpointed). For a straggling run
        (``rec.slow != 1.0``) the schedule inversion sees the *unslowed*
        clock (the schedule charged normal step times; the wall merely
        stretched), and kept charges scale back up by ``slow`` — so the
        refund inverts exactly what ``try_start`` billed.
        """
        spec = self.specs[rec.cfg.pool]
        # the charged dev_s covers compute only (weights-load is an
        # idle-power period), so progress is measured over the compute
        # window [compute_begin, end] — a victim preempted mid-load
        # gets a full refund either way
        window = max(rec.end - rec.compute_begin, 1e-12)
        elapsed = min(max(t - rec.compute_begin, 0.0), window)
        # executed device-seconds so far; dev_s spreads uniformly over
        # the window (paths run concurrently, so the rate is
        # ndev * paths even when the wall clock is path-multiplied)
        exec_dev_s = rec.dev_s * (elapsed / window)
        if salvage and rec.resumable and self.resume:
            # checkpoint/resume: invert the step schedule over the
            # compute window — completed batch steps survive, the
            # in-flight step is discarded
            impl = self.impls[rec.cfg.impl]
            node = vst.dag.nodes[vtid]
            work = impl.work_fn(node.tokens_in, node.tokens_out)
            # the refund inverts the exact schedule _duration charged,
            # including its prefix-cache discount (rec.cache_frac)
            sched_elapsed = (elapsed if rec.slow == 1.0
                             else elapsed / rec.slow)
            done, wall = self.profiles.completed_items(CostQuery(
                impl=impl, spec=spec, n_devices=rec.cfg.n_devices,
                work=work, batch=rec.batch, items=rec.items_per_inst,
                elapsed_s=sched_elapsed, cache_hit_frac=rec.cache_frac))
            kept_items = min(done * rec.n_inst,
                             node.work_items - rec.items_done0)
            if kept_items:
                vst.items_done[vtid] = rec.items_done0 + kept_items
                self.resumed_items += kept_items
            # step-granular refund: completed steps stay charged (their
            # items never re-run); the in-flight step is refunded — its
            # items ride the residual requeue, which re-charges them,
            # so the task's total charge across attempts is exactly
            # schedule_latency(total items)
            kept_dev_s = wall * rec.ndev * rec.cfg.paths
            if rec.slow != 1.0:
                kept_dev_s *= rec.slow
            refund = max(rec.dev_s - kept_dev_s, 0.0)
            self.wasted_dev_s += max(exec_dev_s - kept_dev_s, 0.0)
        else:
            # restart from scratch (non-chunkable / resume disabled /
            # losing hedge): refund only the unexecuted remainder — the
            # executed compute stays charged (that energy was really
            # burned) and is all wasted, since nothing of it survives
            refund = rec.dev_s * (1.0 - elapsed / window)
            self.wasted_dev_s += exec_dev_s
        self.ledger.charge_active(spec, -refund,
                                  utilization=rec.pf, pool=rec.cfg.pool)
        self.busy[rec.cfg.pool] = self.busy.get(rec.cfg.pool, 0.0) - refund
        self.served.charge(vst.tenant, -refund)

    # -- accounting -----------------------------------------------------------
    def finalize(self, makespan: float):
        """Integrate the idle-power floor over each pool's capacity log."""
        for pool, p in self.cluster.pools.items():
            spec = p.spec
            log = self.cluster.capacity_log(pool)
            if len(log) == 1:
                # constant capacity: the seed's exact expression (golden
                # traces pin the float op order)
                self.ledger.charge_idle(spec, p.capacity, makespan)
            else:
                dev_s = self.cluster.capacity_device_seconds(pool, makespan)
                self.ledger.charge_idle(spec, 1, dev_s)

    def report(self, makespan: float) -> SimReport:
        per_wf = {wid: {"start": st.arrival, "finish": st.finish,
                        "tasks": len(st.dag), "tenant": st.tenant}
                  for wid, st in self.wfs.items()}
        return SimReport(
            makespan_s=makespan,
            energy_wh=self.ledger.wh,
            active_wh=self.ledger.active_joules / 3600.0,
            idle_wh=self.ledger.idle_joules / 3600.0,
            usd=self.ledger.usd,
            trace=sorted(self.trace,
                         key=lambda e: (e.start, e.end, e.workflow)),
            per_workflow=per_wf,
            pool_busy_device_s=self.busy,
            preemptions=self.cluster.preemptions - self.preempt0,
            requeues=self.requeues,
            resumed_items=self.resumed_items,
            wasted_dev_s=self.wasted_dev_s,
            cache_lookups=self.cache_lookups,
            cache_hits=self.cache_hits,
            cache_hit_rate=(self.cache_hits / self.cache_lookups
                            if self.cache_lookups else 0.0),
            prefill_tokens_saved=self.prefill_tokens_saved,
            faults_injected=self.faults_injected,
            instance_crashes=self.instance_crashes,
            task_faults=self.task_faults,
            fault_retries=self.fault_retries,
            hedges_launched=self.hedges_launched,
            hedges_won=self.hedges_won,
            dead_letters=self.dead_letters,
            degrade_replans=self.degrade_replans,
        )

    def steady_state(self, rep: SimReport, horizon_s: float,
                     warmup_s: float, arrivals: int, wall: float,
                     scale_actions: list) -> OpenLoopReport:
        """Fold steady-state serving metrics into an OpenLoopReport."""
        completed = 0
        per_class: dict[str, dict] = {}
        spans: dict[str, list[float]] = {}
        met: dict[str, int] = {}
        # dead-lettered workflows per tenant (post-warmup): they count
        # against SLO attainment — an abandoned request is a missed SLO,
        # not a dropped sample — but contribute no latency span
        dead: dict[str, int] = {}
        measured = 0
        goodput_n = 0
        for wid, st in self.wfs.items():
            done = len(st.done) == len(st.dag.nodes)
            if done:
                completed += 1
            if st.arrival < warmup_s:
                continue
            if st.dead:
                measured += 1
                dead[st.tenant] = dead.get(st.tenant, 0) + 1
                continue
            if not done:
                continue
            measured += 1
            span = st.finish - st.arrival
            spans.setdefault(st.tenant, []).append(span)
            if st.slo_s is not None:
                ok = span <= st.slo_s
                met[st.tenant] = met.get(st.tenant, 0) + (1 if ok else 0)
                if ok:
                    goodput_n += 1
        for tenant, ss in sorted(spans.items()):
            ss.sort()
            n = len(ss)
            per_class[tenant] = {
                "n": n,
                "p50_s": ss[int(0.50 * (n - 1))],
                "p95_s": ss[int(0.95 * (n - 1))],
                "p99_s": ss[int(0.99 * (n - 1))],
                "mean_s": sum(ss) / n,
                "dead": dead.get(tenant, 0),
                "slo_attainment": (
                    met[tenant] / (n + dead.get(tenant, 0))
                    if tenant in met else None),
            }
        for tenant, n_dead in sorted(dead.items()):
            if tenant not in per_class:
                # every post-warmup workflow of this class dead-lettered
                per_class[tenant] = {
                    "n": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                    "mean_s": 0.0, "dead": n_dead, "slo_attainment": 0.0,
                }
        elapsed = max(rep.makespan_s - warmup_s, 1e-9)
        n_ev = self.n_events + self.n_attempts
        return OpenLoopReport(
            **{f: getattr(rep, f) for f in (
                "makespan_s", "energy_wh", "active_wh", "idle_wh", "usd",
                "trace", "per_workflow", "pool_busy_device_s",
                "preemptions", "requeues", "resumed_items", "wasted_dev_s",
                "cache_lookups", "cache_hits", "cache_hit_rate",
                "prefill_tokens_saved", "faults_injected",
                "instance_crashes", "task_faults", "fault_retries",
                "hedges_launched", "hedges_won", "dead_letters",
                "degrade_replans")},
            horizon_s=horizon_s,
            warmup_s=warmup_s,
            offered_rps=arrivals / max(horizon_s, 1e-9),
            arrivals=arrivals,
            completed=completed,
            measured=measured,
            goodput_rps=goodput_n / elapsed,
            per_class=per_class,
            n_events=self.n_events,
            n_attempts=self.n_attempts,
            wall_s=wall,
            events_per_s=n_ev / max(wall, 1e-9),
            scale_actions=scale_actions,
        )
