"""Layered discrete-event engine (DESIGN.md §12).

``core/simulator.py`` used to hold the whole engine as one monolith; it is
now a thin façade over this package, whose modules are the engine's
layers:

- :mod:`.events` — the event heap, clock, same-timestamp drain loops and
  the state records events carry (``TraceEntry``/``Submission``/
  ``_WfState``/``_Running``), including contiguous-finish coalescing;
- :mod:`.dispatch` — admission, the indexed ready-set, the blocked-group
  epoch memo, task start/preemption and finish settlement;
- :mod:`.ledger` — energy/$/served charging inverses (step-granular
  refunds), the idle-floor capacity-timeline integration, and the
  ``SimReport``/``OpenLoopReport`` assembly;
- :mod:`.recovery` — fault injection, retry/backoff, crash/repair and
  hedge paths (all provably inert when ``faults=None``).

``Engine`` composes the four mixins over one shared state bag built here:
one instance per ``Simulator.run``/``run_open_loop`` call. The layers
deliberately share ``self`` (a run's state is one object graph — heap,
workflows, cluster, ledgers); the split is about *reading* the engine,
and about making each layer's contract explicit, not about isolating
state behind interfaces the hot path would then have to cross.
"""
from __future__ import annotations

import heapq
import itertools

from ..admission import ServedLedger
from ..energy import EnergyLedger
from ..faults import FaultProfile
from .dispatch import DispatchMixin
from .events import (EventLoopMixin, Submission, TraceEntry, _Running,
                     _WfState)
from .ledger import LedgerMixin, OpenLoopReport, SimReport
from .recovery import RecoveryMixin

__all__ = [
    "Engine", "OpenLoopReport", "SimReport", "Submission", "TraceEntry",
]


class Engine(EventLoopMixin, DispatchMixin, RecoveryMixin, LedgerMixin):
    """One run's event-loop state, shared by ``run`` and ``run_open_loop``.

    The seed kept all of this in closures inside ``run``; hoisting it lets
    the open-loop mode reuse admission, preemption, dispatch and accounting
    verbatim (identical float-op order — the golden tests pin it).
    """

    def __init__(self, sim, pol, log: list | None,
                 collect_trace: bool = True):
        self.sim = sim
        self.cluster = sim.cluster
        self.pol = pol
        self.log = log
        self.collect_trace = collect_trace
        # hot-path caches: pool -> device spec (device SKUs never change
        # mid-run; capacities may), impl name -> "is a model" (vs tool),
        # and the per-Simulator constants try_start reads on every attempt
        self.specs = {name: p.spec for name, p in sim.cluster.pools.items()}
        self.impls = sim.library.impls
        self.is_model = {name: sim._is_model(impl)
                         for name, impl in sim.library.impls.items()}
        self.profiles = sim.profiles
        self.resume = sim.resume
        self.kv_cache = sim.kv_cache
        self.cache_affinity = sim.cache_affinity
        self.tele = sim.telemetry
        # power_frac memo: pins never change mid-run, so (impl, pool,
        # n_devices) fully determines the fraction
        self._pf_memo: dict[tuple, float] = {}
        self.wfs: dict[str, _WfState] = {}
        self.ledger = EnergyLedger()
        self.served = ServedLedger()
        self.preempt0 = sim.cluster.preemptions
        self.trace: list[TraceEntry] = []
        self.busy: dict[str, float] = {}
        self.running: dict[tuple[str, str], _Running] = {}
        self.lease_owner: dict[int, tuple[str, str]] = {}
        self.requeues = 0
        self.resumed_items = 0
        self.wasted_dev_s = 0.0
        # fault injection + recovery (DESIGN.md §10). ``faults`` is None on
        # a fault-free run: every fault path below is gated on it, so the
        # event heap, float-op order and counters stay byte-identical.
        self.faults: FaultProfile | None = sim.faults
        self.retry = sim.faults.retry if sim.faults is not None else None
        self.hedges: dict[tuple[str, str], _Running] = {}
        self._pool_rng: dict = {}        # pool -> crash-process generator
        self.incomplete = 0              # live (not finished/dead) workflows
        self.faults_injected = 0
        self.instance_crashes = 0
        self.task_faults = 0
        self.fault_retries = 0
        self.hedges_launched = 0
        self.hedges_won = 0
        self.dead_letters = 0
        self.degrade_replans = 0
        # KV/prefix-cache counters (DESIGN.md §9)
        self.cache_lookups = 0
        self.cache_hits = 0
        self.prefill_tokens_saved = 0.0
        self.events: list[tuple[float, int, str, object]] = []
        self.ctr = itertools.count()
        self.t = 0.0
        self.n_events = 0
        self.n_attempts = 0
        # dispatch-order index over admitted, incomplete workflows:
        # static policies keep a key-sorted list (keys are immutable
        # admission facts); weighted-fair re-sorts per pass (virtual time
        # moves between passes)
        self.active: list[tuple[tuple, str]] = []    # static: (key, wid)
        self.active_dyn: list[str] = []              # dynamic: wids
        # static policies only: the subset of ``active`` whose ready set is
        # nonempty, kept key-sorted — dispatch passes iterate this instead
        # of filtering every active workflow (invariant: (key, wid) here
        # ⟺ wfs[wid].ready nonempty)
        self.active_ready: list[tuple[tuple, str]] = []
        # blocked-group memo: (impl, pool, n_devices, n_instances, tenant)
        # -> pool free_epoch at last failed attempt. Skip while unchanged.
        self.blocked: dict[tuple, int] = {}
        # root (topo_rank, tid) pairs per distinct DAG object (id-keyed;
        # the DAGs are kept alive by wfs entries)
        self._roots: dict[int, list] = {}
        # coalesced-finish-group state (events.py): inside a group this is
        # an ordered set (dict) of pools whose epoch bump is deferred to
        # group end; None outside a group (the per-finish bump path)
        self._pend_pools: dict | None = None

    def push_event(self, t: float, kind: str, payload) -> None:
        """Queue one event (cold-path helper; hot paths push inline)."""
        heapq.heappush(self.events, (t, next(self.ctr), kind, payload))
