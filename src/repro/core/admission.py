"""Multi-tenant admission: tenant classes + dispatch-order policies.

The paper's adaptive-runtime claim (§5, "heavy traffic from millions of
users") needs a policy layer between declarative jobs and the cluster:
which tenant's ready work is dispatched first when capacity is scarce.
Production studies of compound AI deployments identify exactly this
tenant-aware admission/priority policy as the missing piece between
workflow orchestration and the cluster manager.

Three tenant classes (``Job.tenant_class``):

- ``priority``  — latency-sensitive; may *preempt* harvest-class leases
  (the simulator reclaims them via ``ClusterManager.preempt_harvest``).
- ``standard``  — the default; scheduled by policy order, never preempts.
- ``harvest``   — best-effort; its allocations are marked preemptible
  (spot semantics), so priority tenants can reclaim the devices mid-run.

Three policies (``POLICIES``): ``fcfs`` (arrival order, the legacy
behaviour), ``strict-priority`` (class rank, then arrival) and
``weighted-fair`` (classes served in proportion to configurable weights,
tracked as virtual time = device-seconds served / weight — the classic
WFQ approximation). A policy orders the *ready queue*; dispatch stays
work-conserving: lower classes still run when higher classes leave
capacity free.
"""
from __future__ import annotations

from dataclasses import dataclass, field

TENANT_CLASSES = ("priority", "standard", "harvest")
_RANK = {c: i for i, c in enumerate(TENANT_CLASSES)}


@dataclass(frozen=True)
class Admission:
    """One tenant's entry in the admission queue."""

    workflow: str
    tenant: str
    arrival: float


class AdmissionPolicy:
    """Orders ready work across tenants; subclasses define the key."""

    name = "base"
    # dynamic policies derive keys from state that moves between dispatch
    # passes (e.g. WFQ virtual time); static policies key on immutable
    # admission facts, so the event engine may sort each workflow once at
    # admission and keep it in a persistent heap (DESIGN.md §8)
    dynamic = False

    def key(self, adm: Admission, served: dict[str, float]) -> tuple:
        """Sort key for one admission; lower dispatches first."""
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class FCFS(AdmissionPolicy):
    """Arrival order, tenant-blind (the legacy ``execute_many`` order)."""

    name = "fcfs"

    def key(self, adm: Admission, served: dict[str, float]) -> tuple:
        """Arrival time, workflow id as the deterministic tie-break."""
        return (adm.arrival, adm.workflow)


class StrictPriority(AdmissionPolicy):
    """Class rank first (priority < standard < harvest), arrival second."""

    name = "strict-priority"

    def key(self, adm: Admission, served: dict[str, float]) -> tuple:
        """Tenant-class rank, then arrival order."""
        return (_RANK[adm.tenant], adm.arrival, adm.workflow)


class WeightedFair(AdmissionPolicy):
    """Serve classes in proportion to weights: the class with the lowest
    virtual time (device-seconds served / weight) goes first."""

    name = "weighted-fair"
    dynamic = True

    def __init__(self, weights: dict[str, float] | None = None):
        self.weights = dict(weights or
                            {"priority": 4.0, "standard": 2.0,
                             "harvest": 1.0})

    def key(self, adm: Admission, served: dict[str, float]) -> tuple:
        """Virtual time (served / weight), rank + arrival tie-breaks."""
        w = self.weights.get(adm.tenant, 1.0)
        vtime = served.get(adm.tenant, 0.0) / max(w, 1e-9)
        return (vtime, _RANK[adm.tenant], adm.arrival, adm.workflow)


POLICIES: dict[str, type[AdmissionPolicy]] = {
    FCFS.name: FCFS,
    StrictPriority.name: StrictPriority,
    WeightedFair.name: WeightedFair,
}


def get_policy(policy: "str | AdmissionPolicy | None") -> AdmissionPolicy:
    """Normalize a policy name or instance (None -> FCFS)."""
    if policy is None:
        return FCFS()
    if isinstance(policy, AdmissionPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown admission policy {policy!r}; "
                         f"one of {sorted(POLICIES)}") from None


def validate_tenant(tenant: str) -> str:
    """Reject unknown tenant classes; returns the class unchanged."""
    if tenant not in TENANT_CLASSES:
        raise ValueError(f"unknown tenant class {tenant!r}; "
                         f"one of {TENANT_CLASSES}")
    return tenant


@dataclass
class ServedLedger:
    """Device-seconds served per tenant class (feeds weighted-fair).

    Preemption refunds charge negative device-seconds. With work-item
    checkpoint/resume (DESIGN.md §6.4) the refund is step-granular: the
    victim keeps the charge for its completed batch steps and is refunded
    the rest, so a resumed task's served total across attempts equals one
    clean run — virtual time under ``weighted-fair`` is not distorted by
    preemption. A refund never exceeds the task's original charge, so
    class totals stay non-negative (a hypothesis property in
    ``tests/test_checkpoint_resume.py``).
    """

    served: dict[str, float] = field(default_factory=dict)

    def charge(self, tenant: str, device_seconds: float):
        """Accrue served device-seconds (negative on preemption refunds)."""
        self.served[tenant] = self.served.get(tenant, 0.0) + device_seconds
