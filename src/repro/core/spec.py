"""Typed dataflow specs: the scenario-agnostic workflow vocabulary.

The seed hardwired the video workload into the core (``_VIDEO_TASKS`` in the
planner, ``scenes * fps if iface == "summarize"`` cardinality heuristics in
three modules, ``VideoInput`` isinstance checks in the lowering paths). This
module is the replacement vocabulary (DESIGN.md §2):

- ``Artifact`` / ``ArtifactRegistry`` — the dataflow *types* that flow along
  DAG edges ("frames", "passages", "chunk_summaries"). Interfaces declare
  what they produce/consume in these types; the registry makes typos a
  registration-time error instead of a silently-missing edge.
- ``InputSet`` — the protocol job inputs satisfy: an ``artifact`` type plus
  a ``units()`` breakdown ("scenes": 8, "frames": 80). Videos, documents and
  queries are just instances; the core never names any of them.
- ``CardinalityModel`` / ``TokenModel`` — declared *by the agent interface*:
  how many work-items one task invocation fans out to (in input units) and
  its per-item LLM token footprint. Planners read these instead of carrying
  per-interface constants.
- ``TaskSpec`` + ``build_node`` — the typed intermediate between NL task
  text and the scheduling IR; the single shared lowering step for the rule
  planner, the LLM planner and the imperative baseline.
- ``Scenario`` / ``ScenarioRegistry`` — a workload registered *onto* the
  API: default NL decomposition, deliverable (aggregation) stages, and
  toolcall-arg builders, keyed by the input artifact types that trigger it.
  The video pipeline is one registered scenario among peers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Protocol, Sequence, \
    runtime_checkable

from .dag import TaskNode


# ---------------------------------------------------------------------------
# Artifacts: the dataflow type system
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Artifact:
    """One dataflow type that can flow along a DAG edge."""

    name: str
    description: str = ""


class ArtifactRegistry:
    """Known dataflow types; interface registration validates against it."""

    def __init__(self):
        self._types: dict[str, Artifact] = {}

    def define(self, name: str, description: str = "") -> Artifact:
        """Register (or redefine) a dataflow type."""
        art = Artifact(name, description)
        self._types[name] = art
        return art

    def __contains__(self, name: str) -> bool:
        return name in self._types

    def __getitem__(self, name: str) -> Artifact:
        if name not in self._types:
            raise KeyError(
                f"unknown artifact type {name!r}; known: {self.names()}. "
                f"Define it first via ARTIFACTS.define({name!r}, ...)")
        return self._types[name]

    def names(self) -> list[str]:
        """All registered artifact type names."""
        return sorted(self._types)


#: The default registry. Library interfaces and scenarios share it.
ARTIFACTS = ArtifactRegistry()

for _name, _desc in [
    ("video", "raw input video file"),
    ("frames", "sampled video frames"),
    ("transcript", "speech-to-text output"),
    ("objects", "detected/classified objects"),
    ("summary", "scene/frame summaries"),
    ("vectors", "embeddings resident in a vector index"),
    ("answer", "final QA answer"),
    ("query", "user retrieval query"),
    ("passages", "retrieved candidate passages"),
    ("ranked_passages", "reranked passages (relevance order)"),
    ("grounded_answer", "answer synthesized from retrieved context"),
    ("document", "raw input document (pdf/scan)"),
    ("text_chunks", "parsed+chunked document text"),
    ("chunk_summaries", "per-chunk digests"),
    ("chat_turn", "one user turn of an ongoing chat session"),
    ("chat_reply", "assistant reply for a chat turn"),
]:
    ARTIFACTS.define(_name, _desc)


# ---------------------------------------------------------------------------
# Input sets
# ---------------------------------------------------------------------------


@runtime_checkable
class InputSet(Protocol):
    """What a job input must provide: its artifact type and unit counts."""

    artifact: str

    def units(self) -> dict[str, int]:
        """Work-unit breakdown, e.g. ``{"scenes": 4, "frames": 40}``."""
        ...


def input_units(inputs: Sequence[Any]) -> dict[str, int]:
    """Merged unit counts over a job's inputs (summed per unit key).

    Non-``InputSet`` inputs contribute nothing — a job may carry opaque
    payloads alongside typed ones.
    """
    units: dict[str, int] = {}
    for x in inputs:
        if not isinstance(x, InputSet):
            continue
        for k, v in x.units().items():
            units[k] = units.get(k, 0) + int(v)
    return units


def input_artifacts(inputs: Sequence[Any]) -> set[str]:
    """The artifact types present across a job's input sets."""
    return {x.artifact for x in inputs if isinstance(x, InputSet)}


# ---------------------------------------------------------------------------
# Interface-declared workload models
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CardinalityModel:
    """Work-items of one invocation, in terms of the job's input units.

    ``units`` is tried in order; the first key present in the job's merged
    input units wins. An empty tuple (or no key present) yields ``default``
    — one indivisible invocation.
    """

    units: tuple[str, ...] = ()
    default: int = 1

    def items(self, available: Mapping[str, int]) -> int:
        """Work-item count for a job's merged input units."""
        for u in self.units:
            if u in available:
                return max(int(available[u]), 1)
        return self.default


@dataclass(frozen=True)
class TokenModel:
    """Per-work-item LLM token footprint of an interface.

    ``tokens_in``/``tokens_out`` are the fixed per-item footprint.
    ``in_units`` optionally names an input-unit key whose count is *added*
    to ``tokens_in`` (e.g. ``history_tokens`` — conversation history grows
    the prompt per turn); ``prefix_units`` names the unit key counted as
    the session-shared *prefix* span of the prompt, the part a resident KV
    cache can serve (DESIGN.md §9). Both default to empty, making the model
    byte-compatible with the fixed-footprint era.
    """

    tokens_in: int = 0
    tokens_out: int = 0
    in_units: str = ""
    prefix_units: str = ""

    def footprint(self, available: Mapping[str, int]) \
            -> tuple[int, int, int]:
        """``(tokens_in, tokens_out, prefix_tokens)`` for a job's units."""
        tin = self.tokens_in
        if self.in_units:
            tin += int(available.get(self.in_units, 0))
        prefix = int(available.get(self.prefix_units, 0)) \
            if self.prefix_units else 0
        return tin, self.tokens_out, min(prefix, tin)


# ---------------------------------------------------------------------------
# TaskSpec: the typed pre-IR and the shared lowering step
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TaskSpec:
    """One task bound to an interface, before dataflow wiring."""

    description: str
    interface: str
    args: dict = field(default_factory=dict)


def build_node(tid: str, description: str, iface, deps: tuple[str, ...],
               args: dict, units: Mapping[str, int],
               chunkable: bool = True) -> TaskNode:
    """The one place a TaskNode is derived from an interface's models."""
    tin, tout, prefix = iface.tokens.footprint(units)
    return TaskNode(
        id=tid, description=description, agent=iface.name, deps=deps,
        args=args, work_items=iface.cardinality.items(units),
        chunkable=chunkable, tokens_in=tin, tokens_out=tout,
        prefix_tokens=prefix)


# ---------------------------------------------------------------------------
# Scenarios: workloads registered onto the API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """A registered workflow shape: decomposition defaults + arg builders."""

    name: str
    input_artifacts: tuple[str, ...]
    default_tasks: tuple[str, ...]
    aggregate_tasks: tuple[str, ...] = ()
    # interface name -> Callable[[Job], dict] producing toolcall args
    arg_builders: Mapping[str, Callable[[Any], dict]] = \
        field(default_factory=dict)

    def args_for(self, interface: str, job) -> dict:
        """Toolcall args the scenario builds for one interface."""
        builder = self.arg_builders.get(interface)
        return builder(job) if builder is not None else {}


class ScenarioRegistry:
    """Registered workflow shapes, matched by input artifact types."""

    def __init__(self):
        self._scenarios: dict[str, Scenario] = {}

    def register(self, scenario: Scenario) -> Scenario:
        """Add a scenario; its input artifact types must be registered."""
        for art in scenario.input_artifacts:
            ARTIFACTS[art]            # raises on unknown artifact types
        self._scenarios[scenario.name] = scenario
        return scenario

    def __getitem__(self, name: str) -> Scenario:
        self._ensure_builtin()
        return self._scenarios[name]

    def names(self) -> list[str]:
        """All registered scenario names (built-ins loaded lazily)."""
        self._ensure_builtin()
        return sorted(self._scenarios)

    def match(self, inputs: Sequence[Any]) -> Scenario | None:
        """Scenario with the largest input-artifact overlap (ties: first
        registered). ``None`` when no registered scenario applies."""
        self._ensure_builtin()
        arts = input_artifacts(inputs)
        best, best_overlap = None, 0
        for sc in self._scenarios.values():
            overlap = len(arts & set(sc.input_artifacts))
            if overlap > best_overlap:
                best, best_overlap = sc, overlap
        return best

    @staticmethod
    def _ensure_builtin():
        """Import the built-in scenario configs (idempotent, lazy to avoid
        an import cycle: configs modules import core)."""
        from ..configs import (workflow_docingest, workflow_rag,  # noqa: F401
                               workflow_video)


#: The default scenario registry; configs modules register onto it.
SCENARIOS = ScenarioRegistry()
