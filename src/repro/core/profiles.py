"""Execution profiles: (implementation x hardware) efficiency/quality records.

The paper (§3.2 Model/Tool Selection): "Murakkab generates an execution
profile for each model/tool and hardware resource pair when a new one is
added to the library — the profile captures an efficiency vs quality
tradeoff."

Here a profile is generated analytically from the same three-term roofline
the perf analysis uses (DESIGN.md §5.4): latency = max(compute, memory,
collective) over the implementation's workload model and the device's specs.
Measured calibration points (e.g. the paper-cluster Whisper timings in
``configs/workflow_video.py``) can be *pinned* and take precedence — that is
the moral equivalent of the paper's offline profiling runs, amortized across
workflows. A pin may carry a per-batch latency *curve* (DESIGN.md §7.2), so
measured rows batch on calibration data instead of the deprecated
``batch ** alpha`` scalar.
"""
from __future__ import annotations

import math
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from typing import Sequence

try:                                    # the vectorized pricing kernel
    import numpy as _np                 # (DESIGN.md §12); the scalar path
except ImportError:                     # needs no numpy, so its absence
    _np = None                          # only disables batching

from .agents import AgentImpl, AgentLibrary, Work
from .energy import (CATALOG, DeviceSpec, batch_roofline_latency,
                     roofline_latency)


@dataclass(frozen=True, kw_only=True)
class CostQuery:
    """One cost-model query: everything a latency/price question names.

    The four ``ProfileStore`` entry points (``step_latency`` /
    ``schedule_latency`` / ``completed_items`` / ``latency``) used to share
    a positional-kwarg sprawl of ``(impl, spec, n_devices, work, batch,
    items, elapsed_s, ...)``; they now all accept one frozen keyword-only
    query object, so a new pricing dimension threads through one site
    instead of four. ``cache_hit_frac`` is that dimension for KV/prefix
    caching (DESIGN.md §9): the fraction of the item's *input* tokens whose
    prefix KV is already resident on the serving instance — the prefill
    phase is charged only for the un-cached remainder, in both the
    scheduler's estimates and the simulator's actuals (parity by
    construction, since both price through the same query).

    Not hashable (``AgentImpl`` carries dict fields); the memo key is the
    name-based tuple ``ProfileStore`` derives, unchanged from the
    positional era so cache-less queries hit the same entries.
    """

    impl: AgentImpl
    spec: DeviceSpec
    n_devices: int
    work: Work
    batch: int = 1
    items: int = 1
    items_done: int = 0
    elapsed_s: float = 0.0
    cache_hit_frac: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.cache_hit_frac <= 1.0:
            raise ValueError(
                f"cache_hit_frac must be in [0, 1], got {self.cache_hit_frac}")

    def effective_work(self) -> Work:
        """The work actually charged: prefill scaled by the cache miss rate.

        A hit fraction of ``h`` makes ``h`` of the prompt's prefix KV
        resident, so only ``(1-h)`` of the prefill FLOPs/bytes are
        executed; decode is untouched (every output token is new). Works
        without a prefill/decode phase split have no prefill to discount
        and are returned as-is, as is the ``h == 0`` case — the *same*
        object, no float ops, so cold-path pricing is byte-identical to
        the pre-cache model.
        """
        h = self.cache_hit_frac
        w = self.work
        if h <= 0.0 or not w.has_phases:
            return w
        keep = 1.0 - h
        return Work.two_phase(
            prefill_flops=w.prefill_flops * keep,
            decode_flops=w.decode_flops,
            prefill_bytes=w.prefill_bytes * keep,
            decode_bytes=w.decode_bytes,
            weight_bytes=w.weight_bytes,
            decode_steps=w.decode_steps,
            coll_bytes=w.coll_bytes)


# a pinned calibration row: ((batch, per_item_latency_s), ...), sorted by
# batch, per-item latency non-increasing (see _as_curve)
BatchCurve = tuple[tuple[int, float], ...]


def _as_curve(latency_s) -> BatchCurve:
    """Normalize a pin's latency argument into a monotone batch curve.

    Accepts a scalar (per-item seconds at batch=1 — the legacy single-point
    form), a ``{batch: per_item_s}`` mapping, or an iterable of ``(batch,
    per_item_s)`` pairs. Per-item latencies are made non-increasing in batch
    by a running minimum (absorbs measurement noise; co-scheduling more
    items can never *raise* per-item latency on real hardware), and the
    implied step latency ``batch * per_item`` must be non-decreasing — a
    superlinear batching speedup is unphysical and would unsound the
    scheduler's dominated-config pruning bound.
    """
    if isinstance(latency_s, (int, float)):
        pts = [(1, float(latency_s))]
    else:
        items = (latency_s.items() if isinstance(latency_s, dict)
                 else latency_s)
        pts = sorted((int(b), float(v)) for b, v in items)
    if not pts:
        raise ValueError("empty batch-latency curve")
    seen = set()
    for b, v in pts:
        if b < 1:
            raise ValueError(f"batch sizes must be >= 1, got {b}")
        if v <= 0:
            raise ValueError(f"per-item latency must be positive, got {v}")
        if b in seen:
            raise ValueError(f"duplicate batch size {b} in curve")
        seen.add(b)
    if len(pts) == 1 and pts[0][0] != 1:
        raise ValueError(
            f"a single-point pin must be the batch=1 per-item latency "
            f"(got batch {pts[0][0]}): the alpha fallback anchors at b=1 — "
            f"include more batch points to pin a curve instead")
    lo = math.inf
    curve = []
    for b, v in pts:
        lo = min(lo, v)
        curve.append((b, lo))
    for (b0, v0), (b1, v1) in zip(curve, curve[1:]):
        if b1 * v1 < b0 * v0 * (1 - 1e-9):
            raise ValueError(
                f"step latency decreases from batch {b0} ({b0 * v0:.4g}s) "
                f"to batch {b1} ({b1 * v1:.4g}s): a batched step cannot "
                f"take less wall time than a smaller one")
    return tuple(curve)


def _curve_per_item(curve: BatchCurve, batch: int) -> float:
    """Per-item latency at ``batch``, interpolating the measured points.

    Log-log linear between bracketing points — exact for power-law curves
    (``lat1 * b ** (alpha - 1)``), which is how legacy ``batch_alpha``
    calibrations migrate without moving any number — and clamped flat
    outside the measured range (extrapolating a measured curve would claim
    speedups nobody observed).
    """
    if batch <= curve[0][0]:
        return curve[0][1]
    if batch >= curve[-1][0]:
        return curve[-1][1]
    for (b0, v0), (b1, v1) in zip(curve, curve[1:]):
        if b0 <= batch <= b1:
            if batch == b0:
                return v0
            if batch == b1:
                return v1
            t = (math.log(batch) - math.log(b0)) \
                / (math.log(b1) - math.log(b0))
            return math.exp(math.log(v0) + t * (math.log(v1) - math.log(v0)))
    return curve[-1][1]   # unreachable; curve is sorted


@dataclass(frozen=True)
class Profile:
    """One (impl, device SKU, device count) profile row."""

    impl: str
    device: str
    n_devices: int
    latency_s: float          # per work-item
    energy_j: float           # marginal (above idle) energy per work-item
    usd: float                # $ per work-item
    quality: float
    pinned: bool = False      # measured (calibrated) vs analytic


class ProfileStore:
    """Profile generation + pinned calibration overrides.

    ``step_latency`` is the single latency model both the scheduler's
    estimates and the simulator's actuals consume (DESIGN.md §7);
    ``schedule_latency`` composes it into the batched execution schedule of
    a whole task (full steps + one remainder step, §7.2). Results are
    memoized in a bounded LRU keyed by ``(impl, device, n_devices, batch,
    work)`` — the work signature is the frozen ``Work`` dataclass itself —
    so repeated planning over the same library/cluster pays the roofline
    math once; remainder steps land in the same cache under their own
    batch key.
    """

    CACHE_MAX = 8192

    def __init__(self, library: AgentLibrary):
        self.library = library
        # (impl, device, n_devices) -> (batch curve, power_frac)
        self._pinned: dict[tuple[str, str, int], tuple[BatchCurve, float]] = {}
        # impl -> measured quality override (the telemetry feedback loop's
        # calibration target, DESIGN.md §11); absent impls answer with
        # their declared ladder score
        self._quality: dict[str, float] = {}
        self._cache: OrderedDict[tuple, float] = OrderedDict()
        self.cache_enabled = True
        self.cache_hits = 0
        self.cache_misses = 0
        # bumped on every pin(): downstream caches keyed on estimates (the
        # admission plan cache) include it so calibration invalidates them
        self.version = 0
        self._alpha_warned: set[tuple[str, str]] = set()

    # -- calibration ---------------------------------------------------------
    def pin(self, impl: str, device: str, n_devices: int, latency_s,
            power_frac: float | None = None):
        """Pin a measured calibration row for (impl, device, count).

        ``latency_s`` is either a scalar — per-item seconds at batch=1, the
        legacy single-point form — or a per-batch latency curve
        (``{batch: per_item_s}`` mapping or ``(batch, per_item_s)`` pairs)
        captured by e.g. ``benchmarks/calibrate_batch_curves.py``. Curves
        batch by monotone log-log interpolation over the measured points;
        single-point pins fall back to the deprecated ``batch ** alpha``
        scalar (and warn the first time a batched step asks for one).
        Pinned rows take precedence over the analytic roofline, and every
        pin bumps ``version`` / drops the estimate memo so calibration
        invalidates cached plans.
        """
        imp = self.library.impls[impl]
        pf = imp.power_frac if power_frac is None else power_frac
        self._pinned[(impl, device, n_devices)] = (_as_curve(latency_s), pf)
        self._cache.clear()     # calibration invalidates memoized estimates
        self.version += 1

    def pin_quality(self, impl: str, quality: float):
        """Pin a *measured* quality for an implementation (DESIGN.md §11).

        The quality column of the profile library: the scheduler's
        ``quality_floor`` gate and quality estimates read
        :meth:`quality`, so a telemetry-calibrated value (e.g. from
        ``OfflineEvaluator.calibrate_profiles``) changes which impls are
        selectable under a floor. Bumps ``version`` — the admission plan
        cache is keyed on it, so stale plans are invalidated — without
        touching the latency memo (quality prices nothing).
        """
        if impl not in self.library.impls:
            raise KeyError(f"unknown implementation {impl!r}")
        if not 0.0 < quality <= 1.0:
            raise ValueError(f"quality must be in (0, 1], got {quality}")
        self._quality[impl] = float(quality)
        self.version += 1

    def quality(self, impl_name: str) -> float:
        """Implementation quality: the measured (pinned) value when the
        telemetry loop calibrated one, else the declared ladder score.
        With no pins this is exactly ``impl.quality`` — the scheduler's
        pre-quality-column behaviour, byte-identical."""
        q = self._quality.get(impl_name)
        if q is not None:
            return q
        return self.library.impls[impl_name].quality

    # -- queries --------------------------------------------------------------
    def _pinned_curve(self, impl: AgentImpl, spec: DeviceSpec,
                      n_devices: int) -> BatchCurve | None:
        """Calibrated batch curve, or None when only analytic."""
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][0]
        # nearest pinned device-count, strong-scaled (90% efficiency/doubling)
        cands = [(n, v) for (i, d, n), v in self._pinned.items()
                 if i == impl.name and d == spec.name]
        if cands:
            n0, (curve, _) = min(cands, key=lambda c: abs(
                math.log(c[0] / max(n_devices, 1))))
            scale = (n0 / n_devices) ** 0.9
            return tuple((b, v * scale) for b, v in curve)
        return None

    def _warn_alpha_fallback(self, impl: AgentImpl, spec: DeviceSpec):
        key = (impl.name, spec.name)
        if key in self._alpha_warned:
            return
        self._alpha_warned.add(key)
        warnings.warn(
            f"single-point pinned profile for ({impl.name}, {spec.name}): "
            f"batched steps fall back to the deprecated batch_alpha scalar. "
            f"Pin a per-batch latency curve instead (ProfileStore.pin with "
            f"a {{batch: per_item_s}} mapping; capture one with "
            f"benchmarks/calibrate_batch_curves.py).",
            DeprecationWarning, stacklevel=3)

    def _alpha_step(self, impl: AgentImpl, spec: DeviceSpec, base: float,
                    b: int, *, pinned: bool) -> float:
        """The deprecated ``batch ** alpha`` batch model — the one site.

        ``base`` is the batch=1 step latency: a single-point pin's
        per-item latency, or the analytic ``overhead + roofline`` for a
        work without a prefill/decode phase split. Pinned rows warn once
        per (impl, device) when actually batched — they *could* carry a
        measured curve and should; analytic phase-less works stay silent
        (alpha is their declared batch model, there is nothing to
        migrate).
        """
        if pinned and b > 1:
            self._warn_alpha_fallback(impl, spec)
        return base * b ** impl.batch_alpha

    @staticmethod
    def _require_query(method: str, query) -> None:
        if not isinstance(query, CostQuery):
            raise TypeError(
                f"ProfileStore.{method} takes a CostQuery; the positional "
                f"(impl, spec, n_devices, ...) form was removed — build "
                f"CostQuery(impl=..., spec=..., n_devices=..., work=...)")

    def _step(self, impl: AgentImpl, spec: DeviceSpec, n_devices: int,
              work: Work, batch: int) -> float:
        """Memoized one-step latency on an *effective* (post-discount) work.

        Three regimes, in precedence order:

        - *pinned* (measured) rows batch over their calibrated per-batch
          latency curve (monotone log-log interpolation); single-point pins
          carry no batch information, so the deprecated ``batch ** alpha``
          scalar stays their batch model (with a one-time warning);
        - analytic works *with* a prefill/decode phase split use the
          batch-aware roofline (weights stream amortizes across the batch);
        - analytic works without a split fall back to ``batch ** alpha``.
        """
        key = (impl.name, spec.name, n_devices, batch, work)
        if self.cache_enabled:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.cache_hits += 1
                return hit
            self.cache_misses += 1
        curve = self._pinned_curve(impl, spec, n_devices)
        b = max(batch, 1)
        if curve is not None:
            if len(curve) > 1:
                step = b * _curve_per_item(curve, b)
            else:
                step = self._alpha_step(impl, spec, curve[0][1], b,
                                        pinned=True)
        elif work.has_phases:
            step = impl.overhead_s + b * batch_roofline_latency(
                work, spec, n_devices=n_devices, batch=batch,
                efficiency=impl.mxu_efficiency)
        else:
            step = self._alpha_step(
                impl, spec,
                impl.overhead_s + roofline_latency(
                    work.flops, work.hbm_bytes, spec, n_devices=n_devices,
                    collective_bytes=work.coll_bytes,
                    efficiency=impl.mxu_efficiency),
                b, pinned=False)
        if self.cache_enabled:
            self._cache[key] = step
            if len(self._cache) > self.CACHE_MAX:
                self._cache.popitem(last=False)
        return step

    def step_latency(self, query: CostQuery) -> float:
        """Wall time of ONE step co-scheduling ``query.batch`` work-items.

        The query's ``cache_hit_frac`` discounts the prefill phase before
        pricing (:meth:`CostQuery.effective_work`); at hit 0 the step is
        priced on the original work object, byte-identical to the cache-less
        model.
        """
        self._require_query("step_latency", query)
        return self._step(query.impl, query.spec, query.n_devices,
                          query.effective_work(), query.batch)

    def schedule_latency(self, query: CostQuery) -> float:
        """Wall time to run ``query.items`` work-items in ``batch`` batches.

        The batched execution schedule (DESIGN.md §7.2): ``floor(items/b)``
        full steps plus — when ``items % b != 0`` — one *remainder* step
        charged at ``step_latency(items % b)``, not at the full batch's
        price. ``Scheduler.estimate`` and ``Simulator._duration`` both call
        this, so estimate/actual parity holds by construction — including
        the prefill discount at ``query.cache_hit_frac`` (one pricing site,
        DESIGN.md §9). The schedule never exceeds the legacy
        ``ceil(items/b)`` full-step charge
        (``tests/test_batch_schedule.py`` holds the property).
        """
        self._require_query("schedule_latency", query)
        eff = query.effective_work()
        b = max(int(query.batch), 1)
        items = max(int(query.items), 0)
        if items == 0:
            return 0.0
        full, rem = divmod(items, b)
        total = full * self._step(query.impl, query.spec, query.n_devices,
                                  eff, b) if full else 0.0
        if rem:
            total += self._step(query.impl, query.spec, query.n_devices,
                                eff, rem)
        return total

    # -- vectorized batch kernel (DESIGN.md §12) ------------------------------
    def step_latency_batch(self, queries: "Sequence[CostQuery]") \
            -> list[float]:
        """Price many one-step queries in one call — the batch kernel.

        Bitwise-identical to mapping :meth:`step_latency` over ``queries``,
        by construction: the analytic regimes' roofline arithmetic
        (divisions, maxima, multiply-adds) runs as numpy elementwise ops
        over the whole miss set — each IEEE-754 elementwise ``+ - * /`` and
        ``maximum`` has exactly one correctly-rounded answer, so the lanes
        match the scalar path bit for bit. Transcendentals do NOT vectorize
        safely (numpy's SIMD ``log``/``exp``/``power`` round differently
        from libm on ~3% of inputs), so the ``batch ** alpha`` power law
        and the pinned curve's log-log interpolation stay scalar per
        element. Results land in the shared step memo: later scalar calls
        on the same keys are hits, which is how the scheduler's grid
        prewarm feeds the estimate loop.
        """
        n_q = len(queries)
        out: list = [None] * n_q
        cache = self._cache if self.cache_enabled else None
        # miss buckets: row = (position, resolved inputs...)
        phased: list[tuple] = []        # analytic, prefill/decode split
        alpha: list[tuple] = []         # analytic, no split (power law)
        for i, q in enumerate(queries):
            self._require_query("step_latency_batch", q)
            work = q.effective_work()
            key = (q.impl.name, q.spec.name, q.n_devices, q.batch, work)
            if cache is not None:
                hit = cache.get(key)
                if hit is not None:
                    cache.move_to_end(key)
                    self.cache_hits += 1
                    out[i] = hit
                    continue
            curve = self._pinned_curve(q.impl, q.spec, q.n_devices)
            if curve is not None or _np is None:
                # pinned rows (and the no-numpy fallback) price through
                # the scalar path — it owns the memo bookkeeping
                out[i] = self._step(q.impl, q.spec, q.n_devices, work,
                                    q.batch)
                continue
            self.cache_misses += 1
            b = max(q.batch, 1)
            n = max(q.n_devices, 1)
            if work.has_phases:
                phased.append((i, key, q, work, b, n))
            else:
                alpha.append((i, key, q, work, b, n))
        if phased:
            # step = overhead + b * (max(compute, memory, coll) / b), with
            # every expression shaped exactly like batch_roofline_latency's
            bv = _np.array([r[4] for r in phased], dtype=float)
            nv = _np.array([r[5] for r in phased], dtype=float)
            flops = _np.array([r[3].flops for r in phased])
            shared = _np.array([r[3].shared_bytes for r in phased])
            per_it = _np.array([r[3].per_item_bytes for r in phased])
            coll = _np.array([r[3].coll_bytes for r in phased])
            peak = _np.array([r[2].spec.peak_flops for r in phased])
            hbm = _np.array([r[2].spec.hbm_bw for r in phased])
            link = _np.array([r[2].spec.link_bw for r in phased])
            eff = _np.array([r[2].impl.mxu_efficiency for r in phased])
            over = _np.array([r[2].impl.overhead_s for r in phased])
            t_c = bv * flops / (nv * peak * eff)
            t_m = (shared + bv * per_it) / (nv * hbm)
            t_x = _np.zeros_like(t_c)
            nz = link != 0.0
            if nz.any():
                t_x[nz] = bv[nz] * coll[nz] / (nv[nz] * link[nz])
            step = over + bv * (_np.maximum(_np.maximum(t_c, t_m), t_x)
                                / bv)
            for (i, key, _q, _w, _b, _n), s in zip(phased, step):
                out[i] = s = float(s)
                if cache is not None:
                    cache[key] = s
        if alpha:
            # base = overhead + max(three roofline terms); the power law
            # itself stays scalar (libm, via _alpha_step — one fallback
            # site, shared with the scalar path)
            nv = _np.array([r[5] for r in alpha], dtype=float)
            flops = _np.array([r[3].flops for r in alpha])
            hbytes = _np.array([r[3].hbm_bytes for r in alpha])
            coll = _np.array([r[3].coll_bytes for r in alpha])
            peak = _np.array([r[2].spec.peak_flops for r in alpha])
            hbm = _np.array([r[2].spec.hbm_bw for r in alpha])
            link = _np.array([r[2].spec.link_bw for r in alpha])
            eff = _np.array([r[2].impl.mxu_efficiency for r in alpha])
            over = _np.array([r[2].impl.overhead_s for r in alpha])
            t_c = flops / (nv * peak * eff)
            t_m = hbytes / (nv * hbm)
            t_x = _np.zeros_like(t_c)
            nz = link != 0.0
            if nz.any():
                t_x[nz] = coll[nz] / (nv[nz] * link[nz])
            base = over + _np.maximum(_np.maximum(t_c, t_m), t_x)
            for (i, key, q, _w, b, _n), bs in zip(alpha, base):
                out[i] = s = float(self._alpha_step(q.impl, q.spec,
                                                    float(bs), b,
                                                    pinned=False))
                if cache is not None:
                    cache[key] = s
        if cache is not None:
            while len(cache) > self.CACHE_MAX:
                cache.popitem(last=False)
        return out

    def schedule_latency_batch(self, queries: "Sequence[CostQuery]") \
            -> list[float]:
        """Batched-execution schedules for many queries in one kernel call.

        Expands each query into its full-batch step and (when ``items %
        batch != 0``) its remainder step, prices all steps through
        :meth:`step_latency_batch`, and recomposes ``full * step(b) +
        step(rem)`` — the exact float-op sequence of
        :meth:`schedule_latency`, so results (and the memo entries left
        behind) are bitwise-identical to the scalar path.
        """
        step_qs: list[CostQuery] = []
        plan: list[tuple] = []
        for q in queries:
            self._require_query("schedule_latency_batch", q)
            eff = q.effective_work()
            b = max(int(q.batch), 1)
            items = max(int(q.items), 0)
            if items == 0:
                plan.append((0, 0, None, None))
                continue
            full, rem = divmod(items, b)
            i_b = i_r = None
            if full:
                i_b = len(step_qs)
                step_qs.append(CostQuery(impl=q.impl, spec=q.spec,
                                         n_devices=q.n_devices, work=eff,
                                         batch=b))
            if rem:
                i_r = len(step_qs)
                step_qs.append(CostQuery(impl=q.impl, spec=q.spec,
                                         n_devices=q.n_devices, work=eff,
                                         batch=rem))
            plan.append((full, rem, i_b, i_r))
        steps = self.step_latency_batch(step_qs)
        out = []
        for full, rem, i_b, i_r in plan:
            total = full * steps[i_b] if full else 0.0
            if rem:
                total += steps[i_r]
            out.append(total)
        return out

    def completed_items(self, query: CostQuery) -> tuple[int, float]:
        """Invert the ``schedule_latency`` step schedule at ``elapsed_s``.

        Returns ``(items_done, wall_s)``: how many work-items' batch steps
        had *fully completed* after ``query.elapsed_s`` seconds of the
        schedule, and the wall time those completed steps took. A step
        checkpoints only at its end — a preempted in-flight step is
        discarded work — so full steps complete every ``step_latency(b)``
        seconds and the remainder step only at the schedule's very end. The
        simulator uses this to salvage a preempted task's finished items
        (DESIGN.md §6.4): re-running the residual then costs exactly
        ``schedule_latency(items) - wall_s``, which is what keeps the
        step-granular refund and estimate/actual parity exact. The
        inversion prices the same effective (cache-discounted) work the
        schedule charged, so refunds invert exactly what was billed.
        """
        self._require_query("completed_items", query)
        eff = query.effective_work()
        b = max(int(query.batch), 1)
        items = max(int(query.items), 0)
        elapsed_s = query.elapsed_s
        if items == 0 or elapsed_s <= 0:
            return 0, 0.0
        step_b = self._step(query.impl, query.spec, query.n_devices, eff, b)
        full, rem = divmod(items, b)
        # 1e-9 of slack so a preemption landing exactly on a step boundary
        # credits the step that just finished
        steps = min(int((elapsed_s + 1e-9) / max(step_b, 1e-12)), full)
        done, wall = steps * b, steps * step_b
        if steps == full and rem:
            rem_lat = self._step(query.impl, query.spec, query.n_devices,
                                 eff, rem)
            if elapsed_s + 1e-9 >= wall + rem_lat:
                done, wall = items, wall + rem_lat
        return done, wall

    def cache_info(self) -> dict:
        """Estimate-memo counters: hits, misses, size, cap and hit rate."""
        total = self.cache_hits + self.cache_misses
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "size": len(self._cache), "max": self.CACHE_MAX,
                "hit_rate": self.cache_hits / total if total else 0.0}

    def cache_reset(self, enabled: bool = True):
        """Drop memoized estimates and zero the counters (benchmarks)."""
        self._cache.clear()
        self.cache_enabled = enabled
        self.cache_hits = self.cache_misses = 0

    def pinned_counts(self, impl_name: str, device: str) -> list[int]:
        """Profiled device counts for (impl, device). When non-empty, the
        scheduler selects among exactly these configurations — the paper's
        semantics: selection happens over the profile library."""
        return sorted(n for (i, d, n) in self._pinned
                      if i == impl_name and d == device)

    def pinned_batches(self, impl_name: str, device: str) -> list[int]:
        """Calibrated batch sizes for (impl, device), across all pinned
        counts. Non-empty for measured rows; the joint lever search uses
        these points as the batch candidate grid (selection over the
        profile library, mirroring ``pinned_counts``)."""
        out: set[int] = set()
        for (i, d, _n), (curve, _pf) in self._pinned.items():
            if i == impl_name and d == device:
                out.update(b for b, _ in curve)
        return sorted(out)

    def power_frac(self, impl: AgentImpl, spec: DeviceSpec,
                   n_devices: int) -> float:
        """Fraction of (active - idle) power drawn while running; pinned
        rows override the implementation's declared fraction."""
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][1]
        return impl.power_frac

    def profile(self, impl_name: str, device: str, n_devices: int,
                tokens_in: int = 1024, tokens_out: int = 256) -> Profile:
        """One profile row: per-item latency/energy/$ and quality for an
        (impl, device, count) triple at the given token footprint."""
        impl = self.library.impls[impl_name]
        spec = CATALOG[device]
        work = impl.work_fn(tokens_in, tokens_out)
        lat = self._step(impl, spec, n_devices, work, 1)
        pf = self.power_frac(impl, spec, n_devices)
        energy = lat * n_devices * pf * (spec.active_w - spec.idle_w)
        usd = lat * n_devices / 3600.0 * spec.usd_per_hour
        return Profile(impl=impl_name, device=device, n_devices=n_devices,
                       latency_s=lat, energy_j=energy, usd=usd,
                       quality=self.quality(impl_name),
                       pinned=(impl_name, device, n_devices) in self._pinned)

    # -- the "profile everything on add" sweep --------------------------------
    def profile_table(self, devices: dict[str, list[int]],
                      tokens_in: int = 1024, tokens_out: int = 256) \
            -> list[Profile]:
        """Profiles for every (impl x compatible device x count) pair.

        ``devices``: device-SKU name -> candidate device counts.
        """
        rows: list[Profile] = []
        for impl in self.library.impls.values():
            for dev, counts in devices.items():
                spec = CATALOG[dev]
                if spec.kind not in impl.hw_kinds:
                    continue
                lo = impl.min_devices.get(spec.kind, 1)
                hi = impl.max_devices.get(spec.kind, max(counts))
                for n in counts:
                    if lo <= n <= hi:
                        rows.append(self.profile(impl.name, dev, n,
                                                 tokens_in, tokens_out))
        return rows
