"""Execution profiles: (implementation x hardware) efficiency/quality records.

The paper (§3.2 Model/Tool Selection): "Murakkab generates an execution
profile for each model/tool and hardware resource pair when a new one is
added to the library — the profile captures an efficiency vs quality
tradeoff."

Here a profile is generated analytically from the same three-term roofline
the perf analysis uses (DESIGN.md §5.4): latency = max(compute, memory,
collective) over the implementation's workload model and the device's specs.
Measured calibration points (e.g. the paper-cluster Whisper timings in
``configs/workflow_video.py``) can be *pinned* and take precedence — that is
the moral equivalent of the paper's offline profiling runs, amortized across
workflows.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .agents import AgentImpl, AgentLibrary, Work
from .energy import CATALOG, DeviceSpec, roofline_latency


@dataclass(frozen=True)
class Profile:
    """One (impl, device SKU, device count) profile row."""

    impl: str
    device: str
    n_devices: int
    latency_s: float          # per work-item
    energy_j: float           # marginal (above idle) energy per work-item
    usd: float                # $ per work-item
    quality: float
    pinned: bool = False      # measured (calibrated) vs analytic


class ProfileStore:
    """Profile generation + pinned calibration overrides."""

    def __init__(self, library: AgentLibrary):
        self.library = library
        # (impl, device, n_devices) -> (latency_s per item, power_frac)
        self._pinned: dict[tuple[str, str, int], tuple[float, float]] = {}

    # -- calibration ---------------------------------------------------------
    def pin(self, impl: str, device: str, n_devices: int, latency_s: float,
            power_frac: float | None = None):
        imp = self.library.impls[impl]
        pf = imp.power_frac if power_frac is None else power_frac
        self._pinned[(impl, device, n_devices)] = (latency_s, pf)

    # -- queries --------------------------------------------------------------
    def latency(self, impl: AgentImpl, spec: DeviceSpec, n_devices: int,
                work: Work) -> float:
        """Per-work-item latency for one instance of ``n_devices``."""
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][0]
        # nearest pinned device-count, strong-scaled (90% efficiency/doubling)
        cands = [(n, v) for (i, d, n), v in self._pinned.items()
                 if i == impl.name and d == spec.name]
        if cands:
            n0, (lat0, _) = min(cands, key=lambda c: abs(
                math.log(c[0] / max(n_devices, 1))))
            scale = (n0 / n_devices) ** 0.9
            return lat0 * scale
        return impl.overhead_s + roofline_latency(
            work.flops, work.hbm_bytes, spec, n_devices=n_devices,
            collective_bytes=work.coll_bytes,
            efficiency=impl.mxu_efficiency)

    def pinned_counts(self, impl_name: str, device: str) -> list[int]:
        """Profiled device counts for (impl, device). When non-empty, the
        scheduler selects among exactly these configurations — the paper's
        semantics: selection happens over the profile library."""
        return sorted(n for (i, d, n) in self._pinned
                      if i == impl_name and d == device)

    def power_frac(self, impl: AgentImpl, spec: DeviceSpec,
                   n_devices: int) -> float:
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][1]
        return impl.power_frac

    def profile(self, impl_name: str, device: str, n_devices: int,
                tokens_in: int = 1024, tokens_out: int = 256) -> Profile:
        impl = self.library.impls[impl_name]
        spec = CATALOG[device]
        work = impl.work_fn(tokens_in, tokens_out)
        lat = self.latency(impl, spec, n_devices, work)
        pf = self.power_frac(impl, spec, n_devices)
        energy = lat * n_devices * pf * (spec.active_w - spec.idle_w)
        usd = lat * n_devices / 3600.0 * spec.usd_per_hour
        return Profile(impl=impl_name, device=device, n_devices=n_devices,
                       latency_s=lat, energy_j=energy, usd=usd,
                       quality=impl.quality,
                       pinned=(impl_name, device, n_devices) in self._pinned)

    # -- the "profile everything on add" sweep --------------------------------
    def profile_table(self, devices: dict[str, list[int]],
                      tokens_in: int = 1024, tokens_out: int = 256) \
            -> list[Profile]:
        """Profiles for every (impl x compatible device x count) pair.

        ``devices``: device-SKU name -> candidate device counts.
        """
        rows: list[Profile] = []
        for impl in self.library.impls.values():
            for dev, counts in devices.items():
                spec = CATALOG[dev]
                if spec.kind not in impl.hw_kinds:
                    continue
                lo = impl.min_devices.get(spec.kind, 1)
                hi = impl.max_devices.get(spec.kind, max(counts))
                for n in counts:
                    if lo <= n <= hi:
                        rows.append(self.profile(impl.name, dev, n,
                                                 tokens_in, tokens_out))
        return rows
