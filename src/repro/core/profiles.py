"""Execution profiles: (implementation x hardware) efficiency/quality records.

The paper (§3.2 Model/Tool Selection): "Murakkab generates an execution
profile for each model/tool and hardware resource pair when a new one is
added to the library — the profile captures an efficiency vs quality
tradeoff."

Here a profile is generated analytically from the same three-term roofline
the perf analysis uses (DESIGN.md §5.4): latency = max(compute, memory,
collective) over the implementation's workload model and the device's specs.
Measured calibration points (e.g. the paper-cluster Whisper timings in
``configs/workflow_video.py``) can be *pinned* and take precedence — that is
the moral equivalent of the paper's offline profiling runs, amortized across
workflows.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass

from .agents import AgentImpl, AgentLibrary, Work
from .energy import (CATALOG, DeviceSpec, batch_roofline_latency,
                     roofline_latency)


@dataclass(frozen=True)
class Profile:
    """One (impl, device SKU, device count) profile row."""

    impl: str
    device: str
    n_devices: int
    latency_s: float          # per work-item
    energy_j: float           # marginal (above idle) energy per work-item
    usd: float                # $ per work-item
    quality: float
    pinned: bool = False      # measured (calibrated) vs analytic


class ProfileStore:
    """Profile generation + pinned calibration overrides.

    ``step_latency`` is the single latency model both the scheduler's
    estimates and the simulator's actuals consume (DESIGN.md §7). Results
    are memoized in a bounded LRU keyed by
    ``(impl, device, n_devices, batch, work)`` — the work signature is the
    frozen ``Work`` dataclass itself — so repeated planning over the same
    library/cluster pays the roofline math once.
    """

    CACHE_MAX = 8192

    def __init__(self, library: AgentLibrary):
        self.library = library
        # (impl, device, n_devices) -> (latency_s per item, power_frac)
        self._pinned: dict[tuple[str, str, int], tuple[float, float]] = {}
        self._cache: OrderedDict[tuple, float] = OrderedDict()
        self.cache_enabled = True
        self.cache_hits = 0
        self.cache_misses = 0
        # bumped on every pin(): downstream caches keyed on estimates (the
        # admission plan cache) include it so calibration invalidates them
        self.version = 0

    # -- calibration ---------------------------------------------------------
    def pin(self, impl: str, device: str, n_devices: int, latency_s: float,
            power_frac: float | None = None):
        imp = self.library.impls[impl]
        pf = imp.power_frac if power_frac is None else power_frac
        self._pinned[(impl, device, n_devices)] = (latency_s, pf)
        self._cache.clear()     # calibration invalidates memoized estimates
        self.version += 1

    # -- queries --------------------------------------------------------------
    def _pinned_per_item(self, impl: AgentImpl, spec: DeviceSpec,
                         n_devices: int) -> float | None:
        """Calibrated per-item latency, or None when only analytic."""
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][0]
        # nearest pinned device-count, strong-scaled (90% efficiency/doubling)
        cands = [(n, v) for (i, d, n), v in self._pinned.items()
                 if i == impl.name and d == spec.name]
        if cands:
            n0, (lat0, _) = min(cands, key=lambda c: abs(
                math.log(c[0] / max(n_devices, 1))))
            return lat0 * (n0 / n_devices) ** 0.9
        return None

    def step_latency(self, impl: AgentImpl, spec: DeviceSpec, n_devices: int,
                     work: Work, batch: int = 1) -> float:
        """Wall time of ONE step co-scheduling ``batch`` work-items.

        Three regimes, in precedence order:

        - *pinned* (measured) rows carry no FLOP/byte decomposition, so the
          deprecated ``batch ** alpha`` scalar stays their batch model;
        - analytic works *with* a prefill/decode phase split use the
          batch-aware roofline (weights stream amortizes across the batch);
        - analytic works without a split fall back to ``batch ** alpha``.
        """
        key = (impl.name, spec.name, n_devices, batch, work)
        if self.cache_enabled:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.cache_hits += 1
                return hit
            self.cache_misses += 1
        pinned = self._pinned_per_item(impl, spec, n_devices)
        if pinned is not None:
            step = pinned * batch ** impl.batch_alpha
        elif work.has_phases:
            step = impl.overhead_s + max(batch, 1) * batch_roofline_latency(
                work, spec, n_devices=n_devices, batch=batch,
                efficiency=impl.mxu_efficiency)
        else:
            step = (impl.overhead_s + roofline_latency(
                work.flops, work.hbm_bytes, spec, n_devices=n_devices,
                collective_bytes=work.coll_bytes,
                efficiency=impl.mxu_efficiency)) * batch ** impl.batch_alpha
        if self.cache_enabled:
            self._cache[key] = step
            if len(self._cache) > self.CACHE_MAX:
                self._cache.popitem(last=False)
        return step

    def latency(self, impl: AgentImpl, spec: DeviceSpec, n_devices: int,
                work: Work, batch: int = 1) -> float:
        """Per-work-item latency within a batch of ``batch`` items."""
        return self.step_latency(impl, spec, n_devices, work, batch) \
            / max(batch, 1)

    def cache_info(self) -> dict:
        total = self.cache_hits + self.cache_misses
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "size": len(self._cache), "max": self.CACHE_MAX,
                "hit_rate": self.cache_hits / total if total else 0.0}

    def cache_reset(self, enabled: bool = True):
        """Drop memoized estimates and zero the counters (benchmarks)."""
        self._cache.clear()
        self.cache_enabled = enabled
        self.cache_hits = self.cache_misses = 0

    def pinned_counts(self, impl_name: str, device: str) -> list[int]:
        """Profiled device counts for (impl, device). When non-empty, the
        scheduler selects among exactly these configurations — the paper's
        semantics: selection happens over the profile library."""
        return sorted(n for (i, d, n) in self._pinned
                      if i == impl_name and d == device)

    def power_frac(self, impl: AgentImpl, spec: DeviceSpec,
                   n_devices: int) -> float:
        key = (impl.name, spec.name, n_devices)
        if key in self._pinned:
            return self._pinned[key][1]
        return impl.power_frac

    def profile(self, impl_name: str, device: str, n_devices: int,
                tokens_in: int = 1024, tokens_out: int = 256) -> Profile:
        impl = self.library.impls[impl_name]
        spec = CATALOG[device]
        work = impl.work_fn(tokens_in, tokens_out)
        lat = self.latency(impl, spec, n_devices, work)
        pf = self.power_frac(impl, spec, n_devices)
        energy = lat * n_devices * pf * (spec.active_w - spec.idle_w)
        usd = lat * n_devices / 3600.0 * spec.usd_per_hour
        return Profile(impl=impl_name, device=device, n_devices=n_devices,
                       latency_s=lat, energy_j=energy, usd=usd,
                       quality=impl.quality,
                       pinned=(impl_name, device, n_devices) in self._pinned)

    # -- the "profile everything on add" sweep --------------------------------
    def profile_table(self, devices: dict[str, list[int]],
                      tokens_in: int = 1024, tokens_out: int = 256) \
            -> list[Profile]:
        """Profiles for every (impl x compatible device x count) pair.

        ``devices``: device-SKU name -> candidate device counts.
        """
        rows: list[Profile] = []
        for impl in self.library.impls.values():
            for dev, counts in devices.items():
                spec = CATALOG[dev]
                if spec.kind not in impl.hw_kinds:
                    continue
                lo = impl.min_devices.get(spec.kind, 1)
                hi = impl.max_devices.get(spec.kind, max(counts))
                for n in counts:
                    if lo <= n <= hi:
                        rows.append(self.profile(impl.name, dev, n,
                                                 tokens_in, tokens_out))
        return rows
