"""Discrete-event execution engine over the modeled cluster.

Runs one or many workflows (DAG + ExecutionPlan) against the cluster
manager's pools: list-scheduling with dependency and capacity constraints,
warm-instance reuse, cold-start (weights-load) latencies, and energy/$
integration via ``EnergyLedger``. Produces per-task traces — the Fig-3
artifact — and is the scale path (a 1000-node cluster is just bigger pool
capacities; the engine is O(events log events)).

Semantics notes:
- A *model* implementation (``load_time_s > 0`` or zoo-backed) executes on
  persistent warm instances; first use pays the load. Tools alloc/release
  per task.
- If fewer than ``n_instances`` instances fit right now, the task degrades
  gracefully to what fits (>=1) rather than deadlocking; if none fit, it
  waits for the next completion event.
- Energy: active increments per task; the idle floor for every metered pool
  is integrated over the makespan at ``finalize`` (paper Table-2 semantics).
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

from .agents import AgentLibrary
from .cluster import ClusterManager, Instance, Lease
from .dag import DAG
from .energy import CATALOG, EnergyLedger
from .profiles import ProfileStore
from .scheduler import ExecutionPlan, TaskConfig


@dataclass(frozen=True)
class TraceEntry:
    workflow: str
    task: str
    impl: str
    pool: str
    devices: int              # total devices (n_devices * n_instances)
    start: float
    end: float
    note: str = ""


@dataclass
class SimReport:
    makespan_s: float
    energy_wh: float
    active_wh: float
    idle_wh: float
    usd: float
    trace: list[TraceEntry]
    per_workflow: dict[str, dict]
    pool_busy_device_s: dict[str, float]
    preemptions: int = 0

    def workflow_span(self, wf: str) -> float:
        return self.per_workflow[wf]["finish"] - self.per_workflow[wf]["start"]


@dataclass
class _WfState:
    dag: DAG
    plan: ExecutionPlan
    arrival: float
    done: set[str] = field(default_factory=set)
    started: set[str] = field(default_factory=set)
    finish: float = 0.0


class Simulator:
    def __init__(self, cluster: ClusterManager, library: AgentLibrary,
                 profiles: ProfileStore):
        self.cluster = cluster
        self.library = library
        self.profiles = profiles

    # -- duration under actual warmth ------------------------------------------
    def _duration(self, node, cfg: TaskConfig, n_inst: int,
                  new_instances: int) -> float:
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        work = impl.work_fn(node.tokens_in, node.tokens_out)
        per_item = self.profiles.latency(impl, spec, cfg.n_devices, work)
        batch = 1 if spec.kind == "cpu" else cfg.batch
        items = math.ceil(node.work_items / max(n_inst, 1))
        steps = math.ceil(items / batch)
        compute = steps * per_item * batch ** impl.batch_alpha
        lat = compute
        if new_instances and not cfg.warm:
            # cfg.warm = provisioned capacity (PTU-style): always-on, no load
            lat += impl.load_time_s
        return lat, compute

    def _is_model(self, impl) -> bool:
        return impl.load_time_s > 0 or impl.arch is not None

    # -- engine ------------------------------------------------------------------
    def run(self, workflows: dict[str, tuple[DAG, ExecutionPlan, float]],
            log: list | None = None) -> SimReport:
        wfs = {wid: _WfState(dag, plan, arrival)
               for wid, (dag, plan, arrival) in workflows.items()}
        for wid, st in wfs.items():
            self.cluster.register_workflow(wid, st.dag)

        ledger = EnergyLedger()
        trace: list[TraceEntry] = []
        busy: dict[str, float] = {}
        events: list[tuple[float, int, str, str, list[Lease],
                           list[Instance]]] = []
        ctr = itertools.count()
        for wid, st in wfs.items():
            heapq.heappush(events, (st.arrival, next(ctr), "arrive", wid,
                                    [], []))
        t = 0.0

        def ready_tasks():
            out = []
            for wid, st in sorted(wfs.items(),
                                  key=lambda kv: kv[1].arrival):
                if t < st.arrival:
                    continue
                for tid in st.dag.topo_order:
                    if tid in st.done or tid in st.started:
                        continue
                    if all(d in st.done for d in st.dag.nodes[tid].deps):
                        out.append((wid, tid))
            return out

        def try_start(wid: str, tid: str) -> bool:
            st = wfs[wid]
            node = st.dag.nodes[tid]
            cfg = st.plan[tid]
            impl = self.library.impls[cfg.impl]
            spec = CATALOG[self.cluster.pools[cfg.pool].device]
            leases: list[Lease] = []
            insts: list[Instance] = []
            new_inst = 0
            # degrade configs planned for a larger cluster (elasticity)
            cap = self.cluster.pools[cfg.pool].capacity
            if cfg.n_devices > cap:
                lo = impl.min_devices.get(spec.kind, 1)
                n = 1
                while n * 2 <= cap:
                    n *= 2
                if n < lo:
                    raise RuntimeError(
                        f"{cfg.impl} needs >= {lo} {spec.kind} devices; "
                        f"pool {cfg.pool} has {cap}")
                cfg = cfg.with_(n_devices=n, n_instances=1)
                st.plan.configs[tid] = cfg

            def _alloc_or_evict(n):
                lease = self.cluster.alloc(cfg.pool, n, t)
                if lease is None:
                    # evict idle warm instances of *other* impls (LRU)
                    idle = sorted(
                        (i for i in self.cluster.instances
                         if i.pool == cfg.pool and i.busy_until <= t
                         and i.impl != cfg.impl),
                        key=lambda i: i.warm_since)
                    for victim in idle:
                        self.cluster.evict_instance(victim, t)
                        lease = self.cluster.alloc(cfg.pool, n, t)
                        if lease is not None:
                            break
                return lease

            if self._is_model(impl):
                # reuse idle warm instances on the right pool/size first
                avail = [i for i in self.cluster.instances
                         if i.impl == cfg.impl and i.pool == cfg.pool
                         and i.n_devices == cfg.n_devices
                         and i.busy_until <= t]
                insts = avail[:cfg.n_instances]
                while len(insts) < cfg.n_instances:
                    lease = _alloc_or_evict(cfg.n_devices)
                    if lease is None:
                        break
                    inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                                    warm_since=t, lease=lease)
                    self.cluster.add_instance(inst)
                    insts.append(inst)
                    new_inst += 1
                if not insts:
                    return False
                n_inst = len(insts)
            else:
                total = cfg.n_devices * cfg.n_instances
                lease = self.cluster.alloc(cfg.pool, total, t)
                n_inst = cfg.n_instances
                if lease is None:
                    lease = _alloc_or_evict(cfg.n_devices)
                    n_inst = 1
                    if lease is None:
                        return False
                leases.append(lease)

            dur, compute = self._duration(node, cfg, n_inst, new_inst)
            dur *= cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
            end = t + dur
            for inst in insts:
                inst.busy_until = end
            ndev = cfg.n_devices * n_inst
            dev_s = compute * ndev * cfg.paths
            pf = self.profiles.power_frac(impl, spec, cfg.n_devices)
            ledger.charge_active(spec, dev_s, utilization=pf, pool=cfg.pool)
            busy[cfg.pool] = busy.get(cfg.pool, 0.0) + dev_s
            st.started.add(tid)
            trace.append(TraceEntry(wid, tid, cfg.impl, cfg.pool, ndev, t,
                                    end,
                                    note="cold" if new_inst else
                                    ("warm" if insts else "")))
            heapq.heappush(events, (end, next(ctr), "finish", f"{wid}|{tid}",
                                    leases, []))
            if log is not None:
                log.append(f"[{t:8.1f}s] start {wid}:{tid} on "
                           f"{ndev}x{cfg.pool} ({cfg.impl})")
            return True

        while events:
            t, _, kind, key, leases, _ = heapq.heappop(events)
            if kind == "finish":
                wid, tid = key.split("|")
                st = wfs[wid]
                st.done.add(tid)
                st.finish = max(st.finish, t)
                self.cluster.complete_task(wid, tid)
                for lease in leases:
                    # model instances keep their devices (stay warm); tools
                    # release. Instance devices are reclaimed by rebalance.
                    impl = self.library.impls[st.plan[tid].impl]
                    if not self._is_model(impl):
                        self.cluster.release(lease, t)
                # workflow-aware reclamation once demand disappears
                for action in self.cluster.rebalance(self.library, t):
                    if log is not None:
                        log.append(f"[{t:8.1f}s] rebalance: {action}")
            # start whatever is now ready and fits
            progress = True
            while progress:
                progress = False
                for wid, tid in ready_tasks():
                    if try_start(wid, tid):
                        progress = True

        stuck = [(wid, tid) for wid, s in wfs.items()
                 for tid in s.dag.nodes
                 if tid not in s.done]
        if stuck:
            raise RuntimeError(f"deadlocked tasks (resources never fit): "
                               f"{stuck[:8]}")
        makespan = max((st.finish for st in wfs.values()), default=0.0)
        # instances still holding devices release at makespan (accounted as
        # idle power via the pool floor below).
        for pool, p in self.cluster.pools.items():
            spec = p.spec
            ledger.charge_idle(spec, p.capacity, makespan)

        per_wf = {wid: {"start": st.arrival, "finish": st.finish,
                        "tasks": len(st.dag)}
                  for wid, st in wfs.items()}
        return SimReport(
            makespan_s=makespan,
            energy_wh=ledger.wh,
            active_wh=ledger.active_joules / 3600.0,
            idle_wh=ledger.idle_joules / 3600.0,
            usd=ledger.usd,
            trace=sorted(trace, key=lambda e: e.start),
            per_workflow=per_wf,
            pool_busy_device_s=busy,
            preemptions=self.cluster.preemptions,
        )


def render_trace(report: SimReport, width: int = 72) -> str:
    """ASCII Fig-3-style execution trace."""
    if not report.trace:
        return "(empty trace)"
    span = max(report.makespan_s, 1e-9)
    lines = [f"{'task':<28s} {'pool':<10s} {'t':>7s}  timeline"]
    for e in report.trace:
        a = int(e.start / span * width)
        b = max(int(e.end / span * width), a + 1)
        bar = " " * a + "#" * (b - a)
        lines.append(f"{e.workflow + ':' + e.task:<28.28s} {e.pool:<10.10s} "
                     f"{e.end - e.start:7.1f}  |{bar:<{width}s}|")
    lines.append(f"makespan={report.makespan_s:.1f}s "
                 f"energy={report.energy_wh:.1f}Wh "
                 f"(active {report.active_wh:.1f} + idle {report.idle_wh:.1f})"
                 f" cost=${report.usd:.2f}")
    return "\n".join(lines)
