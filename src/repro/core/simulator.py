"""Discrete-event execution engine over the modeled cluster.

Runs one or many workflows (DAG + ExecutionPlan) against the cluster
manager's pools: list-scheduling with dependency and capacity constraints,
warm-instance reuse, cold-start (weights-load) latencies, and energy/$
integration via ``EnergyLedger``. Produces per-task traces — the Fig-3
artifact — and is the scale path (a 1000-node cluster is just bigger pool
capacities; the engine is O(events log events)).

Semantics notes:
- A *model* implementation (``load_time_s > 0`` or zoo-backed) executes on
  persistent warm instances; first use pays the load. Tools alloc/release
  per task.
- If fewer than ``n_instances`` instances fit right now, the task degrades
  gracefully to what fits (>=1) rather than deadlocking; if none fit, it
  waits for the next completion event.
- Energy: active increments per task; the idle floor for every metered pool
  is integrated over the *capacity timeline* at finalize (paper Table-2
  semantics; under autoscaling the floor follows ``set_capacity`` changes).

Multi-tenant semantics (core/admission.py):
- Workflows may arrive as ``Submission`` objects carrying a tenant class
  and an optional ``plan_fn``; planning then happens *at admission*, so the
  scheduler sees the cluster state (warm instances, free devices) at
  arrival rather than an empty cluster.
- Ready work is dispatched in admission-policy order (FCFS /
  strict-priority / weighted-fair), work-conserving.
- Harvest-class tenants hold preemptible leases. When a priority tenant
  cannot allocate, the engine reclaims harvest leases via
  ``ClusterManager.preempt_harvest``: the victims' in-flight tasks are
  cancelled, re-enqueued, and both the truncated run (``note="preempted"``)
  and the re-execution appear in the trace.
- Work-item checkpoint/resume (DESIGN.md §6.4): a *chunkable* victim's
  completed batch steps survive preemption — ``cancel_task`` inverts the
  ``ProfileStore.schedule_latency`` step schedule over the compute window
  (``ProfileStore.completed_items``), records the surviving item count on
  the workflow state, and the requeued attempt executes only the residual
  (``note="resume"``, composed with warmth as e.g. ``"resume+cold"``).
  Refunds are step-granular: completed steps stay charged (their items are
  never re-executed), the in-flight step is refunded (its items ride the
  residual, which re-charges them), so a resumed task's total charge is
  exactly ``schedule_latency(total items)`` across attempts. Non-chunkable
  tasks keep the restart-from-scratch path: time-fraction refund of the
  unexecuted remainder, ``note="requeue"``. Discarded-but-executed compute
  accrues in ``SimReport.wasted_dev_s`` either way.

Event-engine fast path (DESIGN.md §8): the dispatch loop keeps an *indexed
ready-set* per workflow — roots enter at admission, successors enter when
their last dependency finishes, preemption victims re-enter on cancel — so
each pass touches only genuinely ready tasks instead of rescanning every
workflow's whole DAG. Tasks that failed to start are skipped while their
pool's availability epoch is unchanged (``ClusterManager.free_epoch``): a
failed ``try_start`` depends only on (impl, pool, n_devices, n_instances,
tenant) and pool state, so identical-key retries under unchanged state fail
identically and may be elided without changing the schedule. The seed's
full rescan survives as ``fast_dispatch=False`` — the reference the
equivalence tests compare byte-identical traces against.
"""
from __future__ import annotations

import bisect
import heapq
import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator

from .admission import Admission, ServedLedger, get_policy
from .agents import AgentLibrary
from .cluster import ClusterManager, Instance, Lease, kv_cache_cap
from .dag import DAG
from .energy import CATALOG, EnergyLedger
from .faults import FaultProfile
from .profiles import CostQuery, ProfileStore
from .scheduler import ExecutionPlan, TaskConfig


@dataclass(frozen=True)
class TraceEntry:
    """One task execution interval in the Fig-3-style trace."""

    workflow: str
    task: str
    impl: str
    pool: str
    devices: int              # total devices (n_devices * n_instances)
    start: float
    end: float
    note: str = ""


@dataclass
class SimReport:
    """Aggregate outcome of one simulated run (energy, trace, spans)."""

    makespan_s: float
    energy_wh: float
    active_wh: float
    idle_wh: float
    usd: float
    trace: list[TraceEntry]
    per_workflow: dict[str, dict]
    pool_busy_device_s: dict[str, float]
    preemptions: int = 0
    requeues: int = 0            # task re-executions caused by preemption
    resumed_items: int = 0       # work-items salvaged by checkpoint/resume
    wasted_dev_s: float = 0.0    # executed-then-discarded device-seconds
    # KV/prefix-cache residency (DESIGN.md §9): lookups = session tasks
    # that could have hit, hits = tasks that started with a warm prefix
    cache_lookups: int = 0
    cache_hits: int = 0
    cache_hit_rate: float = 0.0
    prefill_tokens_saved: float = 0.0   # un-recomputed prefill tokens
    # fault injection + recovery (DESIGN.md §10); all zero when faults=None
    faults_injected: int = 0     # crashes + transient fails + stragglers
    instance_crashes: int = 0    # crash events that killed a live instance
    task_faults: int = 0         # transient mid-compute task failures
    fault_retries: int = 0       # task re-executions after a fault backoff
    hedges_launched: int = 0     # straggler duplicates started
    hedges_won: int = 0          # duplicates that beat their primary
    dead_letters: int = 0        # workflows abandoned (retries exhausted)
    degrade_replans: int = 0     # replans onto the degraded live cluster

    def workflow_span(self, wf: str) -> float:
        """Arrival-to-finish seconds for one workflow (tenant latency)."""
        return self.per_workflow[wf]["finish"] - self.per_workflow[wf]["start"]


@dataclass
class OpenLoopReport(SimReport):
    """SimReport + steady-state serving metrics from ``run_open_loop``."""

    horizon_s: float = 0.0       # arrival window length
    warmup_s: float = 0.0        # arrivals before this are trimmed
    offered_rps: float = 0.0     # arrivals / horizon
    arrivals: int = 0            # workflows admitted
    completed: int = 0           # workflows finished
    measured: int = 0            # completions past warmup (metric base)
    goodput_rps: float = 0.0     # SLO-met completions / measured seconds
    per_class: dict = field(default_factory=dict)
    n_events: int = 0            # heap events processed
    n_attempts: int = 0          # dispatch attempts (try_start calls)
    wall_s: float = 0.0
    events_per_s: float = 0.0    # (n_events + n_attempts) / wall_s
    scale_actions: list = field(default_factory=list)


@dataclass(slots=True)
class Submission:
    """One tenant's workflow submission to the multi-tenant engine.

    ``plan`` may be ``None`` with a ``plan_fn`` instead: the engine calls it
    when the workflow is admitted (its arrival event fires), so scheduling
    sees the live cluster state. ``slo_s``/``scenario`` feed the open-loop
    SLO-attainment metrics and are ignored by the closed-loop ``run``.
    """

    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None
    slo_s: float | None = None
    scenario: str = ""
    session: str = ""            # serving-session identity (KV affinity)


@dataclass(slots=True)
class _WfState:
    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None
    done: set[str] = field(default_factory=set)
    started: set[str] = field(default_factory=set)
    finish: float = 0.0
    attempt: dict[str, int] = field(default_factory=dict)
    # work-items checkpointed per task: survived preemption, never re-run
    items_done: dict[str, int] = field(default_factory=dict)
    slo_s: float | None = None
    scenario: str = ""
    session: str = ""
    # indexed ready set: (topo_rank, task_id), kept sorted by insort
    ready: list = field(default_factory=list)
    adm: Admission | None = None
    sort_key: tuple | None = None     # static-policy dispatch key
    # fault machinery (inert when faults=None)
    dead: bool = False                # dead-lettered: retries exhausted
    fails: dict[str, int] = field(default_factory=dict)   # fault count/task


@dataclass(slots=True)
class _Running:
    """Book-keeping for an in-flight task (needed to preempt it)."""

    cfg: TaskConfig
    leases: list[Lease]
    insts: list[Instance]
    start: float
    end: float
    compute_begin: float      # start + weights-load wall time
    ndev: int
    dev_s: float
    pf: float
    note: str
    n_inst: int               # instances actually acquired (may be < plan)
    batch: int                # effective batch (CPU pools force 1)
    items_done0: int          # items already checkpointed before this run
    items_per_inst: int       # the split _duration charged (refund inverts it)
    resumable: bool           # chunkable: completed steps survive preempt
    session: str = ""         # serving session the run belongs to
    cache_frac: float = 0.0   # prefix-cache hit fraction priced into dur
    slow: float = 1.0         # straggler multiplier on the compute window


class _Engine:
    """One run's event-loop state, shared by ``run`` and ``run_open_loop``.

    The seed kept all of this in closures inside ``run``; hoisting it lets
    the open-loop mode reuse admission, preemption, dispatch and accounting
    verbatim (identical float-op order — the golden tests pin it).
    """

    def __init__(self, sim: "Simulator", pol, log: list | None,
                 collect_trace: bool = True):
        self.sim = sim
        self.cluster = sim.cluster
        self.pol = pol
        self.log = log
        self.collect_trace = collect_trace
        # hot-path caches: pool -> device spec (device SKUs never change
        # mid-run; capacities may) and impl name -> "is a model" (vs tool)
        self.specs = {name: p.spec for name, p in sim.cluster.pools.items()}
        self.impls = sim.library.impls
        self.is_model = {name: sim._is_model(impl)
                         for name, impl in sim.library.impls.items()}
        self.wfs: dict[str, _WfState] = {}
        self.ledger = EnergyLedger()
        self.served = ServedLedger()
        self.preempt0 = sim.cluster.preemptions
        self.trace: list[TraceEntry] = []
        self.busy: dict[str, float] = {}
        self.running: dict[tuple[str, str], _Running] = {}
        self.lease_owner: dict[int, tuple[str, str]] = {}
        self.requeues = 0
        self.resumed_items = 0
        self.wasted_dev_s = 0.0
        # fault injection + recovery (DESIGN.md §10). ``faults`` is None on
        # a fault-free run: every fault path below is gated on it, so the
        # event heap, float-op order and counters stay byte-identical.
        self.faults: FaultProfile | None = sim.faults
        self.retry = sim.faults.retry if sim.faults is not None else None
        self.hedges: dict[tuple[str, str], _Running] = {}
        self._pool_rng: dict = {}        # pool -> crash-process generator
        self.incomplete = 0              # live (not finished/dead) workflows
        self.faults_injected = 0
        self.instance_crashes = 0
        self.task_faults = 0
        self.fault_retries = 0
        self.hedges_launched = 0
        self.hedges_won = 0
        self.dead_letters = 0
        self.degrade_replans = 0
        # KV/prefix-cache counters (DESIGN.md §9)
        self.cache_lookups = 0
        self.cache_hits = 0
        self.prefill_tokens_saved = 0.0
        self.events: list[tuple[float, int, str, object]] = []
        self.ctr = itertools.count()
        self.t = 0.0
        self.n_events = 0
        self.n_attempts = 0
        # dispatch-order index over admitted, incomplete workflows:
        # static policies keep a key-sorted list (keys are immutable
        # admission facts); weighted-fair re-sorts per pass (virtual time
        # moves between passes)
        self.active: list[tuple[tuple, str]] = []    # static: (key, wid)
        self.active_dyn: list[str] = []              # dynamic: wids
        # static policies only: the subset of ``active`` whose ready set is
        # nonempty, kept key-sorted — dispatch passes iterate this instead
        # of filtering every active workflow (invariant: (key, wid) here
        # ⟺ wfs[wid].ready nonempty)
        self.active_ready: list[tuple[tuple, str]] = []
        # blocked-group memo: (impl, pool, n_devices, n_instances, tenant)
        # -> pool free_epoch at last failed attempt. Skip while unchanged.
        self.blocked: dict[tuple, int] = {}
        # root (topo_rank, tid) pairs per distinct DAG object (id-keyed;
        # the DAGs are kept alive by wfs entries)
        self._roots: dict[int, list] = {}

    # -- submissions / admission ------------------------------------------------
    def add_submission(self, wid: str, sub: Submission):
        """Queue a workflow's arrival event."""
        self.wfs[wid] = _WfState(sub.dag, sub.plan, sub.arrival, sub.tenant,
                                 sub.plan_fn, slo_s=sub.slo_s,
                                 scenario=sub.scenario, session=sub.session)
        self.incomplete += 1
        heapq.heappush(self.events,
                       (sub.arrival, next(self.ctr), "arrive", wid))

    def admit(self, wid: str):
        """Arrive event: resolve the plan and index the workflow's roots."""
        st = self.wfs[wid]
        if st.plan is None:
            if st.plan_fn is None:
                raise ValueError(f"workflow {wid!r} submitted without a "
                                 f"plan or plan_fn")
            # admission-time planning: the scheduler sees the live cluster
            # (warm instances, free devices)
            st.plan = st.plan_fn()
        st.adm = Admission(wid, st.tenant, st.arrival)
        dag = st.dag
        roots = self._roots.get(id(dag))
        if roots is None:
            # open-loop submissions share one DAG per scenario: compute
            # the root (topo_rank, tid) pairs once per distinct DAG
            roots = self._roots[id(dag)] = [
                (dag.topo_index(tid), tid) for tid in dag.topo_order
                if not dag.nodes[tid].deps]
        st.ready.extend(roots)
        if self.pol.dynamic:
            self.active_dyn.append(wid)
        else:
            st.sort_key = self.pol.key(st.adm, self.served.served)
            bisect.insort(self.active, (st.sort_key, wid))
            if st.ready:
                bisect.insort(self.active_ready, (st.sort_key, wid))

    def _deactivate(self, wid: str, st: _WfState):
        if self.pol.dynamic:
            self.active_dyn.remove(wid)
        else:
            i = bisect.bisect_left(self.active, (st.sort_key, wid))
            del self.active[i]

    def _push_ready(self, wid: str, st: _WfState, tid: str):
        if not st.ready and not self.pol.dynamic:
            bisect.insort(self.active_ready, (st.sort_key, wid))
        bisect.insort(st.ready, (st.dag.topo_index(tid), tid))

    # -- dispatch candidates -----------------------------------------------------
    def _ready_scan(self) -> list[tuple[str, str]]:
        """The seed's full rescan: every workflow, every task, every pass.

        Kept verbatim as the ``fast_dispatch=False`` reference path; the
        equivalence tests assert the indexed ready-set produces
        byte-identical traces against this.
        """
        out = []
        t = self.t
        admitted = [Admission(wid, st.tenant, st.arrival)
                    for wid, st in self.wfs.items()
                    if t >= st.arrival and st.plan is not None]
        for adm in sorted(admitted,
                          key=lambda a: self.pol.key(a, self.served.served)):
            st = self.wfs[adm.workflow]
            for tid in st.dag.topo_order:
                if tid in st.done or tid in st.started:
                    continue
                if all(d in st.done for d in st.dag.nodes[tid].deps):
                    out.append((adm.workflow, tid))
        return out

    def _candidates(self) -> list[tuple[str, str]]:
        """Ready (workflow, task) pairs in admission-policy order, from the
        incremental index: O(active + ready) instead of O(total tasks)."""
        out = []
        wfs = self.wfs
        if self.pol.dynamic:
            served = self.served.served
            # filtering to ready-nonempty before the sort commutes with it
            order = sorted((w for w in self.active_dyn if wfs[w].ready),
                           key=lambda w: self.pol.key(wfs[w].adm, served))
            for wid in order:
                out.extend((wid, tid) for _, tid in wfs[wid].ready)
            return out
        for _, wid in self.active_ready:
            out.extend((wid, tid) for _, tid in wfs[wid].ready)
        return out

    def dispatch(self):
        """Start whatever is ready and fits, repeating while progress."""
        if not self.sim.fast_dispatch:
            progress = True
            while progress:
                progress = False
                for wid, tid in self._ready_scan():
                    self.n_attempts += 1
                    if self.try_start(wid, tid):
                        progress = True
            return
        cluster = self.cluster
        epochs = cluster.free_epoch
        progress = True
        while progress:
            progress = False
            epoch_snap = cluster.epoch_total
            for wid, tid in self._candidates():
                st = self.wfs[wid]
                if tid in st.started or tid in st.done:
                    continue
                cfg = st.plan.configs[tid]
                key = (cfg.impl, cfg.pool, cfg.n_devices, cfg.n_instances,
                       st.tenant)
                # a failed start depends only on this key and pool state;
                # while the pool epoch hasn't moved since the last failure,
                # a retry fails identically — skip it (DESIGN.md §8)
                if self.blocked.get(key) == epochs[cfg.pool]:
                    continue
                self.n_attempts += 1
                if self.try_start(wid, tid):
                    progress = True
                else:
                    # record *post*-attempt epoch: a failing attempt may
                    # itself evict idle instances (bumping the epoch), and
                    # those evictions don't make this key startable
                    cfg2 = st.plan.configs[tid]   # degrade may have moved it
                    key2 = (cfg2.impl, cfg2.pool, cfg2.n_devices,
                            cfg2.n_instances, st.tenant)
                    self.blocked[key2] = epochs[cfg2.pool]
            # a re-scan pass can only start something if availability
            # moved during this pass (preemption, eviction, release,
            # harvest supply): every survivor is memoized at the current
            # epoch, and new ready entries only appear via cancel_task,
            # which releases (bumping the epoch). No movement ⟹ the next
            # pass is provably a no-op — skip it.
            if progress and cluster.epoch_total == epoch_snap:
                break
        return

    # -- preemption ---------------------------------------------------------------
    def cancel_task(self, vwid: str, vtid: str):
        """Preemption: roll a task back to pending, checkpoint the work
        already finished (chunkable tasks), refund the unearned energy/$
        and release whatever it still holds."""
        t = self.t
        rec = self.running.pop((vwid, vtid), None)
        if rec is None:
            return
        if self.hedges:
            # a hedge dies with its primary: any rollback of the primary
            # also cancels the in-flight duplicate (its work is discarded)
            self._kill_hedge(vwid, vtid)
        vst = self.wfs[vwid]
        vst.started.discard(vtid)
        self._push_ready(vwid, vst, vtid)
        vst.attempt[vtid] = vst.attempt.get(vtid, 0) + 1
        for lease in rec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in rec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst in self.cluster.instances:
                self.cluster.evict_instance(inst, t)
        self._refund(rec, vst, vtid, t)
        self.requeues += 1
        if self.collect_trace:
            self.trace.append(TraceEntry(vwid, vtid, rec.cfg.impl,
                                         rec.cfg.pool, rec.ndev, rec.start,
                                         t, note="preempted"))
        if self.log is not None:
            kept = vst.items_done.get(vtid, 0)
            self.log.append(f"[{t:8.1f}s] preempt {vwid}:{vtid} "
                            f"({rec.ndev}x{rec.cfg.pool}); requeued"
                            + (f" ({kept} items checkpointed)" if kept
                               else ""))

    def _refund(self, rec: _Running, vst: _WfState, vtid: str, t: float,
                salvage: bool = True):
        """Roll back an interrupted run's energy/$ charge, step-granularly.

        Shared by preemption (``cancel_task``), fault failures
        (``fail_task``) and hedge cancellation (``_kill_hedge``, with
        ``salvage=False`` — a losing duplicate's completed steps are
        discarded, never checkpointed). For a straggling run
        (``rec.slow != 1.0``) the schedule inversion sees the *unslowed*
        clock (the schedule charged normal step times; the wall merely
        stretched), and kept charges scale back up by ``slow`` — so the
        refund inverts exactly what ``try_start`` billed.
        """
        spec = CATALOG[self.cluster.pools[rec.cfg.pool].device]
        # the charged dev_s covers compute only (weights-load is an
        # idle-power period), so progress is measured over the compute
        # window [compute_begin, end] — a victim preempted mid-load
        # gets a full refund either way
        window = max(rec.end - rec.compute_begin, 1e-12)
        elapsed = min(max(t - rec.compute_begin, 0.0), window)
        # executed device-seconds so far; dev_s spreads uniformly over
        # the window (paths run concurrently, so the rate is
        # ndev * paths even when the wall clock is path-multiplied)
        exec_dev_s = rec.dev_s * (elapsed / window)
        if salvage and rec.resumable and self.sim.resume:
            # checkpoint/resume: invert the step schedule over the
            # compute window — completed batch steps survive, the
            # in-flight step is discarded
            impl = self.sim.library.impls[rec.cfg.impl]
            node = vst.dag.nodes[vtid]
            work = impl.work_fn(node.tokens_in, node.tokens_out)
            # the refund inverts the exact schedule _duration charged,
            # including its prefix-cache discount (rec.cache_frac)
            sched_elapsed = (elapsed if rec.slow == 1.0
                             else elapsed / rec.slow)
            done, wall = self.sim.profiles.completed_items(CostQuery(
                impl=impl, spec=spec, n_devices=rec.cfg.n_devices,
                work=work, batch=rec.batch, items=rec.items_per_inst,
                elapsed_s=sched_elapsed, cache_hit_frac=rec.cache_frac))
            kept_items = min(done * rec.n_inst,
                             node.work_items - rec.items_done0)
            if kept_items:
                vst.items_done[vtid] = rec.items_done0 + kept_items
                self.resumed_items += kept_items
            # step-granular refund: completed steps stay charged (their
            # items never re-run); the in-flight step is refunded — its
            # items ride the residual requeue, which re-charges them,
            # so the task's total charge across attempts is exactly
            # schedule_latency(total items)
            kept_dev_s = wall * rec.ndev * rec.cfg.paths
            if rec.slow != 1.0:
                kept_dev_s *= rec.slow
            refund = max(rec.dev_s - kept_dev_s, 0.0)
            self.wasted_dev_s += max(exec_dev_s - kept_dev_s, 0.0)
        else:
            # restart from scratch (non-chunkable / resume disabled /
            # losing hedge): refund only the unexecuted remainder — the
            # executed compute stays charged (that energy was really
            # burned) and is all wasted, since nothing of it survives
            refund = rec.dev_s * (1.0 - elapsed / window)
            self.wasted_dev_s += exec_dev_s
        self.ledger.charge_active(spec, -refund,
                                  utilization=rec.pf, pool=rec.cfg.pool)
        self.busy[rec.cfg.pool] = self.busy.get(rec.cfg.pool, 0.0) - refund
        self.served.charge(vst.tenant, -refund)

    def try_preempt(self, pool: str, n_needed: int) -> bool:
        """Reclaim harvest-class leases for a priority tenant."""
        t = self.t
        deficit = n_needed - self.cluster.free(pool)
        if deficit <= 0 or self.cluster.harvest_devices(pool) < deficit:
            return False
        victims = self.cluster.preempt_harvest(pool, deficit, t)
        for lease in victims:
            # idle warm instance on a preempted lease: drop the shell
            # through the manager's eviction path so its bookkeeping
            # (instance list + lease table) stays consistent; the lease
            # itself was already released by preempt_harvest, which
            # evict_instance tolerates
            for inst in [i for i in self.cluster.instances
                         if i.lease is not None
                         and i.lease.id == lease.id]:
                self.cluster.evict_instance(inst, t)
            owner = self.lease_owner.pop(lease.id, None)
            if owner is not None:
                if len(owner) == 3:
                    # ("h", wid, tid): a hedge duplicate lost its devices —
                    # cancel just the hedge; its primary keeps running
                    self._kill_hedge(owner[1], owner[2])
                else:
                    self.cancel_task(*owner)
        return bool(victims)

    # -- task start ----------------------------------------------------------------
    def _alloc_or_evict(self, cluster, cfg, n: int, t: float,
                        harvest: bool):
        """Allocate ``n`` devices, evicting idle other-impl warm instances
        (LRU by warm_since) until the allocation fits or nothing is left."""
        lease = cluster.alloc(cfg.pool, n, t, harvest=harvest)
        if lease is None:
            idle = sorted(
                (i for i in cluster.instances
                 if i.pool == cfg.pool and i.busy_until <= t
                 and i.impl != cfg.impl),
                key=lambda i: i.warm_since)
            for victim in idle:
                cluster.evict_instance(victim, t)
                lease = cluster.alloc(cfg.pool, n, t, harvest=harvest)
                if lease is not None:
                    break
        return lease

    def _acquire(self, cluster, cfg, t: float, harvest: bool,
                 insts: list, session: str = "") -> int:
        """Fill ``insts`` up to ``cfg.n_instances`` — reusing idle warm
        instances first (first-fit in index order), then provisioning new
        ones; returns how many were newly provisioned.

        A non-empty ``session`` reorders the warm-reuse scan by resident
        prefix tokens for that session, descending (stable, so instances
        with no cache entry keep index order): session affinity prefers the
        shell whose KV cache already holds the conversation prefix
        (DESIGN.md §9). With ``session == ""`` the scan is byte-identical
        to the affinity-less engine.
        """
        new_inst = 0
        need = cfg.n_instances - len(insts)
        warm = cluster.warm_instances(cfg.impl, cfg.pool, cfg.n_devices)
        if session:
            warm = sorted(
                warm, key=lambda i: -i.cache[session].tokens
                if session in i.cache else 0)
        for i in warm:
            if need <= 0:
                break
            if i.busy_until <= t and i not in insts:
                insts.append(i)
                need -= 1
        while len(insts) < cfg.n_instances:
            lease = self._alloc_or_evict(cluster, cfg, cfg.n_devices, t,
                                         harvest)
            if lease is None:
                break
            inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                            warm_since=t, lease=lease,
                            cache_cap_bytes=self.sim._cache_cap(cfg))
            cluster.add_instance(inst)
            insts.append(inst)
            new_inst += 1
        return new_inst

    def try_start(self, wid: str, tid: str) -> bool:
        """Start a ready task if its resources fit right now."""
        t = self.t
        st = self.wfs[wid]
        cluster = self.cluster
        node = st.dag.nodes[tid]
        cfg = st.plan.configs[tid]
        impl = self.impls[cfg.impl]
        spec = self.specs[cfg.pool]
        harvest = st.tenant == "harvest"
        priority = st.tenant == "priority"
        leases: list[Lease] = []
        insts: list[Instance] = []
        new_inst = 0
        # degrade configs planned for a larger cluster (elasticity)
        cap = cluster.pools[cfg.pool].capacity
        if cfg.n_devices > cap:
            if cap < self.sim._pool_limit(cfg.pool):
                # the pool is autoscaled below its limit right now: wait
                # for the scale-up instead of permanently degrading the
                # plan to the shrunken size
                return False
            lo = impl.min_devices.get(spec.kind, 1)
            n = 1
            while n * 2 <= cap:
                n *= 2
            if n < lo:
                raise RuntimeError(
                    f"{cfg.impl} needs >= {lo} {spec.kind} devices; "
                    f"pool {cfg.pool} has {cap}")
            cfg = cfg.with_(n_devices=n, n_instances=1)
            # copy-on-write: amortized open-loop submissions share one
            # template plan per scenario; take a private copy before the
            # only in-place plan mutation the engine ever performs
            st.plan = ExecutionPlan(dict(st.plan.configs))
            st.plan.configs[tid] = cfg

        # KV/prefix cache (DESIGN.md §9): a task is cache-eligible when the
        # engine models caches, the workflow carries a session and the node
        # has a session-shared prefix on a KV-tracking impl. The affinity
        # lever (cache_affinity) only reorders warm-shell reuse — pricing
        # below uses whatever cache the acquired shells actually hold.
        session = (st.session if self.sim.kv_cache and st.session
                   and node.prefix_tokens > 0
                   and impl.kv_bytes_per_token > 0 else "")
        if self.is_model[cfg.impl]:
            affinity = session if self.sim.cache_affinity else ""
            new_inst = self._acquire(cluster, cfg, t, harvest, insts,
                                     affinity)
            if not insts and priority and \
                    self.try_preempt(cfg.pool, cfg.n_devices):
                new_inst += self._acquire(cluster, cfg, t, harvest, insts,
                                          affinity)
            if not insts:
                return False
            for inst in insts:
                lease = inst.lease
                if lease is not None and lease.harvest != harvest:
                    self.sim._relabel_lease(inst, harvest, t)
            n_inst = len(insts)
        else:
            total = cfg.n_devices * cfg.n_instances
            lease = cluster.alloc(cfg.pool, total, t, harvest=harvest)
            n_inst = cfg.n_instances
            if lease is None:
                lease = self._alloc_or_evict(cluster, cfg, cfg.n_devices,
                                             t, harvest)
                n_inst = 1
                if lease is None and priority and \
                        self.try_preempt(cfg.pool, cfg.n_devices):
                    lease = self._alloc_or_evict(cluster, cfg,
                                                 cfg.n_devices, t, harvest)
                if lease is None:
                    return False
            leases.append(lease)

        items_done = st.items_done.get(tid, 0) if self.sim.resume else 0
        cache_frac = 0.0
        if session and insts:
            self.cache_lookups += 1
            # every acquired shell must hold the prefix for the discount
            # to apply to the whole (identically-priced) instance group;
            # in practice chat turns run on one instance
            tok = min((inst.cache[session].tokens if session in inst.cache
                       else 0) for inst in insts)
            hit_tokens = min(tok, node.prefix_tokens)
            if hit_tokens > 0 and node.tokens_in > 0:
                cache_frac = hit_tokens / node.tokens_in
                self.cache_hits += 1
                remaining = max(node.work_items - items_done, 0)
                self.prefill_tokens_saved += hit_tokens * remaining
                for inst in insts:
                    cluster.cache_touch(inst, session, t)
        dur, compute, per_inst = self.sim._duration(node, cfg, n_inst,
                                                    new_inst, items_done,
                                                    cache_frac)
        pmult = cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
        dur *= pmult
        # seeded fault draws (DESIGN.md §10): a pure function of
        # (seed, wid, tid, attempt), so replay and the fast/reference
        # dispatch paths see identical fault streams regardless of
        # dispatch order. All three draws always happen (stream stability).
        attempt = st.attempt.get(tid, 0)
        slow, fail_frac = 1.0, 0.0
        fp = self.faults
        if fp is not None:
            u_fail, u_frac, u_strag = fp.task_draws(wid, tid, attempt)
            if u_fail < fp.task_fail_p:
                # transient failure somewhere inside the compute window
                fail_frac = 0.05 + 0.9 * u_frac
            elif u_strag < fp.straggler_p:
                slow = fp.straggler_mult
                self.faults_injected += 1
        base_dur = dur          # the CostQuery estimate (hedge trigger)
        if slow != 1.0:
            extra = compute * (slow - 1.0)
            compute = compute * slow
            dur = dur + extra * pmult
        end = t + dur
        # the tail of the run is compute; any lead-in is weights load
        compute_begin = end - compute * pmult
        for inst in insts:
            inst.busy_until = end
        ndev = cfg.n_devices * n_inst
        dev_s = compute * ndev * cfg.paths
        pf = self.sim.profiles.power_frac(impl, spec, cfg.n_devices)
        self.ledger.charge_active(spec, dev_s, utilization=pf,
                                  pool=cfg.pool)
        self.busy[cfg.pool] = self.busy.get(cfg.pool, 0.0) + dev_s
        self.served.charge(st.tenant, dev_s)
        st.started.add(tid)
        i = bisect.bisect_left(st.ready, (st.dag.topo_index(tid), tid))
        if i < len(st.ready) and st.ready[i][1] == tid:
            del st.ready[i]
            if not st.ready and not self.pol.dynamic:
                j = bisect.bisect_left(self.active_ready,
                                       (st.sort_key, wid))
                if j < len(self.active_ready) and \
                        self.active_ready[j][1] == wid:
                    del self.active_ready[j]
        # compose the note: restart kind + warmth, so preemption
        # analysis sees a requeue that also paid a cold weights load
        # ("requeue+cold") rather than losing the restart cost
        restart = ("resume" if attempt and items_done else
                   "requeue" if attempt else "")
        warmth = "cold" if new_inst else ("warm" if insts else "")
        if cache_frac > 0.0:
            # surface the prefix hit in the trace ("warm+kv")
            warmth = warmth + "+kv" if warmth else "kv"
        note = (restart + "+" + warmth if restart and warmth
                else restart or warmth)
        if slow != 1.0:
            note = note + "+slow" if note else "slow"
        for lease in leases:
            self.lease_owner[lease.id] = (wid, tid)
        for inst in insts:
            if inst.lease is not None:
                self.lease_owner[inst.lease.id] = (wid, tid)
        self.running[(wid, tid)] = _Running(cfg, leases, insts, t, end,
                                            compute_begin, ndev, dev_s, pf,
                                            note, n_inst=n_inst,
                                            batch=(1 if spec.kind == "cpu"
                                                   else cfg.batch),
                                            items_done0=items_done,
                                            items_per_inst=per_inst,
                                            resumable=node.chunkable,
                                            session=session,
                                            cache_frac=cache_frac,
                                            slow=slow)
        if fail_frac:
            # this attempt dies mid-compute instead of finishing
            fail_t = compute_begin + (end - compute_begin) * fail_frac
            heapq.heappush(self.events, (fail_t, next(self.ctr), "tfail",
                                         (wid, tid, attempt)))
        else:
            heapq.heappush(self.events, (end, next(self.ctr), "finish",
                                         (wid, tid, attempt)))
            if fp is not None and fp.hedge and slow >= fp.hedge_threshold:
                # straggler detected against the CostQuery estimate: at
                # threshold x the estimated duration the task is still
                # running — launch a duplicate then (first finish wins)
                heapq.heappush(
                    self.events,
                    (t + base_dur * fp.hedge_threshold, next(self.ctr),
                     "hedge", (wid, tid, attempt)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] start {wid}:{tid} on "
                            f"{ndev}x{cfg.pool} ({cfg.impl})"
                            + (f" [{restart}]" if restart else ""))
        return True

    # -- finish -------------------------------------------------------------------
    def on_finish(self, payload) -> bool:
        """Finish event; returns True when the whole workflow completed."""
        wid, tid, attempt = payload
        st = self.wfs[wid]
        if st.attempt.get(tid, 0) != attempt:
            return False    # stale: this execution was preempted
        rec = self.running.pop((wid, tid))
        if self.hedges:
            # the primary beat its duplicate: cancel the hedge, discard
            # and waste whatever it had executed (first finish wins)
            self._kill_hedge(wid, tid)
        return self._complete(wid, tid, st, rec)

    def _complete(self, wid: str, tid: str, st: _WfState,
                  rec: _Running) -> bool:
        """Book a finished run (shared by primary finishes and hedge wins).

        For a dead-lettered workflow the run still settles its resources
        and trace, but spawns no successors and can never count as a
        workflow completion.
        """
        t = self.t
        cluster = self.cluster
        st.done.add(tid)
        if t > st.finish:
            st.finish = t
        cluster.complete_task(wid, tid)
        if rec.slow != 1.0:
            # a straggler that ran to completion burned ``slow``x the
            # compute the work required: the excess is overhead of the
            # fault, booked as waste — the same currency a hedge-beaten
            # primary's discarded run is booked in, so the fault bench
            # compares hedging against let-it-drag honestly
            self.wasted_dev_s += rec.dev_s * (rec.slow - 1.0) / rec.slow
        cfg = rec.cfg
        model = self.is_model[cfg.impl]
        lease_owner = self.lease_owner
        for lease in rec.leases:
            # model instances keep their devices (stay warm); tools
            # release. Instance devices are reclaimed by rebalance.
            lease_owner.pop(lease.id, None)
            if not model:
                cluster.release(lease, t)
        for inst in rec.insts:
            if inst.lease is not None:
                lease_owner.pop(inst.lease.id, None)
        # session finished a turn on these shells: the full prompt+reply KV
        # is now resident, serving the *next* turn's prefix (DESIGN.md §9).
        # Insertion is gated like the pricing above, so cache-less runs
        # never touch the ledger (byte-identity with the pre-cache engine).
        if rec.session:
            node = st.dag.nodes[tid]
            impl = self.impls[cfg.impl]
            tokens = node.tokens_in + node.tokens_out
            nbytes = impl.kv_bytes_per_token * tokens
            for inst in rec.insts:
                cluster.cache_insert(inst, rec.session, tokens, nbytes, t)
        # the task's instances just went idle: blocked tasks keyed on this
        # pool may now reuse (or evict) them, so the availability epoch
        # must move even though no lease was released (model path)
        cluster.free_epoch[cfg.pool] += 1
        cluster.epoch_total += 1
        if self.collect_trace:
            self.trace.append(TraceEntry(wid, tid, rec.cfg.impl,
                                         rec.cfg.pool, rec.ndev,
                                         rec.start, t, note=rec.note))
        tele = self.sim.telemetry
        if tele is not None:
            # one record per completed attempt, priced exactly as the
            # ledger charged it (marginal energy over idle; $ over the full
            # device-seconds). Pure observation — nothing above read it.
            node = st.dag.nodes[tid]
            spec = self.specs[cfg.pool]
            energy = (rec.dev_s * rec.pf * (spec.active_w - spec.idle_w)
                      if spec.metered else 0.0)
            tele.observe(
                t=t, workflow=wid, task=tid, node=node,
                interface=node.agent, impl=cfg.impl, pool=cfg.pool,
                latency_s=t - rec.start, energy_j=energy,
                usd=rec.dev_s / 3600.0 * spec.usd_per_hour,
                declared_quality=cfg.quality,
                routed=node.agent in self.sim.routed_interfaces)
        # index newly-ready successors (their last dependency just
        # finished); a dead workflow spawns nothing
        done = st.done
        nodes = st.dag.nodes
        if not st.dead:
            for succ in st.dag.succ(tid):
                if succ in done or succ in st.started:
                    continue
                if all(d in done for d in nodes[succ].deps):
                    self._push_ready(wid, st, succ)
        finished = not st.dead and len(done) == len(nodes)
        if finished:
            self._deactivate(wid, st)
            self.incomplete -= 1
        # workflow-aware reclamation once demand disappears. Gated on the
        # demand-hit-zero flag: rebalance can only newly reclaim at the
        # instant some interface's pending count reaches 0 (an interface
        # with zero demand has no running tasks either, so its instances
        # were all idle — and evicted — the moment it zeroed), which makes
        # skipping the other calls a pure no-op elision.
        if self.cluster.demand_zeroed:
            self.cluster.demand_zeroed = False
            for action in self.cluster.rebalance(self.sim.library, t):
                if self.log is not None:
                    self.log.append(f"[{t:8.1f}s] rebalance: {action}")
        return finished

    # -- fault injection + recovery (DESIGN.md §10) -----------------------------
    def seed_faults(self):
        """Arm the per-pool crash processes (called once, at run start)."""
        fp = self.faults
        fp.validate_pools(self.cluster.pools)
        # crash-shrunk pools must make over-sized plans *wait* for repair,
        # not permanently degrade them: remember the nominal capacities as
        # the no-autoscaler pool limit (Simulator._pool_limit)
        self.sim._nominal_caps = {name: p.capacity
                                  for name, p in self.cluster.pools.items()}
        for pool in sorted(fp.instance_mtbf_s):
            rng = self._pool_rng[pool] = fp.pool_stream(pool)
            gap = rng.expovariate(1.0 / fp.instance_mtbf_s[pool])
            heapq.heappush(self.events,
                           (gap, next(self.ctr), "crash", pool))

    def on_fault_event(self, kind: str, payload) -> None:
        """Dispatch one fault-machinery heap event."""
        if kind == "crash":
            self.on_crash(payload)
        elif kind == "repair":
            self.on_repair(payload)
        elif kind == "tfail":
            wid, tid, attempt = payload
            self.fail_task(wid, tid, attempt, "fault")
        elif kind == "retry":
            self.on_retry(payload)
        elif kind == "hedge":
            self.on_hedge(payload)
        elif kind == "hfinish":
            self.on_hfinish(payload)
        else:
            raise RuntimeError(f"unknown event kind {kind!r}")

    def fail_task(self, wid: str, tid: str, t_attempt: int, reason: str,
                  crashed: Instance | None = None):
        """A running task just failed (transient fault or instance crash).

        Like ``cancel_task``, but: surviving shells go *idle* instead of
        being evicted (the software failed, not the hardware), the failure
        counts against the workflow's retry budget, and the task re-queues
        only after a seeded exponential backoff (the retry event) — or the
        workflow dead-letters once the budget is exhausted. Chunkable tasks
        checkpoint their completed steps through the same ``_refund``
        inversion preemption uses, so a retry resumes from ``items_done``.
        """
        st = self.wfs[wid]
        if st.attempt.get(tid, 0) != t_attempt:
            return                      # stale: that execution already ended
        rec = self.running.pop((wid, tid), None)
        if rec is None:
            return
        t = self.t
        if self.hedges:
            self._kill_hedge(wid, tid)  # a hedge dies with its primary
        st.started.discard(tid)
        st.attempt[tid] = t_attempt + 1
        for lease in rec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in rec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst is crashed or inst not in self.cluster.instances:
                continue
            inst.busy_until = t         # surviving shells idle immediately
        if rec.insts:
            # availability moved (shells idled / died): wake blocked keys
            self.cluster.free_epoch[rec.cfg.pool] += 1
            self.cluster.epoch_total += 1
        self._refund(rec, st, tid, t)
        self.faults_injected += 1
        if reason == "fault":
            self.task_faults += 1
        if self.collect_trace:
            self.trace.append(TraceEntry(
                wid, tid, rec.cfg.impl, rec.cfg.pool, rec.ndev, rec.start,
                t, note=("crashed" if reason == "crash" else "failed")))
        if st.dead:
            return      # already dead-lettered: this run just settled
        fails = st.fails.get(tid, 0) + 1
        st.fails[tid] = fails
        if fails >= self.retry.attempts_for(st.tenant):
            if self.log is not None:
                self.log.append(f"[{t:8.1f}s] {reason} {wid}:{tid} "
                                f"(attempt {fails}); retries exhausted")
            self._dead_letter(wid, st)
            return
        delay = self.retry.backoff_s(
            fails, self.faults.retry_jitter(wid, tid, fails))
        heapq.heappush(self.events,
                       (t + delay, next(self.ctr), "retry",
                        (wid, tid, fails)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] {reason} {wid}:{tid} "
                            f"(attempt {fails}); retry in {delay:.1f}s")

    def _dead_letter(self, wid: str, st: _WfState):
        """Abandon a workflow whose task exhausted its retry budget."""
        self.dead_letters += 1
        st.dead = True
        if st.ready and not self.pol.dynamic:
            j = bisect.bisect_left(self.active_ready, (st.sort_key, wid))
            if j < len(self.active_ready) and \
                    self.active_ready[j][1] == wid:
                del self.active_ready[j]
        st.ready.clear()
        self._deactivate(wid, st)
        # its unfinished tasks are no longer upcoming demand
        self.cluster.abandon_workflow(wid)
        self.incomplete -= 1
        if self.log is not None:
            self.log.append(f"[{self.t:8.1f}s] dead-letter {wid} "
                            f"({st.tenant})")

    def on_crash(self, pool: str):
        """Exponential-MTBF instance crash on ``pool``.

        The victim dies through ``evict_instance`` — its lease is released
        and its KV/prefix entries die with the shell — and the crashed
        device group leaves the pool's capacity until a seeded repair
        restores it (the autoscaler may backfill sooner). The draws happen
        unconditionally so the crash clock is a pure function of the seed,
        whatever the cluster looks like when it fires.
        """
        fp = self.faults
        rng = self._pool_rng[pool]
        u_victim = rng.random()
        gap = rng.expovariate(1.0 / fp.instance_mtbf_s[pool])
        repair = rng.expovariate(1.0 / fp.repair_s)
        if self.incomplete <= 0:
            return      # run drained: stop the crash process
        t = self.t
        live = [i for i in self.cluster.instances if i.pool == pool]
        if live:
            victim = live[min(int(u_victim * len(live)), len(live) - 1)]
            self.instance_crashes += 1
            lease = victim.lease
            owner = (self.lease_owner.pop(lease.id, None)
                     if lease is not None else None)
            n = victim.n_devices
            self.cluster.evict_instance(victim, t)
            cap = self.cluster.pools[pool].capacity
            self.cluster.set_capacity(pool, cap - n, t)
            heapq.heappush(self.events,
                           (t + repair, next(self.ctr), "repair",
                            (pool, n)))
            if self.log is not None:
                self.log.append(f"[{t:8.1f}s] crash {victim.impl} "
                                f"({n}x{pool}); repair in {repair:.0f}s")
            if owner is None:
                self.faults_injected += 1   # idle shell (KV died with it)
            elif len(owner) == 3:
                self.faults_injected += 1
                self._kill_hedge(owner[1], owner[2])
            else:
                wid, tid = owner
                self.fail_task(wid, tid,
                               self.wfs[wid].attempt.get(tid, 0),
                               "crash", crashed=victim)
        if self.incomplete > 0:
            heapq.heappush(self.events,
                           (t + gap, next(self.ctr), "crash", pool))

    def on_repair(self, payload):
        """Restore a crashed device group's capacity (clamped to the pool
        limit, so an autoscaler keeps authority over the final size)."""
        pool, n = payload
        cap = self.cluster.pools[pool].capacity
        new_cap = min(cap + n, self.sim._pool_limit(pool))
        if new_cap > cap:
            self.cluster.set_capacity(pool, new_cap, self.t)
            if self.log is not None:
                self.log.append(f"[{self.t:8.1f}s] repair +{n}x{pool}")

    def on_retry(self, payload):
        """Backoff elapsed: requeue the failed task (maybe replanned)."""
        wid, tid, fails = payload
        st = self.wfs.get(wid)
        if st is None or st.dead or st.fails.get(tid, 0) != fails:
            return
        if tid in st.done or tid in st.started:
            return
        self.fault_retries += 1
        rp = self.retry
        if rp.replan_after > 0 and fails >= rp.replan_after \
                and st.plan_fn is not None:
            # graceful degradation: under retry pressure, replan the
            # workflow's remaining tasks against the *live* (possibly
            # capacity-degraded) cluster — the planner picks a cheaper
            # impl/config within the quality floor if the original no
            # longer fits well
            self._degrade_replan(wid, st)
        self._push_ready(wid, st, tid)
        if self.log is not None:
            self.log.append(f"[{self.t:8.1f}s] retry {wid}:{tid} "
                            f"(failure {fails})")

    def _degrade_replan(self, wid: str, st: _WfState):
        """Re-plan remaining tasks on the degraded cluster (copy-on-write)."""
        try:
            fresh = st.plan_fn()
        except Exception:
            return                      # planning may fail mid-degradation
        cfgs = dict(st.plan.configs)
        changed = False
        for tid, cfg in fresh.configs.items():
            if tid in st.done or tid in st.started:
                continue                # only not-yet-run tasks may move
            if cfgs.get(tid) != cfg:
                cfgs[tid] = cfg
                changed = True
        if changed:
            st.plan = ExecutionPlan(cfgs)
            self.degrade_replans += 1
            if self.log is not None:
                self.log.append(f"[{self.t:8.1f}s] degrade-replan {wid}")

    def on_hedge(self, payload):
        """Straggler-detection event: the task has now run for
        ``hedge_threshold x`` its estimate — launch a duplicate if it is
        still running and resources fit."""
        wid, tid, attempt = payload
        st = self.wfs.get(wid)
        if st is None or st.dead or st.attempt.get(tid, 0) != attempt:
            return
        rec = self.running.get((wid, tid))
        if rec is None or (wid, tid) in self.hedges:
            return
        self._start_hedge(wid, tid, attempt, st, rec)

    def _start_hedge(self, wid: str, tid: str, attempt: int,
                     st: _WfState, rec: _Running):
        """Duplicate a straggling run on other shells (first finish wins).

        Hedges are opportunistic: they use genuinely free capacity only —
        no eviction, no preemption — and are themselves preemptible and
        crash-prone, but never straggle or fault (one level of recursion
        is enough). The duplicate prices the same residual the primary
        did (``items_done0``), sessionless (its shells hold no prefix).
        """
        t = self.t
        cluster = self.cluster
        cfg = rec.cfg
        node = st.dag.nodes[tid]
        impl = self.impls[cfg.impl]
        spec = self.specs[cfg.pool]
        harvest = st.tenant == "harvest"
        leases: list[Lease] = []
        insts: list[Instance] = []
        new_inst = 0
        if self.is_model[cfg.impl]:
            for i in cluster.warm_instances(cfg.impl, cfg.pool,
                                            cfg.n_devices):
                if len(insts) >= rec.n_inst:
                    break
                if i.busy_until <= t and i not in rec.insts:
                    insts.append(i)
            provisioned = []
            while len(insts) < rec.n_inst:
                lease = cluster.alloc(cfg.pool, cfg.n_devices, t,
                                      harvest=harvest)
                if lease is None:
                    break
                inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                                warm_since=t, lease=lease,
                                cache_cap_bytes=self.sim._cache_cap(cfg))
                cluster.add_instance(inst)
                insts.append(inst)
                provisioned.append(inst)
                new_inst += 1
            if len(insts) < rec.n_inst:
                for inst in provisioned:    # couldn't fit: roll back
                    cluster.evict_instance(inst, t)
                return
        else:
            lease = cluster.alloc(cfg.pool, cfg.n_devices * rec.n_inst, t,
                                  harvest=harvest)
            if lease is None:
                return
            leases.append(lease)
        n_inst = rec.n_inst
        dur, compute, per_inst = self.sim._duration(
            node, cfg, n_inst, new_inst, rec.items_done0, 0.0)
        pmult = cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
        dur *= pmult
        end = t + dur
        compute_begin = end - compute * pmult
        for inst in insts:
            inst.busy_until = end
        ndev = cfg.n_devices * n_inst
        dev_s = compute * ndev * cfg.paths
        pf = self.sim.profiles.power_frac(impl, spec, cfg.n_devices)
        self.ledger.charge_active(spec, dev_s, utilization=pf,
                                  pool=cfg.pool)
        self.busy[cfg.pool] = self.busy.get(cfg.pool, 0.0) + dev_s
        self.served.charge(st.tenant, dev_s)
        howner = ("h", wid, tid)
        for lease in leases:
            self.lease_owner[lease.id] = howner
        for inst in insts:
            if inst.lease is not None:
                self.lease_owner[inst.lease.id] = howner
        self.hedges[(wid, tid)] = _Running(
            cfg, leases, insts, t, end, compute_begin, ndev, dev_s, pf,
            note="hedge+" + ("cold" if new_inst else "warm"),
            n_inst=n_inst, batch=(1 if spec.kind == "cpu" else cfg.batch),
            items_done0=rec.items_done0, items_per_inst=per_inst,
            resumable=node.chunkable)
        self.hedges_launched += 1
        heapq.heappush(self.events, (end, next(self.ctr), "hfinish",
                                     (wid, tid, attempt)))
        if self.log is not None:
            self.log.append(f"[{t:8.1f}s] hedge {wid}:{tid} on "
                            f"{ndev}x{cfg.pool} (primary "
                            f"{rec.slow:.1f}x slow)")

    def _kill_hedge(self, wid: str, tid: str):
        """Cancel an in-flight hedge; its executed work is discarded."""
        hrec = self.hedges.pop((wid, tid), None)
        if hrec is None:
            return
        t = self.t
        for lease in hrec.leases:
            self.lease_owner.pop(lease.id, None)
            if self.cluster.lease_active(lease):
                self.cluster.release(lease, t)
        for inst in hrec.insts:
            if inst.lease is not None:
                self.lease_owner.pop(inst.lease.id, None)
            if inst in self.cluster.instances:
                inst.busy_until = t
        if hrec.insts:
            self.cluster.free_epoch[hrec.cfg.pool] += 1
            self.cluster.epoch_total += 1
        # salvage=False: the loser's completed steps don't checkpoint (the
        # winner runs the full residual itself — crediting both would
        # double-count items), so executed = wasted, unexecuted = refunded
        self._refund(hrec, self.wfs[wid], tid, t, salvage=False)
        if self.collect_trace:
            self.trace.append(TraceEntry(
                wid, tid, hrec.cfg.impl, hrec.cfg.pool, hrec.ndev,
                hrec.start, t, note="hedge_lost"))

    def on_hfinish(self, payload):
        """A hedge finished first: cancel the straggling primary and
        complete the task through the duplicate's run."""
        wid, tid, attempt = payload
        hrec = self.hedges.get((wid, tid))
        st = self.wfs.get(wid)
        if hrec is None or st is None or \
                st.attempt.get(tid, 0) != attempt:
            return
        del self.hedges[(wid, tid)]
        t = self.t
        prec = self.running.pop((wid, tid), None)
        if prec is not None:
            # invalidate the primary's in-flight finish event
            st.attempt[tid] = attempt + 1
            for lease in prec.leases:
                self.lease_owner.pop(lease.id, None)
                if self.cluster.lease_active(lease):
                    self.cluster.release(lease, t)
            for inst in prec.insts:
                if inst.lease is not None:
                    self.lease_owner.pop(inst.lease.id, None)
                if inst in self.cluster.instances:
                    inst.busy_until = t
            if prec.insts:
                self.cluster.free_epoch[prec.cfg.pool] += 1
                self.cluster.epoch_total += 1
            self._refund(prec, st, tid, t, salvage=False)
            if self.collect_trace:
                self.trace.append(TraceEntry(
                    wid, tid, prec.cfg.impl, prec.cfg.pool, prec.ndev,
                    prec.start, t, note="hedge_beat_primary"))
        self.hedges_won += 1
        self._complete(wid, tid, st, hrec)

    # -- accounting ---------------------------------------------------------------
    def finalize(self, makespan: float):
        """Integrate the idle-power floor over each pool's capacity log."""
        for pool, p in self.cluster.pools.items():
            spec = p.spec
            log = self.cluster.capacity_log(pool)
            if len(log) == 1:
                # constant capacity: the seed's exact expression (golden
                # traces pin the float op order)
                self.ledger.charge_idle(spec, p.capacity, makespan)
            else:
                dev_s = self.cluster.capacity_device_seconds(pool, makespan)
                self.ledger.charge_idle(spec, 1, dev_s)

    def report(self, makespan: float) -> SimReport:
        per_wf = {wid: {"start": st.arrival, "finish": st.finish,
                        "tasks": len(st.dag), "tenant": st.tenant}
                  for wid, st in self.wfs.items()}
        return SimReport(
            makespan_s=makespan,
            energy_wh=self.ledger.wh,
            active_wh=self.ledger.active_joules / 3600.0,
            idle_wh=self.ledger.idle_joules / 3600.0,
            usd=self.ledger.usd,
            trace=sorted(self.trace,
                         key=lambda e: (e.start, e.end, e.workflow)),
            per_workflow=per_wf,
            pool_busy_device_s=self.busy,
            preemptions=self.cluster.preemptions - self.preempt0,
            requeues=self.requeues,
            resumed_items=self.resumed_items,
            wasted_dev_s=self.wasted_dev_s,
            cache_lookups=self.cache_lookups,
            cache_hits=self.cache_hits,
            cache_hit_rate=(self.cache_hits / self.cache_lookups
                            if self.cache_lookups else 0.0),
            prefill_tokens_saved=self.prefill_tokens_saved,
            faults_injected=self.faults_injected,
            instance_crashes=self.instance_crashes,
            task_faults=self.task_faults,
            fault_retries=self.fault_retries,
            hedges_launched=self.hedges_launched,
            hedges_won=self.hedges_won,
            dead_letters=self.dead_letters,
            degrade_replans=self.degrade_replans,
        )


class Simulator:
    """Discrete-event engine executing plans against the modeled cluster."""

    def __init__(self, cluster: ClusterManager, library: AgentLibrary,
                 profiles: ProfileStore, resume: bool = True,
                 fast_dispatch: bool = True, kv_cache: bool = True,
                 cache_affinity: bool = True,
                 faults: FaultProfile | None = None,
                 telemetry=None, routed_interfaces: tuple = ()):
        self.cluster = cluster
        self.library = library
        self.profiles = profiles
        # per-task outcome log feeding the offline routing evaluator
        # (DESIGN.md §11): a core.telemetry.TelemetryStore, written *after*
        # each task's accounting settles so it never influences the run;
        # None keeps the engine byte-identical to a telemetry-less one.
        # ``routed_interfaces`` marks which interfaces a learned router
        # chose the impl for (stamped onto the records).
        self.telemetry = telemetry
        self.routed_interfaces = frozenset(routed_interfaces)
        # seeded fault injection + recovery (DESIGN.md §10); None keeps
        # every fault path provably inert — runs are byte-identical to an
        # engine without the subsystem (the golden tests pin this)
        self.faults = faults
        # KV/prefix-cache residency (DESIGN.md §9). kv_cache is the master
        # switch: False makes every cache path provably inert (sessionless
        # pricing, no ledger writes) — the byte-identity reference.
        # cache_affinity is the placement lever: False keeps hit-rate
        # pricing but ranks warm shells cache-blind (the ablation axis the
        # cache bench compares against).
        self.kv_cache = kv_cache
        self.cache_affinity = cache_affinity
        # work-item checkpoint/resume of preempted chunkable tasks
        # (DESIGN.md §6.4); False restores restart-from-scratch for every
        # victim (the pre-resume baseline benchmarks compare against)
        self.resume = resume
        # indexed ready-set + blocked-group dispatch (DESIGN.md §8);
        # False selects the seed's full-rescan reference path, which the
        # equivalence tests compare byte-identical traces against
        self.fast_dispatch = fast_dispatch
        # autoscale limits per pool (run_open_loop fills this; closed-loop
        # runs treat current capacity as the limit)
        self._scale_limits: dict[str, int] = {}
        # pool capacities at fault-run start (seed_faults fills this):
        # with no autoscaler, a crash-shrunk pool's limit is its nominal
        # size, so over-sized plans wait for the repair instead of
        # permanently degrading to the post-crash capacity
        self._nominal_caps: dict[str, int] = {}
        # duration memo: open-loop serving re-runs identical (config, node
        # workload) pairs thousands of times; keyed on everything
        # _duration reads, including the profile-store version (pin()
        # bumps it, invalidating stale latencies)
        self._dur_memo: dict[tuple, tuple[float, float, int]] = {}

    def _pool_limit(self, pool: str) -> int:
        """Max capacity a pool may scale to (its size when not scaled).

        Autoscaler limits take precedence; otherwise a fault run answers
        with the pool's nominal (pre-crash) size, and a fault-free run
        with the current capacity (the seed's behaviour)."""
        lim = self._scale_limits.get(pool)
        if lim is not None:
            return lim
        return self._nominal_caps.get(pool,
                                      self.cluster.pools[pool].capacity)

    def _cache_cap(self, cfg: TaskConfig) -> float:
        """HBM bytes a new instance of ``cfg`` may devote to KV prefixes
        (0.0 when caches are off or the impl doesn't track KV)."""
        if not self.kv_cache:
            return 0.0
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        return kv_cache_cap(spec, cfg.n_devices, impl.params_bytes,
                            impl.kv_bytes_per_token)

    # -- duration under actual warmth ------------------------------------------
    def _duration(self, node, cfg: TaskConfig, n_inst: int,
                  new_instances: int, items_done: int = 0,
                  cache_frac: float = 0.0) -> tuple[float, float, int]:
        """Wall/compute seconds (and per-instance item count) of one run.

        Returns ``(latency, compute, items_per_inst)``; the item split is
        returned so ``cancel_task``'s refund inverts *exactly* the schedule
        charged here (stored on ``_Running.items_per_inst``) rather than
        re-deriving it. ``cache_frac`` is the resident-prefix hit fraction:
        the schedule prices only the un-cached prefill (DESIGN.md §9).
        """
        key = (cfg.impl, cfg.pool, cfg.n_devices, cfg.batch, cfg.warm,
               n_inst, bool(new_instances), items_done, node.work_items,
               node.tokens_in, node.tokens_out, cache_frac,
               self.profiles.version)
        memo = self._dur_memo.get(key)
        if memo is not None:
            return memo
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        work = impl.work_fn(node.tokens_in, node.tokens_out)
        batch = 1 if spec.kind == "cpu" else cfg.batch
        remaining = max(node.work_items - items_done, 0)
        items = math.ceil(remaining / max(n_inst, 1))
        # the same batched execution schedule the scheduler estimates with
        # (ProfileStore.schedule_latency: full steps + a remainder step at
        # its own price): one source of truth for plan vs actual. A resumed
        # attempt prices only the residual items (Scheduler.estimate takes
        # the same items_done, preserving estimate/actual parity); a warm
        # prefix discounts both sides through the same CostQuery.
        compute = self.profiles.schedule_latency(CostQuery(
            impl=impl, spec=spec, n_devices=cfg.n_devices, work=work,
            batch=batch, items=items, cache_hit_frac=cache_frac))
        lat = compute
        if new_instances and not cfg.warm:
            # cfg.warm = provisioned capacity (PTU-style): always-on, no load
            lat += impl.load_time_s
        out = (lat, compute, items)
        self._dur_memo[key] = out
        return out

    def _is_model(self, impl) -> bool:
        return impl.load_time_s > 0 or impl.arch is not None

    # -- closed-loop engine ------------------------------------------------------
    def run(self,
            workflows: "dict[str, tuple[DAG, ExecutionPlan, float] | Submission]",
            log: list | None = None, policy=None) -> SimReport:
        """Execute one or many workflows; returns the ``SimReport``.

        ``workflows`` maps workflow id to either a ``(dag, plan, arrival)``
        triple or a ``Submission`` (tenant class + optional admission-time
        ``plan_fn``). ``policy`` selects the admission order
        (``core.admission``: fcfs | strict-priority | weighted-fair);
        ``log`` collects human-readable event lines when provided.
        """
        pol = get_policy(policy)
        eng = _Engine(self, pol, log)
        for wid, sub in workflows.items():
            if not isinstance(sub, Submission):
                dag, plan, arrival = sub
                sub = Submission(dag, plan, arrival)
            eng.add_submission(wid, sub)
        for wid, st in eng.wfs.items():
            self.cluster.register_workflow(wid, st.dag)
        if self.faults is not None:
            eng.seed_faults()

        events = eng.events
        try:
            while events:
                t, _, kind, payload = heapq.heappop(events)
                eng.t = t
                # drain every event sharing this timestamp before
                # dispatching: simultaneous arrivals are all admitted (and
                # planned) before any of them starts work, so
                # admission-policy order holds for same-time tenants and
                # identical tenants admitted into the same cluster state
                # share one plan via the plan cache.
                batch = [(kind, payload)]
                while events and events[0][0] == t:
                    _, _, k, p = heapq.heappop(events)
                    batch.append((k, p))
                eng.n_events += len(batch)
                for kind, payload in batch:
                    if kind == "arrive":
                        eng.admit(payload)
                    elif kind == "finish":
                        eng.on_finish(payload)
                    else:
                        eng.on_fault_event(kind, payload)
                eng.dispatch()
        finally:
            self._nominal_caps = {}

        stuck = [(wid, tid) for wid, s in eng.wfs.items()
                 if not s.dead
                 for tid in s.dag.nodes
                 if tid not in s.done]
        if stuck:
            raise RuntimeError(f"deadlocked tasks (resources never fit): "
                               f"{stuck[:8]}")
        if __debug__:
            self.cluster.audit()
        makespan = max((st.finish for st in eng.wfs.values()), default=0.0)
        # instances still holding devices release at makespan (accounted as
        # idle power via the pool floor below).
        eng.finalize(makespan)
        return eng.report(makespan)

    # -- open-loop engine --------------------------------------------------------
    def run_open_loop(self,
                      source: "Iterable[tuple[str, Submission]]",
                      horizon_s: float,
                      *,
                      warmup_s: float = 0.0,
                      policy=None,
                      autoscaler=None,
                      log: list | None = None,
                      collect_trace: bool = True) -> OpenLoopReport:
        """Serve an open-loop arrival stream for ``horizon_s`` seconds.

        ``source`` yields ``(workflow_id, Submission)`` pairs with
        non-decreasing arrival times (``core.arrivals`` generators qualify);
        arrivals are pulled lazily — one look-ahead submission lives in the
        event heap at a time, so a 10k-workflow sweep never materializes
        its whole future. Arrivals past ``horizon_s`` are not admitted;
        admitted work drains to completion.

        Steady-state metrics trim the warmup: only workflows arriving in
        ``[warmup_s, horizon_s]`` count toward per-class SLO attainment,
        goodput, and the span percentiles. ``autoscaler`` (an
        ``core.autoscale.Autoscaler``) is consulted on periodic ``scale``
        events and applies pool resizes through
        ``ClusterManager.set_capacity`` — scale-ups after the policy lag,
        scale-downs immediately (cooldown permitting).
        """
        wall0 = time.perf_counter()
        pol = get_policy(policy)
        eng = _Engine(self, pol, log, collect_trace=collect_trace)
        stream: Iterator[tuple[str, Submission]] = iter(source)
        arrivals = 0
        last_arrival = 0.0
        exhausted = False

        def _pull() -> bool:
            """Admit the next submission into the heap (one look-ahead)."""
            nonlocal arrivals, last_arrival, exhausted
            if exhausted:
                return False
            for wid, sub in stream:
                if sub.arrival > horizon_s:
                    # past the arrival window: stop pulling (the source may
                    # be an infinite generator)
                    exhausted = True
                    return False
                if sub.arrival < last_arrival:
                    raise ValueError(
                        f"open-loop source must be time-ordered: "
                        f"{wid!r} arrives at {sub.arrival} after "
                        f"{last_arrival}")
                last_arrival = sub.arrival
                eng.add_submission(wid, sub)
                arrivals += 1
                return True
            exhausted = True
            return False

        _pull()
        if self.faults is not None:
            eng.seed_faults()
        if autoscaler is not None:
            self._scale_limits = autoscaler.limits()
            autoscaler.validate(self.cluster)
            heapq.heappush(eng.events,
                           (autoscaler.interval_s, next(eng.ctr),
                            "scale", None))
        scale_actions: list[tuple] = []
        events = eng.events
        heappop = heapq.heappop
        try:
            while events:
                t, _, kind, payload = heappop(events)
                eng.t = t
                n = 1
                # drain every same-t event (including ones the handlers
                # chain in: zero-lag applies, same-t arrivals pulled from
                # the stream) before dispatching once for the timestamp.
                # Same-t events pop in push-counter order, so handling
                # them as they pop matches handling them as a batch.
                while True:
                    if kind == "arrive":
                        eng.admit(payload)
                        # keep exactly one future arrival in the heap
                        self.cluster.register_workflow(
                            payload, eng.wfs[payload].dag)
                        _pull()
                    elif kind == "finish":
                        eng.on_finish(payload)
                    elif kind == "scale":
                        for act in autoscaler.decide(
                                self.cluster, self._demand_by_pool(eng), t):
                            if act.lag_s > 0:
                                heapq.heappush(
                                    events, (t + act.lag_s, next(eng.ctr),
                                             "scale_apply", act))
                            else:
                                autoscaler.apply(self.cluster, act, t)
                                scale_actions.append(
                                    (t, act.pool, act.capacity))
                        if events or eng.running or \
                                any(st.ready for st in eng.wfs.values()):
                            heapq.heappush(
                                events, (t + autoscaler.interval_s,
                                         next(eng.ctr), "scale", None))
                    elif kind == "scale_apply":
                        autoscaler.apply(self.cluster, payload, t)
                        scale_actions.append(
                            (t, payload.pool, payload.capacity))
                    else:
                        eng.on_fault_event(kind, payload)
                    if events and events[0][0] == t:
                        _, _, kind, payload = heappop(events)
                        n += 1
                    else:
                        break
                eng.n_events += n
                eng.dispatch()
        finally:
            self._scale_limits = {}
            self._nominal_caps = {}

        if __debug__:
            self.cluster.audit()
        makespan = max((st.finish for st in eng.wfs.values()), default=0.0)
        eng.finalize(makespan)
        rep = eng.report(makespan)
        wall = time.perf_counter() - wall0
        return self._steady_state(rep, eng, horizon_s, warmup_s, arrivals,
                                  wall, scale_actions)

    def _demand_by_pool(self, eng: _Engine) -> dict[str, int]:
        """Devices wanted right now per pool: held + queued (ready) work."""
        demand = dict(self.cluster._used)
        for st in eng.wfs.values():
            if st.plan is None:
                continue
            for _, tid in st.ready:
                cfg = st.plan.configs[tid]
                demand[cfg.pool] = demand.get(cfg.pool, 0) + \
                    cfg.n_devices * cfg.n_instances
        return demand

    def _steady_state(self, rep: SimReport, eng: _Engine, horizon_s: float,
                      warmup_s: float, arrivals: int, wall: float,
                      scale_actions: list) -> OpenLoopReport:
        """Fold steady-state serving metrics into an OpenLoopReport."""
        completed = 0
        per_class: dict[str, dict] = {}
        spans: dict[str, list[float]] = {}
        met: dict[str, int] = {}
        # dead-lettered workflows per tenant (post-warmup): they count
        # against SLO attainment — an abandoned request is a missed SLO,
        # not a dropped sample — but contribute no latency span
        dead: dict[str, int] = {}
        measured = 0
        goodput_n = 0
        for wid, st in eng.wfs.items():
            done = len(st.done) == len(st.dag.nodes)
            if done:
                completed += 1
            if st.arrival < warmup_s:
                continue
            if st.dead:
                measured += 1
                dead[st.tenant] = dead.get(st.tenant, 0) + 1
                continue
            if not done:
                continue
            measured += 1
            span = st.finish - st.arrival
            spans.setdefault(st.tenant, []).append(span)
            if st.slo_s is not None:
                ok = span <= st.slo_s
                met[st.tenant] = met.get(st.tenant, 0) + (1 if ok else 0)
                if ok:
                    goodput_n += 1
        for tenant, ss in sorted(spans.items()):
            ss.sort()
            n = len(ss)
            per_class[tenant] = {
                "n": n,
                "p50_s": ss[int(0.50 * (n - 1))],
                "p95_s": ss[int(0.95 * (n - 1))],
                "p99_s": ss[int(0.99 * (n - 1))],
                "mean_s": sum(ss) / n,
                "dead": dead.get(tenant, 0),
                "slo_attainment": (
                    met[tenant] / (n + dead.get(tenant, 0))
                    if tenant in met else None),
            }
        for tenant, n_dead in sorted(dead.items()):
            if tenant not in per_class:
                # every post-warmup workflow of this class dead-lettered
                per_class[tenant] = {
                    "n": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                    "mean_s": 0.0, "dead": n_dead, "slo_attainment": 0.0,
                }
        elapsed = max(rep.makespan_s - warmup_s, 1e-9)
        n_ev = eng.n_events + eng.n_attempts
        return OpenLoopReport(
            **{f: getattr(rep, f) for f in (
                "makespan_s", "energy_wh", "active_wh", "idle_wh", "usd",
                "trace", "per_workflow", "pool_busy_device_s",
                "preemptions", "requeues", "resumed_items", "wasted_dev_s",
                "cache_lookups", "cache_hits", "cache_hit_rate",
                "prefill_tokens_saved", "faults_injected",
                "instance_crashes", "task_faults", "fault_retries",
                "hedges_launched", "hedges_won", "dead_letters",
                "degrade_replans")},
            horizon_s=horizon_s,
            warmup_s=warmup_s,
            offered_rps=arrivals / max(horizon_s, 1e-9),
            arrivals=arrivals,
            completed=completed,
            measured=measured,
            goodput_rps=goodput_n / elapsed,
            per_class=per_class,
            n_events=eng.n_events,
            n_attempts=eng.n_attempts,
            wall_s=wall,
            events_per_s=n_ev / max(wall, 1e-9),
            scale_actions=scale_actions,
        )

    def _relabel_lease(self, inst: Instance, harvest: bool, t: float):
        """Keep an instance lease's preemptibility in sync with the tenant
        running on it: a priority/standard task on a harvest-created warm
        instance must not be preemptible (and vice versa)."""
        lease = inst.lease
        if lease is None or lease.harvest == harvest:
            return
        if not self.cluster.lease_active(lease):
            inst.lease = None
            return
        # flip the flag in place (the lease keeps its id and devices; the
        # seed's release-then-realloc round trip was an artifact of Lease
        # being frozen). Flipping *to* harvest adds preemptible supply, so
        # the pool's availability epoch must move — a blocked priority
        # task may now preempt its way in; flipping away removes supply
        # and can never unblock anything.
        lease.harvest = harvest
        if harvest:
            self.cluster.free_epoch[lease.pool] += 1
            self.cluster.epoch_total += 1


def render_trace(report: SimReport, width: int = 72,
                 max_rows: int = 200) -> str:
    """ASCII Fig-3-style execution trace.

    Long runs are subsampled to ``max_rows`` evenly-spaced task rows (an
    open-loop sweep has tens of thousands — the full dump was unreadable
    and O(events) lines); a footer notes how many rows were elided.
    ``max_rows <= 0`` disables the cap.
    """
    if not report.trace:
        return "(empty trace)"
    span = max(report.makespan_s, 1e-9)
    entries = report.trace
    elided = 0
    if 0 < max_rows < len(entries):
        step = len(entries) / max_rows
        entries = [entries[int(i * step)] for i in range(max_rows)]
        elided = len(report.trace) - len(entries)
    lines = [f"{'task':<28s} {'pool':<10s} {'t':>7s}  timeline"]
    for e in entries:
        a = int(e.start / span * width)
        b = max(int(e.end / span * width), a + 1)
        bar = " " * a + "#" * (b - a)
        lines.append(f"{e.workflow + ':' + e.task:<28.28s} {e.pool:<10.10s} "
                     f"{e.end - e.start:7.1f}  |{bar:<{width}s}|")
    if elided:
        lines.append(f"... {elided} of {len(report.trace)} rows elided "
                     f"(raise max_rows to see more)")
    lines.append(f"makespan={report.makespan_s:.1f}s "
                 f"energy={report.energy_wh:.1f}Wh "
                 f"(active {report.active_wh:.1f} + idle {report.idle_wh:.1f})"
                 f" cost=${report.usd:.2f}")
    return "\n".join(lines)
