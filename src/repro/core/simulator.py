"""Discrete-event execution engine over the modeled cluster.

Runs one or many workflows (DAG + ExecutionPlan) against the cluster
manager's pools: list-scheduling with dependency and capacity constraints,
warm-instance reuse, cold-start (weights-load) latencies, and energy/$
integration via ``EnergyLedger``. Produces per-task traces — the Fig-3
artifact — and is the scale path (a 1000-node cluster is just bigger pool
capacities; the engine is O(events log events)).

Semantics notes:
- A *model* implementation (``load_time_s > 0`` or zoo-backed) executes on
  persistent warm instances; first use pays the load. Tools alloc/release
  per task.
- If fewer than ``n_instances`` instances fit right now, the task degrades
  gracefully to what fits (>=1) rather than deadlocking; if none fit, it
  waits for the next completion event.
- Energy: active increments per task; the idle floor for every metered pool
  is integrated over the makespan at ``finalize`` (paper Table-2 semantics).

Multi-tenant semantics (core/admission.py):
- Workflows may arrive as ``Submission`` objects carrying a tenant class
  and an optional ``plan_fn``; planning then happens *at admission*, so the
  scheduler sees the cluster state (warm instances, free devices) at
  arrival rather than an empty cluster.
- Ready work is dispatched in admission-policy order (FCFS /
  strict-priority / weighted-fair), work-conserving.
- Harvest-class tenants hold preemptible leases. When a priority tenant
  cannot allocate, the engine reclaims harvest leases via
  ``ClusterManager.preempt_harvest``: the victims' in-flight tasks are
  cancelled, re-enqueued, and both the truncated run (``note="preempted"``)
  and the re-execution appear in the trace.
- Work-item checkpoint/resume (DESIGN.md §6.4): a *chunkable* victim's
  completed batch steps survive preemption — ``cancel_task`` inverts the
  ``ProfileStore.schedule_latency`` step schedule over the compute window
  (``ProfileStore.completed_items``), records the surviving item count on
  the workflow state, and the requeued attempt executes only the residual
  (``note="resume"``, composed with warmth as e.g. ``"resume+cold"``).
  Refunds are step-granular: completed steps stay charged (their items are
  never re-executed), the in-flight step is refunded (its items ride the
  residual, which re-charges them), so a resumed task's total charge is
  exactly ``schedule_latency(total items)`` across attempts. Non-chunkable
  tasks keep the restart-from-scratch path: time-fraction refund of the
  unexecuted remainder, ``note="requeue"``. Discarded-but-executed compute
  accrues in ``SimReport.wasted_dev_s`` either way.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable

from .admission import Admission, ServedLedger, get_policy
from .agents import AgentLibrary
from .cluster import ClusterManager, Instance, Lease
from .dag import DAG
from .energy import CATALOG, EnergyLedger
from .profiles import ProfileStore
from .scheduler import ExecutionPlan, TaskConfig


@dataclass(frozen=True)
class TraceEntry:
    """One task execution interval in the Fig-3-style trace."""

    workflow: str
    task: str
    impl: str
    pool: str
    devices: int              # total devices (n_devices * n_instances)
    start: float
    end: float
    note: str = ""


@dataclass
class SimReport:
    """Aggregate outcome of one simulated run (energy, trace, spans)."""

    makespan_s: float
    energy_wh: float
    active_wh: float
    idle_wh: float
    usd: float
    trace: list[TraceEntry]
    per_workflow: dict[str, dict]
    pool_busy_device_s: dict[str, float]
    preemptions: int = 0
    requeues: int = 0            # task re-executions caused by preemption
    resumed_items: int = 0       # work-items salvaged by checkpoint/resume
    wasted_dev_s: float = 0.0    # executed-then-discarded device-seconds

    def workflow_span(self, wf: str) -> float:
        """Arrival-to-finish seconds for one workflow (tenant latency)."""
        return self.per_workflow[wf]["finish"] - self.per_workflow[wf]["start"]


@dataclass
class Submission:
    """One tenant's workflow submission to the multi-tenant engine.

    ``plan`` may be ``None`` with a ``plan_fn`` instead: the engine calls it
    when the workflow is admitted (its arrival event fires), so scheduling
    sees the live cluster state.
    """

    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None


@dataclass
class _WfState:
    dag: DAG
    plan: ExecutionPlan | None
    arrival: float
    tenant: str = "standard"
    plan_fn: "Callable[[], ExecutionPlan] | None" = None
    done: set[str] = field(default_factory=set)
    started: set[str] = field(default_factory=set)
    finish: float = 0.0
    attempt: dict[str, int] = field(default_factory=dict)
    # work-items checkpointed per task: survived preemption, never re-run
    items_done: dict[str, int] = field(default_factory=dict)


@dataclass
class _Running:
    """Book-keeping for an in-flight task (needed to preempt it)."""

    cfg: TaskConfig
    leases: list[Lease]
    insts: list[Instance]
    start: float
    end: float
    compute_begin: float      # start + weights-load wall time
    ndev: int
    dev_s: float
    pf: float
    note: str
    n_inst: int               # instances actually acquired (may be < plan)
    batch: int                # effective batch (CPU pools force 1)
    items_done0: int          # items already checkpointed before this run
    items_per_inst: int       # the split _duration charged (refund inverts it)
    resumable: bool           # chunkable: completed steps survive preempt


class Simulator:
    """Discrete-event engine executing plans against the modeled cluster."""

    def __init__(self, cluster: ClusterManager, library: AgentLibrary,
                 profiles: ProfileStore, resume: bool = True):
        self.cluster = cluster
        self.library = library
        self.profiles = profiles
        # work-item checkpoint/resume of preempted chunkable tasks
        # (DESIGN.md §6.4); False restores restart-from-scratch for every
        # victim (the pre-resume baseline benchmarks compare against)
        self.resume = resume

    # -- duration under actual warmth ------------------------------------------
    def _duration(self, node, cfg: TaskConfig, n_inst: int,
                  new_instances: int, items_done: int = 0) \
            -> tuple[float, float, int]:
        """Wall/compute seconds (and per-instance item count) of one run.

        Returns ``(latency, compute, items_per_inst)``; the item split is
        returned so ``cancel_task``'s refund inverts *exactly* the schedule
        charged here (stored on ``_Running.items_per_inst``) rather than
        re-deriving it.
        """
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        work = impl.work_fn(node.tokens_in, node.tokens_out)
        batch = 1 if spec.kind == "cpu" else cfg.batch
        remaining = max(node.work_items - items_done, 0)
        items = math.ceil(remaining / max(n_inst, 1))
        # the same batched execution schedule the scheduler estimates with
        # (ProfileStore.schedule_latency: full steps + a remainder step at
        # its own price): one source of truth for plan vs actual. A resumed
        # attempt prices only the residual items (Scheduler.estimate takes
        # the same items_done, preserving estimate/actual parity).
        compute = self.profiles.schedule_latency(impl, spec, cfg.n_devices,
                                                 work, batch, items)
        lat = compute
        if new_instances and not cfg.warm:
            # cfg.warm = provisioned capacity (PTU-style): always-on, no load
            lat += impl.load_time_s
        return lat, compute, items

    def _is_model(self, impl) -> bool:
        return impl.load_time_s > 0 or impl.arch is not None

    # -- engine ------------------------------------------------------------------
    def run(self,
            workflows: "dict[str, tuple[DAG, ExecutionPlan, float] | Submission]",
            log: list | None = None, policy=None) -> SimReport:
        """Execute one or many workflows; returns the ``SimReport``.

        ``workflows`` maps workflow id to either a ``(dag, plan, arrival)``
        triple or a ``Submission`` (tenant class + optional admission-time
        ``plan_fn``). ``policy`` selects the admission order
        (``core.admission``: fcfs | strict-priority | weighted-fair);
        ``log`` collects human-readable event lines when provided.
        """
        pol = get_policy(policy)
        wfs: dict[str, _WfState] = {}
        for wid, sub in workflows.items():
            if not isinstance(sub, Submission):
                dag, plan, arrival = sub
                sub = Submission(dag, plan, arrival)
            wfs[wid] = _WfState(sub.dag, sub.plan, sub.arrival, sub.tenant,
                                sub.plan_fn)
        for wid, st in wfs.items():
            self.cluster.register_workflow(wid, st.dag)

        ledger = EnergyLedger()
        served = ServedLedger()
        preempt0 = self.cluster.preemptions
        trace: list[TraceEntry] = []
        busy: dict[str, float] = {}
        running: dict[tuple[str, str], _Running] = {}
        lease_owner: dict[int, tuple[str, str]] = {}
        requeues = 0
        resumed_items = 0
        wasted_dev_s = 0.0
        events: list[tuple[float, int, str, object]] = []
        ctr = itertools.count()
        for wid, st in wfs.items():
            heapq.heappush(events, (st.arrival, next(ctr), "arrive", wid))
        t = 0.0

        def ready_tasks():
            """Dispatchable (workflow, task) pairs in admission order."""
            out = []
            admitted = [Admission(wid, st.tenant, st.arrival)
                        for wid, st in wfs.items()
                        if t >= st.arrival and st.plan is not None]
            for adm in sorted(admitted,
                              key=lambda a: pol.key(a, served.served)):
                st = wfs[adm.workflow]
                for tid in st.dag.topo_order:
                    if tid in st.done or tid in st.started:
                        continue
                    if all(d in st.done for d in st.dag.nodes[tid].deps):
                        out.append((adm.workflow, tid))
            return out

        def cancel_task(vwid: str, vtid: str):
            """Preemption: roll a task back to pending, checkpoint the work
            already finished (chunkable tasks), refund the unearned energy/$
            and release whatever it still holds."""
            nonlocal requeues, resumed_items, wasted_dev_s
            rec = running.pop((vwid, vtid), None)
            if rec is None:
                return
            vst = wfs[vwid]
            vst.started.discard(vtid)
            vst.attempt[vtid] = vst.attempt.get(vtid, 0) + 1
            for lease in rec.leases:
                lease_owner.pop(lease.id, None)
                if self.cluster.lease_active(lease):
                    self.cluster.release(lease, t)
            for inst in rec.insts:
                if inst.lease is not None:
                    lease_owner.pop(inst.lease.id, None)
                if inst in self.cluster.instances:
                    self.cluster.evict_instance(inst, t)
            spec = CATALOG[self.cluster.pools[rec.cfg.pool].device]
            # the charged dev_s covers compute only (weights-load is an
            # idle-power period), so progress is measured over the compute
            # window [compute_begin, end] — a victim preempted mid-load
            # gets a full refund either way
            window = max(rec.end - rec.compute_begin, 1e-12)
            elapsed = min(max(t - rec.compute_begin, 0.0), window)
            # executed device-seconds so far; dev_s spreads uniformly over
            # the window (paths run concurrently, so the rate is
            # ndev * paths even when the wall clock is path-multiplied)
            exec_dev_s = rec.dev_s * (elapsed / window)
            if rec.resumable and self.resume:
                # checkpoint/resume: invert the step schedule over the
                # compute window — completed batch steps survive, the
                # in-flight step is discarded
                impl = self.library.impls[rec.cfg.impl]
                node = vst.dag.nodes[vtid]
                work = impl.work_fn(node.tokens_in, node.tokens_out)
                done, wall = self.profiles.completed_items(
                    impl, spec, rec.cfg.n_devices, work, rec.batch,
                    rec.items_per_inst, elapsed)
                kept_items = min(done * rec.n_inst,
                                 node.work_items - rec.items_done0)
                if kept_items:
                    vst.items_done[vtid] = rec.items_done0 + kept_items
                    resumed_items += kept_items
                # step-granular refund: completed steps stay charged (their
                # items never re-run); the in-flight step is refunded — its
                # items ride the residual requeue, which re-charges them,
                # so the task's total charge across attempts is exactly
                # schedule_latency(total items)
                kept_dev_s = wall * rec.ndev * rec.cfg.paths
                refund = max(rec.dev_s - kept_dev_s, 0.0)
                wasted_dev_s += max(exec_dev_s - kept_dev_s, 0.0)
            else:
                # restart from scratch (non-chunkable / resume disabled):
                # refund only the unexecuted remainder — the executed
                # compute stays charged (that energy was really burned)
                # and is all wasted, since the requeue re-runs everything
                refund = rec.dev_s * (1.0 - elapsed / window)
                wasted_dev_s += exec_dev_s
            ledger.charge_active(spec, -refund,
                                 utilization=rec.pf, pool=rec.cfg.pool)
            busy[rec.cfg.pool] = busy.get(rec.cfg.pool, 0.0) - refund
            served.charge(vst.tenant, -refund)
            requeues += 1
            trace.append(TraceEntry(vwid, vtid, rec.cfg.impl, rec.cfg.pool,
                                    rec.ndev, rec.start, t,
                                    note="preempted"))
            if log is not None:
                kept = vst.items_done.get(vtid, 0)
                log.append(f"[{t:8.1f}s] preempt {vwid}:{vtid} "
                           f"({rec.ndev}x{rec.cfg.pool}); requeued"
                           + (f" ({kept} items checkpointed)" if kept
                              else ""))

        def try_preempt(pool: str, n_needed: int) -> bool:
            """Reclaim harvest-class leases for a priority tenant."""
            deficit = n_needed - self.cluster.free(pool)
            if deficit <= 0 or self.cluster.harvest_devices(pool) < deficit:
                return False
            victims = self.cluster.preempt_harvest(pool, deficit, t)
            for lease in victims:
                # idle warm instance on a preempted lease: drop the shell
                # through the manager's eviction path so its bookkeeping
                # (instance list + lease table) stays consistent; the lease
                # itself was already released by preempt_harvest, which
                # evict_instance tolerates
                for inst in [i for i in self.cluster.instances
                             if i.lease is not None
                             and i.lease.id == lease.id]:
                    self.cluster.evict_instance(inst, t)
                owner = lease_owner.pop(lease.id, None)
                if owner is not None:
                    cancel_task(*owner)
            return bool(victims)

        def try_start(wid: str, tid: str) -> bool:
            """Start a ready task if its resources fit right now."""
            st = wfs[wid]
            node = st.dag.nodes[tid]
            cfg = st.plan[tid]
            impl = self.library.impls[cfg.impl]
            spec = CATALOG[self.cluster.pools[cfg.pool].device]
            harvest = st.tenant == "harvest"
            priority = st.tenant == "priority"
            leases: list[Lease] = []
            insts: list[Instance] = []
            new_inst = 0
            # degrade configs planned for a larger cluster (elasticity)
            cap = self.cluster.pools[cfg.pool].capacity
            if cfg.n_devices > cap:
                lo = impl.min_devices.get(spec.kind, 1)
                n = 1
                while n * 2 <= cap:
                    n *= 2
                if n < lo:
                    raise RuntimeError(
                        f"{cfg.impl} needs >= {lo} {spec.kind} devices; "
                        f"pool {cfg.pool} has {cap}")
                cfg = cfg.with_(n_devices=n, n_instances=1)
                st.plan.configs[tid] = cfg

            def _alloc_or_evict(n):
                lease = self.cluster.alloc(cfg.pool, n, t, harvest=harvest)
                if lease is None:
                    # evict idle warm instances of *other* impls (LRU)
                    idle = sorted(
                        (i for i in self.cluster.instances
                         if i.pool == cfg.pool and i.busy_until <= t
                         and i.impl != cfg.impl),
                        key=lambda i: i.warm_since)
                    for victim in idle:
                        self.cluster.evict_instance(victim, t)
                        lease = self.cluster.alloc(cfg.pool, n, t,
                                                   harvest=harvest)
                        if lease is not None:
                            break
                return lease

            if self._is_model(impl):
                def _acquire():
                    nonlocal new_inst
                    # reuse idle warm instances on the right pool/size first
                    avail = [i for i in self.cluster.instances
                             if i.impl == cfg.impl and i.pool == cfg.pool
                             and i.n_devices == cfg.n_devices
                             and i.busy_until <= t and i not in insts]
                    insts.extend(avail[:cfg.n_instances - len(insts)])
                    while len(insts) < cfg.n_instances:
                        lease = _alloc_or_evict(cfg.n_devices)
                        if lease is None:
                            break
                        inst = Instance(cfg.impl, cfg.pool, cfg.n_devices,
                                        warm_since=t, lease=lease)
                        self.cluster.add_instance(inst)
                        insts.append(inst)
                        new_inst += 1

                _acquire()
                if not insts and priority and \
                        try_preempt(cfg.pool, cfg.n_devices):
                    _acquire()
                if not insts:
                    return False
                for inst in insts:
                    self._relabel_lease(inst, harvest, t)
                n_inst = len(insts)
            else:
                total = cfg.n_devices * cfg.n_instances
                lease = self.cluster.alloc(cfg.pool, total, t,
                                           harvest=harvest)
                n_inst = cfg.n_instances
                if lease is None:
                    lease = _alloc_or_evict(cfg.n_devices)
                    n_inst = 1
                    if lease is None and priority and \
                            try_preempt(cfg.pool, cfg.n_devices):
                        lease = _alloc_or_evict(cfg.n_devices)
                    if lease is None:
                        return False
                leases.append(lease)

            items_done = st.items_done.get(tid, 0) if self.resume else 0
            dur, compute, per_inst = self._duration(node, cfg, n_inst,
                                                    new_inst, items_done)
            pmult = cfg.paths if cfg.paths > 1 and not node.chunkable else 1.0
            dur *= pmult
            end = t + dur
            # the tail of the run is compute; any lead-in is weights load
            compute_begin = end - compute * pmult
            for inst in insts:
                inst.busy_until = end
            ndev = cfg.n_devices * n_inst
            dev_s = compute * ndev * cfg.paths
            pf = self.profiles.power_frac(impl, spec, cfg.n_devices)
            ledger.charge_active(spec, dev_s, utilization=pf, pool=cfg.pool)
            busy[cfg.pool] = busy.get(cfg.pool, 0.0) + dev_s
            served.charge(st.tenant, dev_s)
            st.started.add(tid)
            attempt = st.attempt.get(tid, 0)
            # compose the note: restart kind + warmth, so preemption
            # analysis sees a requeue that also paid a cold weights load
            # ("requeue+cold") rather than losing the restart cost
            restart = ("resume" if attempt and items_done else
                       "requeue" if attempt else "")
            warmth = "cold" if new_inst else ("warm" if insts else "")
            note = "+".join(s for s in (restart, warmth) if s)
            for lease in leases:
                lease_owner[lease.id] = (wid, tid)
            for inst in insts:
                if inst.lease is not None:
                    lease_owner[inst.lease.id] = (wid, tid)
            running[(wid, tid)] = _Running(cfg, leases, insts, t, end,
                                           compute_begin, ndev, dev_s, pf,
                                           note, n_inst=n_inst,
                                           batch=(1 if spec.kind == "cpu"
                                                  else cfg.batch),
                                           items_done0=items_done,
                                           items_per_inst=per_inst,
                                           resumable=node.chunkable)
            heapq.heappush(events, (end, next(ctr), "finish",
                                    (wid, tid, attempt)))
            if log is not None:
                log.append(f"[{t:8.1f}s] start {wid}:{tid} on "
                           f"{ndev}x{cfg.pool} ({cfg.impl})"
                           + (f" [{restart}]" if restart else ""))
            return True

        while events:
            t, _, kind, payload = heapq.heappop(events)
            # drain every event sharing this timestamp before dispatching:
            # simultaneous arrivals are all admitted (and planned) before
            # any of them starts work, so admission-policy order holds for
            # same-time tenants and identical tenants admitted into the
            # same cluster state share one plan via the plan cache.
            batch = [(kind, payload)]
            while events and events[0][0] == t:
                _, _, k, p = heapq.heappop(events)
                batch.append((k, p))
            for kind, payload in batch:
                if kind == "arrive":
                    st = wfs[payload]
                    if st.plan is None:
                        if st.plan_fn is None:
                            raise ValueError(
                                f"workflow {payload!r} submitted without a "
                                f"plan or plan_fn")
                        # admission-time planning: the scheduler sees the
                        # live cluster (warm instances, free devices)
                        st.plan = st.plan_fn()
                elif kind == "finish":
                    wid, tid, attempt = payload
                    st = wfs[wid]
                    if st.attempt.get(tid, 0) != attempt:
                        continue    # stale: this execution was preempted
                    rec = running.pop((wid, tid))
                    st.done.add(tid)
                    st.finish = max(st.finish, t)
                    self.cluster.complete_task(wid, tid)
                    impl = self.library.impls[rec.cfg.impl]
                    for lease in rec.leases:
                        # model instances keep their devices (stay warm);
                        # tools release. Instance devices are reclaimed by
                        # rebalance.
                        lease_owner.pop(lease.id, None)
                        if not self._is_model(impl):
                            self.cluster.release(lease, t)
                    for inst in rec.insts:
                        if inst.lease is not None:
                            lease_owner.pop(inst.lease.id, None)
                    trace.append(TraceEntry(wid, tid, rec.cfg.impl,
                                            rec.cfg.pool, rec.ndev,
                                            rec.start, t, note=rec.note))
                    # workflow-aware reclamation once demand disappears
                    for action in self.cluster.rebalance(self.library, t):
                        if log is not None:
                            log.append(f"[{t:8.1f}s] rebalance: {action}")
            # start whatever is now ready and fits
            progress = True
            while progress:
                progress = False
                for wid, tid in ready_tasks():
                    if try_start(wid, tid):
                        progress = True

        stuck = [(wid, tid) for wid, s in wfs.items()
                 for tid in s.dag.nodes
                 if tid not in s.done]
        if stuck:
            raise RuntimeError(f"deadlocked tasks (resources never fit): "
                               f"{stuck[:8]}")
        makespan = max((st.finish for st in wfs.values()), default=0.0)
        # instances still holding devices release at makespan (accounted as
        # idle power via the pool floor below).
        for pool, p in self.cluster.pools.items():
            spec = p.spec
            ledger.charge_idle(spec, p.capacity, makespan)

        per_wf = {wid: {"start": st.arrival, "finish": st.finish,
                        "tasks": len(st.dag), "tenant": st.tenant}
                  for wid, st in wfs.items()}
        return SimReport(
            makespan_s=makespan,
            energy_wh=ledger.wh,
            active_wh=ledger.active_joules / 3600.0,
            idle_wh=ledger.idle_joules / 3600.0,
            usd=ledger.usd,
            trace=sorted(trace, key=lambda e: (e.start, e.end, e.workflow)),
            per_workflow=per_wf,
            pool_busy_device_s=busy,
            preemptions=self.cluster.preemptions - preempt0,
            requeues=requeues,
            resumed_items=resumed_items,
            wasted_dev_s=wasted_dev_s,
        )

    def _relabel_lease(self, inst: Instance, harvest: bool, t: float):
        """Keep an instance lease's preemptibility in sync with the tenant
        running on it: a priority/standard task on a harvest-created warm
        instance must not be preemptible (and vice versa)."""
        lease = inst.lease
        if lease is None or lease.harvest == harvest:
            return
        if not self.cluster.lease_active(lease):
            inst.lease = None
            return
        self.cluster.release(lease, t)
        inst.lease = self.cluster.alloc(inst.pool, inst.n_devices, t,
                                        harvest=harvest)


def render_trace(report: SimReport, width: int = 72) -> str:
    """ASCII Fig-3-style execution trace."""
    if not report.trace:
        return "(empty trace)"
    span = max(report.makespan_s, 1e-9)
    lines = [f"{'task':<28s} {'pool':<10s} {'t':>7s}  timeline"]
    for e in report.trace:
        a = int(e.start / span * width)
        b = max(int(e.end / span * width), a + 1)
        bar = " " * a + "#" * (b - a)
        lines.append(f"{e.workflow + ':' + e.task:<28.28s} {e.pool:<10.10s} "
                     f"{e.end - e.start:7.1f}  |{bar:<{width}s}|")
    lines.append(f"makespan={report.makespan_s:.1f}s "
                 f"energy={report.energy_wh:.1f}Wh "
                 f"(active {report.active_wh:.1f} + idle {report.idle_wh:.1f})"
                 f" cost=${report.usd:.2f}")
    return "\n".join(lines)
