"""Discrete-event execution façade over the layered engine (``core/engine``).

Runs one or many workflows (DAG + ExecutionPlan) against the cluster
manager's pools: list-scheduling with dependency and capacity constraints,
warm-instance reuse, cold-start (weights-load) latencies, and energy/$
integration via ``EnergyLedger``. Produces per-task traces — the Fig-3
artifact — and is the scale path (a 1000-node cluster is just bigger pool
capacities; the engine is O(events log events)).

This module is the *stable import surface*: ``Simulator`` (run modes,
duration pricing, pool limits) plus re-exports of the engine's public
records (``Submission``/``TraceEntry``/``SimReport``/``OpenLoopReport``)
and ``render_trace``. The event loop, dispatch, accounting and recovery
layers live in :mod:`repro.core.engine` (DESIGN.md §12):

- ``engine.events`` — event heap, clock, same-timestamp drain loops,
  contiguous-finish coalescing;
- ``engine.dispatch`` — admission, indexed ready-set, blocked-group epoch
  memo, task start/preempt/finish settlement;
- ``engine.ledger`` — energy/$/served charging and refunds, report
  assembly, steady-state serving metrics;
- ``engine.recovery`` — fault injection, retry/backoff, crash/repair,
  hedging.

Semantics notes:
- A *model* implementation (``load_time_s > 0`` or zoo-backed) executes on
  persistent warm instances; first use pays the load. Tools alloc/release
  per task.
- If fewer than ``n_instances`` instances fit right now, the task degrades
  gracefully to what fits (>=1) rather than deadlocking; if none fit, it
  waits for the next completion event.
- Energy: active increments per task; the idle floor for every metered pool
  is integrated over the *capacity timeline* at finalize (paper Table-2
  semantics; under autoscaling the floor follows ``set_capacity`` changes).
- Multi-tenant admission, harvest preemption and work-item
  checkpoint/resume semantics are documented on the engine layers that
  implement them (``engine.dispatch``, ``engine.ledger``).

Event-engine fast path (DESIGN.md §8): the dispatch loop keeps an *indexed
ready-set* per workflow — roots enter at admission, successors enter when
their last dependency finishes, preemption victims re-enter on cancel — so
each pass touches only genuinely ready tasks instead of rescanning every
workflow's whole DAG. Tasks that failed to start are skipped while their
pool's availability epoch is unchanged (``ClusterManager.free_epoch``).
The seed's full rescan survives as ``fast_dispatch=False`` — the reference
the equivalence tests compare byte-identical traces against.
"""
from __future__ import annotations

import heapq
import math
import time
from typing import Iterable, Iterator

from .admission import get_policy
from .agents import AgentLibrary
from .cluster import ClusterManager, Instance, kv_cache_cap
from .dag import DAG
from .energy import CATALOG
from .engine import (Engine, OpenLoopReport, SimReport, Submission,
                     TraceEntry)
from .faults import FaultProfile
from .profiles import CostQuery, ProfileStore
from .scheduler import ExecutionPlan, TaskConfig

# back-compat alias: the engine class was ``simulator._Engine`` before the
# package split
_Engine = Engine

__all__ = [
    "OpenLoopReport", "SimReport", "Simulator", "Submission", "TraceEntry",
    "render_trace",
]


class Simulator:
    """Discrete-event engine executing plans against the modeled cluster."""

    def __init__(self, cluster: ClusterManager, library: AgentLibrary,
                 profiles: ProfileStore, resume: bool = True,
                 fast_dispatch: bool = True, kv_cache: bool = True,
                 cache_affinity: bool = True,
                 faults: FaultProfile | None = None,
                 telemetry=None, routed_interfaces: tuple = ()):
        self.cluster = cluster
        self.library = library
        self.profiles = profiles
        # per-task outcome log feeding the offline routing evaluator
        # (DESIGN.md §11): a core.telemetry.TelemetryStore, written *after*
        # each task's accounting settles so it never influences the run;
        # None keeps the engine byte-identical to a telemetry-less one.
        # ``routed_interfaces`` marks which interfaces a learned router
        # chose the impl for (stamped onto the records).
        self.telemetry = telemetry
        self.routed_interfaces = frozenset(routed_interfaces)
        # seeded fault injection + recovery (DESIGN.md §10); None keeps
        # every fault path provably inert — runs are byte-identical to an
        # engine without the subsystem (the golden tests pin this)
        self.faults = faults
        # KV/prefix-cache residency (DESIGN.md §9). kv_cache is the master
        # switch: False makes every cache path provably inert (sessionless
        # pricing, no ledger writes) — the byte-identity reference.
        # cache_affinity is the placement lever: False keeps hit-rate
        # pricing but ranks warm shells cache-blind (the ablation axis the
        # cache bench compares against).
        self.kv_cache = kv_cache
        self.cache_affinity = cache_affinity
        # work-item checkpoint/resume of preempted chunkable tasks
        # (DESIGN.md §6.4); False restores restart-from-scratch for every
        # victim (the pre-resume baseline benchmarks compare against)
        self.resume = resume
        # indexed ready-set + blocked-group dispatch (DESIGN.md §8);
        # False selects the seed's full-rescan reference path, which the
        # equivalence tests compare byte-identical traces against
        self.fast_dispatch = fast_dispatch
        # autoscale limits per pool (run_open_loop fills this; closed-loop
        # runs treat current capacity as the limit)
        self._scale_limits: dict[str, int] = {}
        # pool capacities at fault-run start (seed_faults fills this):
        # with no autoscaler, a crash-shrunk pool's limit is its nominal
        # size, so over-sized plans wait for the repair instead of
        # permanently degrading to the post-crash capacity
        self._nominal_caps: dict[str, int] = {}
        # duration memo: open-loop serving re-runs identical (config, node
        # workload) pairs thousands of times; keyed on everything
        # _duration reads, including the profile-store version (pin()
        # bumps it, invalidating stale latencies)
        self._dur_memo: dict[tuple, tuple[float, float, int]] = {}

    def _pool_limit(self, pool: str) -> int:
        """Max capacity a pool may scale to (its size when not scaled).

        Autoscaler limits take precedence; otherwise a fault run answers
        with the pool's nominal (pre-crash) size, and a fault-free run
        with the current capacity (the seed's behaviour)."""
        lim = self._scale_limits.get(pool)
        if lim is not None:
            return lim
        return self._nominal_caps.get(pool,
                                      self.cluster.pools[pool].capacity)

    def _cache_cap(self, cfg: TaskConfig) -> float:
        """HBM bytes a new instance of ``cfg`` may devote to KV prefixes
        (0.0 when caches are off or the impl doesn't track KV)."""
        if not self.kv_cache:
            return 0.0
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        return kv_cache_cap(spec, cfg.n_devices, impl.params_bytes,
                            impl.kv_bytes_per_token)

    # -- duration under actual warmth ------------------------------------------
    def _duration(self, node, cfg: TaskConfig, n_inst: int,
                  new_instances: int, items_done: int = 0,
                  cache_frac: float = 0.0) -> tuple[float, float, int]:
        """Wall/compute seconds (and per-instance item count) of one run.

        Returns ``(latency, compute, items_per_inst)``; the item split is
        returned so ``cancel_task``'s refund inverts *exactly* the schedule
        charged here (stored on ``_Running.items_per_inst``) rather than
        re-deriving it. ``cache_frac`` is the resident-prefix hit fraction:
        the schedule prices only the un-cached prefill (DESIGN.md §9).
        """
        key = (cfg.impl, cfg.pool, cfg.n_devices, cfg.batch, cfg.warm,
               n_inst, bool(new_instances), items_done, node.work_items,
               node.tokens_in, node.tokens_out, cache_frac,
               self.profiles.version)
        memo = self._dur_memo.get(key)
        if memo is not None:
            return memo
        impl = self.library.impls[cfg.impl]
        spec = CATALOG[self.cluster.pools[cfg.pool].device]
        work = impl.work_fn(node.tokens_in, node.tokens_out)
        batch = 1 if spec.kind == "cpu" else cfg.batch
        remaining = max(node.work_items - items_done, 0)
        items = math.ceil(remaining / max(n_inst, 1))
        # the same batched execution schedule the scheduler estimates with
        # (ProfileStore.schedule_latency: full steps + a remainder step at
        # its own price): one source of truth for plan vs actual. A resumed
        # attempt prices only the residual items (Scheduler.estimate takes
        # the same items_done, preserving estimate/actual parity); a warm
        # prefix discounts both sides through the same CostQuery.
        compute = self.profiles.schedule_latency(CostQuery(
            impl=impl, spec=spec, n_devices=cfg.n_devices, work=work,
            batch=batch, items=items, cache_hit_frac=cache_frac))
        lat = compute
        if new_instances and not cfg.warm:
            # cfg.warm = provisioned capacity (PTU-style): always-on, no load
            lat += impl.load_time_s
        out = (lat, compute, items)
        self._dur_memo[key] = out
        return out

    def _is_model(self, impl) -> bool:
        return impl.load_time_s > 0 or impl.arch is not None

    # -- closed-loop engine ------------------------------------------------------
    def run(self,
            workflows: "dict[str, tuple[DAG, ExecutionPlan, float] | Submission]",
            log: list | None = None, policy=None) -> SimReport:
        """Execute one or many workflows; returns the ``SimReport``.

        ``workflows`` maps workflow id to either a ``(dag, plan, arrival)``
        triple or a ``Submission`` (tenant class + optional admission-time
        ``plan_fn``). ``policy`` selects the admission order
        (``core.admission``: fcfs | strict-priority | weighted-fair);
        ``log`` collects human-readable event lines when provided.
        """
        pol = get_policy(policy)
        eng = Engine(self, pol, log)
        for wid, sub in workflows.items():
            if not isinstance(sub, Submission):
                dag, plan, arrival = sub
                sub = Submission(dag, plan, arrival)
            eng.add_submission(wid, sub)
        for wid, st in eng.wfs.items():
            self.cluster.register_workflow(wid, st.dag)
        if self.faults is not None:
            eng.seed_faults()
        try:
            eng.loop_closed()
        finally:
            self._nominal_caps = {}

        stuck = [(wid, tid) for wid, s in eng.wfs.items()
                 if not s.dead
                 for tid in s.dag.nodes
                 if tid not in s.done]
        if stuck:
            raise RuntimeError(f"deadlocked tasks (resources never fit): "
                               f"{stuck[:8]}")
        if __debug__:
            self.cluster.audit()
        makespan = max((st.finish for st in eng.wfs.values()), default=0.0)
        # instances still holding devices release at makespan (accounted as
        # idle power via the pool floor below).
        eng.finalize(makespan)
        return eng.report(makespan)

    # -- open-loop engine --------------------------------------------------------
    def run_open_loop(self,
                      source: "Iterable[tuple[str, Submission]]",
                      horizon_s: float,
                      *,
                      warmup_s: float = 0.0,
                      policy=None,
                      autoscaler=None,
                      log: list | None = None,
                      collect_trace: bool = True) -> OpenLoopReport:
        """Serve an open-loop arrival stream for ``horizon_s`` seconds.

        ``source`` yields ``(workflow_id, Submission)`` pairs with
        non-decreasing arrival times (``core.arrivals`` generators qualify);
        arrivals are pulled lazily — one look-ahead submission lives in the
        event heap at a time, so a 10k-workflow sweep never materializes
        its whole future. Arrivals past ``horizon_s`` are not admitted;
        admitted work drains to completion.

        Steady-state metrics trim the warmup: only workflows arriving in
        ``[warmup_s, horizon_s]`` count toward per-class SLO attainment,
        goodput, and the span percentiles. ``autoscaler`` (an
        ``core.autoscale.Autoscaler``) is consulted on periodic ``scale``
        events and applies pool resizes through
        ``ClusterManager.set_capacity`` — scale-ups after the policy lag,
        scale-downs immediately (cooldown permitting).
        """
        wall0 = time.perf_counter()
        pol = get_policy(policy)
        eng = Engine(self, pol, log, collect_trace=collect_trace)
        stream: Iterator[tuple[str, Submission]] = iter(source)
        arrivals = 0
        last_arrival = 0.0
        exhausted = False

        def _pull() -> bool:
            """Admit the next submission into the heap (one look-ahead)."""
            nonlocal arrivals, last_arrival, exhausted
            if exhausted:
                return False
            for wid, sub in stream:
                if sub.arrival > horizon_s:
                    # past the arrival window: stop pulling (the source may
                    # be an infinite generator)
                    exhausted = True
                    return False
                if sub.arrival < last_arrival:
                    raise ValueError(
                        f"open-loop source must be time-ordered: "
                        f"{wid!r} arrives at {sub.arrival} after "
                        f"{last_arrival}")
                last_arrival = sub.arrival
                eng.add_submission(wid, sub)
                arrivals += 1
                return True
            exhausted = True
            return False

        _pull()
        if self.faults is not None:
            eng.seed_faults()
        if autoscaler is not None:
            self._scale_limits = autoscaler.limits()
            autoscaler.validate(self.cluster)
            heapq.heappush(eng.events,
                           (autoscaler.interval_s, next(eng.ctr),
                            "scale", None))
        scale_actions: list[tuple] = []
        try:
            eng.loop_open(_pull, autoscaler, scale_actions)
        finally:
            self._scale_limits = {}
            self._nominal_caps = {}

        if __debug__:
            self.cluster.audit()
        makespan = max((st.finish for st in eng.wfs.values()), default=0.0)
        eng.finalize(makespan)
        rep = eng.report(makespan)
        wall = time.perf_counter() - wall0
        return eng.steady_state(rep, horizon_s, warmup_s, arrivals, wall,
                                scale_actions)

    def _relabel_lease(self, inst: Instance, harvest: bool, t: float):
        """Keep an instance lease's preemptibility in sync with the tenant
        running on it: a priority/standard task on a harvest-created warm
        instance must not be preemptible (and vice versa)."""
        lease = inst.lease
        if lease is None or lease.harvest == harvest:
            return
        if not self.cluster.lease_active(lease):
            inst.lease = None
            return
        # flip the flag in place (the lease keeps its id and devices; the
        # seed's release-then-realloc round trip was an artifact of Lease
        # being frozen). Flipping *to* harvest adds preemptible supply, so
        # the pool's availability epoch must move — a blocked priority
        # task may now preempt its way in; flipping away removes supply
        # and can never unblock anything.
        lease.harvest = harvest
        if harvest:
            self.cluster.free_epoch[lease.pool] += 1
            self.cluster.epoch_total += 1


def render_trace(report: SimReport, width: int = 72,
                 max_rows: int = 200) -> str:
    """ASCII Fig-3-style execution trace.

    Long runs are subsampled to ``max_rows`` evenly-spaced task rows (an
    open-loop sweep has tens of thousands — the full dump was unreadable
    and O(events) lines); a footer notes how many rows were elided.
    ``max_rows <= 0`` disables the cap.
    """
    if not report.trace:
        return "(empty trace)"
    span = max(report.makespan_s, 1e-9)
    entries = report.trace
    elided = 0
    if 0 < max_rows < len(entries):
        step = len(entries) / max_rows
        entries = [entries[int(i * step)] for i in range(max_rows)]
        elided = len(report.trace) - len(entries)
    lines = [f"{'task':<28s} {'pool':<10s} {'t':>7s}  timeline"]
    for e in entries:
        a = int(e.start / span * width)
        b = max(int(e.end / span * width), a + 1)
        bar = " " * a + "#" * (b - a)
        lines.append(f"{e.workflow + ':' + e.task:<28.28s} {e.pool:<10.10s} "
                     f"{e.end - e.start:7.1f}  |{bar:<{width}s}|")
    if elided:
        lines.append(f"... {elided} of {len(report.trace)} rows elided "
                     f"(raise max_rows to see more)")
    lines.append(f"makespan={report.makespan_s:.1f}s "
                 f"energy={report.energy_wh:.1f}Wh "
                 f"(active {report.active_wh:.1f} + idle {report.idle_wh:.1f})"
                 f" cost=${report.usd:.2f}")
    return "\n".join(lines)
