"""Task-DAG intermediate representation.

The orchestrator lowers a declarative ``Job`` into this IR: nodes are agent
invocations, edges are dataflow (paper §3.2 "Job Decomposition"). The IR is
pure metadata — scheduling and execution layers consume it.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Iterable


@dataclass(frozen=True)
class TaskNode:
    """One agent invocation in a workflow DAG."""

    id: str
    description: str                 # NL task text (paper Listing 2)
    agent: str                       # agent *interface* name (library key)
    deps: tuple[str, ...] = ()       # dataflow predecessors
    args: dict = field(default_factory=dict)   # toolcall arguments
    # workload descriptors the profile model consumes:
    work_items: int = 1              # chunkable units (scenes, frames, ...)
    chunkable: bool = False          # may be split across instances
    tokens_in: int = 0               # LLM-agent input size
    tokens_out: int = 0              # LLM-agent output size
    # leading tokens_in span shared with the task's serving session (system
    # prompt + prior turns): the part a resident KV prefix can serve
    prefix_tokens: int = 0

    def with_(self, **kw) -> "TaskNode":
        """Functional update (the dataclass is frozen)."""
        return replace(self, **kw)


class DAG:
    """Validated directed acyclic task graph."""

    def __init__(self, nodes: Iterable[TaskNode]):
        self.nodes: dict[str, TaskNode] = {}
        for n in nodes:
            if n.id in self.nodes:
                raise ValueError(f"duplicate task id {n.id!r}")
            self.nodes[n.id] = n
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise ValueError(f"{n.id!r} depends on unknown {d!r}")
        self._topo = self._toposort()
        self._sig: tuple | None = None
        # successor adjacency + topo rank, precomputed once: the event
        # engine's indexed ready-set walks successors on every finish and
        # orders candidates by topo rank — both must be O(1) lookups, not
        # per-call scans over all nodes
        self._succ: dict[str, tuple[str, ...]] = {i: () for i in self.nodes}
        succ_acc: dict[str, list[str]] = {i: [] for i in self.nodes}
        for n in self.nodes.values():
            for d in n.deps:
                succ_acc[d].append(n.id)
        self._succ = {i: tuple(v) for i, v in succ_acc.items()}
        self._topo_idx: dict[str, int] = {
            tid: k for k, tid in enumerate(self._topo)}

    # -- structure -----------------------------------------------------------
    def _toposort(self) -> tuple[str, ...]:
        indeg = {i: len(n.deps) for i, n in self.nodes.items()}
        out: dict[str, list[str]] = {i: [] for i in self.nodes}
        for n in self.nodes.values():
            for d in n.deps:
                out[d].append(n.id)
        ready = sorted(i for i, k in indeg.items() if k == 0)
        order: list[str] = []
        while ready:
            i = ready.pop(0)
            order.append(i)
            for j in sorted(out[i]):
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
        if len(order) != len(self.nodes):
            cyc = set(self.nodes) - set(order)
            raise ValueError(f"cycle involving {sorted(cyc)}")
        return tuple(order)

    @property
    def topo_order(self) -> tuple[str, ...]:
        """Deterministic topological order of task ids."""
        return self._topo

    def signature(self) -> tuple:
        """Hashable structural identity: everything the scheduler reads.

        Two DAGs with equal signatures produce identical plans against the
        same cluster state — the admission-time plan cache's key
        (DESIGN.md §7). Covers ids, interfaces, edges and the workload
        descriptors (work_items, chunkable, token footprint); toolcall args
        and NL descriptions are excluded (the scheduler never reads them).
        """
        if self._sig is None:
            self._sig = tuple(
                (n.id, n.agent, n.deps, n.work_items, n.chunkable,
                 n.tokens_in, n.tokens_out, n.prefix_tokens)
                for n in (self.nodes[i] for i in self._topo))
        return self._sig

    def successors(self, node_id: str) -> list[str]:
        """Tasks that directly depend on ``node_id`` (precomputed)."""
        return list(self._succ[node_id])

    def succ(self, node_id: str) -> tuple[str, ...]:
        """:meth:`successors` without the defensive copy (hot path)."""
        return self._succ[node_id]

    def topo_index(self, node_id: str) -> int:
        """Rank of ``node_id`` in :attr:`topo_order` (O(1))."""
        return self._topo_idx[node_id]

    def roots(self) -> list[str]:
        """Tasks with no dependencies (ready at arrival)."""
        return [i for i, n in self.nodes.items() if not n.deps]

    def leaves(self) -> list[str]:
        """Tasks nothing depends on (the deliverable stages)."""
        succ_of = {d for n in self.nodes.values() for d in n.deps}
        return [i for i in self.nodes if i not in succ_of]

    # -- analysis -------------------------------------------------------------
    def critical_path(self, durations: dict[str, float]) \
            -> tuple[float, tuple[str, ...]]:
        """Longest path under per-node ``durations`` (lower bound on makespan
        with infinite resources)."""
        finish: dict[str, float] = {}
        best_pred: dict[str, str | None] = {}
        for i in self._topo:
            n = self.nodes[i]
            start, pred = 0.0, None
            for d in n.deps:
                if finish[d] > start:
                    start, pred = finish[d], d
            finish[i] = start + durations.get(i, 0.0)
            best_pred[i] = pred
        end = max(finish, key=finish.get)  # type: ignore[arg-type]
        path = [end]
        while best_pred[path[-1]] is not None:
            path.append(best_pred[path[-1]])  # type: ignore[arg-type]
        return finish[end], tuple(reversed(path))

    def levels(self) -> list[list[str]]:
        """Antichains of tasks that may run concurrently (fan-out view)."""
        depth: dict[str, int] = {}
        for i in self._topo:
            n = self.nodes[i]
            depth[i] = 1 + max((depth[d] for d in n.deps), default=-1)
        out: dict[int, list[str]] = {}
        for i, d in depth.items():
            out.setdefault(d, []).append(i)
        return [sorted(out[d]) for d in sorted(out)]

    def __len__(self) -> int:
        return len(self.nodes)

    def __iter__(self):
        return iter(self._topo)

    def to_json(self) -> list[dict[str, Any]]:
        """Serializable node rows in topological order."""
        return [{"id": n.id, "agent": n.agent, "deps": list(n.deps),
                 "description": n.description, "work_items": n.work_items}
                for n in (self.nodes[i] for i in self._topo)]
