"""Checkpoint manager: retention, auto-resume, step bookkeeping."""
from __future__ import annotations

import os
import re
import shutil

from . import checkpoint

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._saver = checkpoint.AsyncSaver() if async_save else None

    # -- discovery ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    # -- save/restore -------------------------------------------------------
    def save(self, step: int, tree):
        path = self._path(step)
        if self._saver is not None:
            self._saver.submit(tree, path)
        else:
            checkpoint.save(tree, path)
        self._gc(step)

    def restore(self, tree_like, step: int | None = None):
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        return checkpoint.restore(tree_like, self._path(step)), step

    def wait(self):
        if self._saver is not None:
            self._saver.wait()

    def _gc(self, newest: int):
        for s in self.steps()[:-self.keep]:
            if s != newest:
                shutil.rmtree(self._path(s), ignore_errors=True)
