"""Checkpoint save/restore: flattened-pytree npz shards + manifest + hashes.

Layout per step::

    <dir>/step_000100/
        manifest.json      # leaf paths, shapes, dtypes, sha256 per shard
        arrays_00000.npz   # <= shard_bytes of leaves each
        ...

Writes are atomic (tmp dir + rename) and optionally asynchronous (background
thread; ``wait()`` joins). Restore validates hashes and reassembles the exact
pytree structure, so save -> restore roundtrips bitwise (tested).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "/"


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names numpy doesn't know natively (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save(tree, directory: str, *, shard_bytes: int = 1 << 30) -> str:
    tmp = directory + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest: dict[str, Any] = {"leaves": {}, "shards": []}
    shard: dict[str, np.ndarray] = {}
    size = 0
    sid = 0

    def emit():
        nonlocal shard, size, sid
        if not shard:
            return
        name = f"arrays_{sid:05d}.npz"
        np.savez(os.path.join(tmp, name), **shard)
        manifest["shards"].append(name)
        shard, size, sid = {}, 0, sid + 1

    for key, arr in flat.items():
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": sid, "sha": _sha(arr)}
        # stored as raw bytes: npz cannot round-trip ml_dtypes (bf16 -> |V2)
        shard[key] = np.frombuffer(
            np.ascontiguousarray(arr).tobytes(), dtype=np.uint8)
        size += arr.nbytes
        if size >= shard_bytes:
            emit()
    emit()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)
    return directory


def restore(tree_like, directory: str, *, validate: bool = True):
    """Restore into the structure of ``tree_like`` (values are templates)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: dict[str, np.ndarray] = {}
    for name in manifest["shards"]:
        with np.load(os.path.join(directory, name)) as z:
            for k in z.files:
                arrays[k] = z[k]
    decoded: dict[str, np.ndarray] = {}
    for key, meta in manifest["leaves"].items():
        arr = arrays[key].view(_np_dtype(meta["dtype"])).reshape(meta["shape"])
        if validate and _sha(arr) != meta["sha"]:
            raise IOError(f"checkpoint corruption at leaf {key!r}")
        decoded[key] = arr

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, template in paths:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in decoded:
            raise KeyError(f"missing leaf {key!r} in checkpoint {directory}")
        leaves.append(jax.numpy.asarray(decoded[key]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncSaver:
    """Background-thread checkpoint writer (keeps the train loop hot)."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def submit(self, tree, directory: str):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(host_tree, directory)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
