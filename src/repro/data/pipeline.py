"""Deterministic synthetic data pipeline (host-sharded, resumable).

Produces LM token batches (plus modality-stub inputs where the architecture
needs them). Determinism contract: batch content is a pure function of
(seed, step), so a restarted job resumes bit-identically from a checkpointed
step — this is what makes checkpoint/restart tests exact.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Markov-ish synthetic text: next token depends on previous (so the loss
    # actually decreases during the e2e training example).
    structure: float = 0.7


def batch_for_step(cfg: DataConfig, step: int, model_cfg=None,
                   batch: int | None = None) -> dict:
    """Deterministic batch for ``step``; numpy on host (feeds device puts)."""
    b = batch or cfg.global_batch
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    toks = rng.integers(0, cfg.vocab_size, size=(b, cfg.seq_len + 1),
                        dtype=np.int32)
    if cfg.structure > 0:
        # structured component: t_{i+1} = (a*t_i + c) % V on masked positions
        mask = rng.random((b, cfg.seq_len)) < cfg.structure
        nxt = (toks[:, :-1] * 31 + 7) % cfg.vocab_size
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
    out = {"tokens": jnp.asarray(toks[:, :-1]),
           "labels": jnp.asarray(toks[:, 1:])}
    if model_cfg is not None and model_cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.seq_len, model_cfg.d_model),
                                dtype=np.float32).astype(np.float32),
            dtype=jnp.bfloat16)
    if model_cfg is not None and model_cfg.family == "vlm":
        v = model_cfg.vision
        out["patches"] = jnp.asarray(
            rng.standard_normal((b, v.num_patches, v.d_vision),
                                dtype=np.float32),
            dtype=jnp.bfloat16)
    return out


class DataIterator:
    """Stateful wrapper with an explicit, checkpointable step cursor."""

    def __init__(self, cfg: DataConfig, model_cfg=None, start_step: int = 0):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.step = start_step

    def __next__(self):
        b = batch_for_step(self.cfg, self.step, self.model_cfg)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
