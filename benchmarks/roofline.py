"""Roofline analysis (deliverable (g)): three terms per (arch x shape x mesh)
from the dry-run's compiled artifacts.

    compute    = HLO_FLOPs / (peak_FLOP/s per chip)        [per-device]
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Also reports MODEL_FLOPS (6*N_active*D for train, 2*N_active*D for serve),
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and the
roofline fraction = ideal-compute-time / bound-time (1.0 = perfectly
compute-bound with zero waste) — the headline §Perf metric.
"""
from __future__ import annotations

import json
import os

PEAK = 197e12          # bf16 FLOP/s per v5e chip
HBM = 819e9            # bytes/s
LINK = 50e9            # bytes/s per ICI link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

_SHAPES = {"train_4k": (4096, 256, "train"),
           "prefill_32k": (32768, 32, "prefill"),
           "decode_32k": (32768, 128, "decode"),
           "long_500k": (524288, 1, "decode")}


def model_flops_per_device(rec: dict) -> float:
    """Useful FLOPs per device: 6*N_active*D (train), 2*N_active*D (serve).

    decode processes ONE new token per sequence; prefill the full context.
    """
    seq, batch, kind = _SHAPES[rec["shape"]]
    n_dev = {"16x16": 256, "2x16x16": 512}[rec["mesh"]]
    n_act = rec["params_active"]
    if kind == "train":
        tokens = seq * batch
        per_tok = 6.0
    elif kind == "prefill":
        tokens = seq * batch
        per_tok = 2.0
    else:
        tokens = batch          # one token per sequence
        per_tok = 2.0
    return per_tok * n_act * tokens / n_dev


def analyze(rec: dict) -> dict:
    t_c = rec["flops_per_device"] / PEAK
    # v2 = production-artifact accounting (launch/hlo_cost.py): while bodies
    # scaled by known_trip_count, Pallas-kernel IO substituted for the
    # kernel-interior loops. Falls back to the legacy extrapolation fields.
    if "v2_bytes_per_device" in rec:
        t_m = rec["v2_bytes_per_device"] / HBM
        t_x = rec["v2_collective_bytes_per_device"] / LINK
    else:
        t_m = rec["hbm_bytes_per_device"] / HBM
        raw_coll = rec.get("scan_cost_raw", {}).get("coll", {}).get(
            "total_bytes", 0.0)
        t_x = max(rec["collective_bytes_per_device"], raw_coll, 0.0) / LINK
    bound = max(t_c, t_m, t_x)
    dom = {t_c: "compute", t_m: "memory", t_x: "collective"}[bound]
    mf = model_flops_per_device(rec)
    ideal = mf / PEAK
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "bound_s": bound, "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / rec["flops_per_device"]
        if rec["flops_per_device"] else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
    }


def load_records(mesh: str = "16x16", results_dir: str = RESULTS) \
        -> list[dict]:
    recs = []
    if not os.path.isdir(results_dir):
        return recs
    for name in sorted(os.listdir(results_dir)):
        if not name.endswith(f"_{mesh}.json"):
            continue
        with open(os.path.join(results_dir, name)) as f:
            rec = json.load(f)
        if rec.get("ok"):
            recs.append(rec)
        elif rec.get("skipped"):
            recs.append(rec)
    return recs


def improvement_hint(a: dict) -> str:
    """One sentence on what would move the dominant term down."""
    if a["dominant"] == "compute":
        if a["useful_ratio"] < 0.5:
            return ("compute-bound but <50% useful: cut remat recompute / "
                    "redundant einsum transposes")
        return "compute-bound at high useful ratio: near roofline; " \
               "only micro-fusion left"
    if a["dominant"] == "memory":
        return ("memory-bound: raise arithmetic intensity (bigger per-chip "
                "batch, fuse decode GEMVs, quantize KV/weights)")
    return ("collective-bound: reshard to cut all-gather/all-reduce volume "
            "(FSDP->TP swap, overlap collectives with compute, int8 grads)")


def markdown_table(mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | bound "
            "| MODEL/HLO | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh):
        if rec.get("skipped"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skip | — | — | {rec['reason']} |")
            continue
        a = analyze(rec)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3g} | "
            f"{a['t_memory_s']:.3g} | {a['t_collective_s']:.3g} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} | {improvement_hint(a)} |")
    return "\n".join(rows)


def run(verbose: bool = True) -> list[tuple[str, float, str]]:
    out: list[tuple[str, float, str]] = []
    for rec in load_records():
        if rec.get("skipped"):
            out.append((f"roofline/{rec['arch']}/{rec['shape']}", -1.0,
                        "skipped: " + rec["reason"]))
            continue
        a = analyze(rec)
        out.append((f"roofline/{a['arch']}/{a['shape']}",
                    round(a["roofline_fraction"], 3),
                    f"bound={a['dominant']} useful={a['useful_ratio']:.2f}"))
    if verbose:
        print(markdown_table())
    if not out:
        out.append(("roofline/no_records", 0.0,
                    "run repro.launch.dryrun --all first"))
    return out


if __name__ == "__main__":
    print(markdown_table())
