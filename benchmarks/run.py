"""Benchmark harness: one module per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,table2,...]

Emits ``name,value,note`` CSV to stdout (and results/bench.csv).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

SUITES = ("fig3", "table2", "table1", "overheads", "multitenant",
          "kernels", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=",".join(SUITES))
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    picked = [s.strip() for s in args.only.split(",") if s.strip()]

    from . import (fig3_traces, kernels_bench, multitenant, overheads,
                   roofline, table1_levers, table2_energy)
    mods = {"fig3": fig3_traces, "table2": table2_energy,
            "table1": table1_levers, "overheads": overheads,
            "multitenant": multitenant, "kernels": kernels_bench,
            "roofline": roofline}

    all_rows: list[tuple[str, float, str]] = []
    failures = []
    for name in picked:
        print(f"\n##### {name} " + "#" * (60 - len(name)))
        t0 = time.perf_counter()
        try:
            rows = mods[name].run(verbose=not args.quiet)
            all_rows += rows
        except Exception as e:  # keep the harness going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")

    print("\n===== CSV =====")
    print("name,value,note")
    for r in all_rows:
        print(",".join(str(x) for x in r))
    os.makedirs("results", exist_ok=True)
    with open("results/bench.csv", "w") as f:
        f.write("name,value,note\n")
        for r in all_rows:
            f.write(",".join(str(x) for x in r) + "\n")
    if failures:
        print(f"\n{len(failures)} suite failures: {failures}")
        raise SystemExit(1)
    print(f"\nall {len(picked)} suites completed; "
          f"{len(all_rows)} metrics -> results/bench.csv")


if __name__ == "__main__":
    main()
